#!/usr/bin/env bash
# Commit a bench report file to the long-lived `bench-reports` branch so the
# per-commit JSON survives artifact expiry. Called by CI on pushes to main:
#
#   .github/publish-bench-report.sh reports/BENCH_swap.json
#
# The branch is seeded from main on first use. Concurrent bench jobs both
# publish here, so the push retries on top of whatever landed first.
set -euo pipefail

report="$1"
[ -f "$report" ] || { echo "missing $report" >&2; exit 1; }

tmp="$(mktemp -d)"
cp "$report" "$tmp/"

git config user.name "github-actions[bot]"
git config user.email "github-actions[bot]@users.noreply.github.com"

if git fetch origin bench-reports 2>/dev/null; then
    git checkout -B bench-reports origin/bench-reports
else
    git checkout -B bench-reports
fi

mkdir -p reports
cp "$tmp/$(basename "$report")" "$report"
git add "$report"
if git commit -m "Update $(basename "$report") from ${GITHUB_SHA:-local}"; then
    for _ in 1 2 3; do
        if git push origin bench-reports; then
            exit 0
        fi
        git fetch origin bench-reports
        git rebase origin/bench-reports
    done
    echo "failed to push bench-reports after retries" >&2
    exit 1
else
    echo "report unchanged; nothing to publish"
fi
