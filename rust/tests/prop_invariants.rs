//! Property-based tests over the coordinator's core invariants (run via the
//! in-repo harness `util::prop` — see Cargo.toml offline note).

use squeezeattention::config::SqueezeConfig;
use squeezeattention::kvcache::{
    EvictionPolicy, FullCache, H2o, SequenceCache, SlidingWindow, SlotMeta, StreamingLlm,
};
use squeezeattention::squeeze::{allocate, kmeans_1d};
use squeezeattention::util::prop::{check, ensure, ensure_eq, ensure_le};
use squeezeattention::util::{Json, Rng};

fn random_meta(rng: &mut Rng, n: usize) -> Vec<SlotMeta> {
    (0..n)
        .map(|i| SlotMeta { position: i as u32, score: rng.f64() * 10.0 })
        .collect()
}

#[test]
fn allocator_conserves_total_budget() {
    check("allocator conservation", 300, |rng| {
        let n = rng.range(4, 96);
        let means: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let b_init = rng.range(4, 4096);
        let cfg = SqueezeConfig {
            enabled: true,
            p: 0.05 + rng.f64() * 0.95,
            groups: 3,
            min_budget: rng.range(1, 8),
        };
        let plan = allocate(&means, b_init, &cfg);
        ensure_eq(plan.total(), n * b_init, "total budget")?;
        ensure(plan.budgets.iter().all(|&b| b > 0), "all budgets positive")?;
        ensure_eq(plan.budgets.len(), n, "plan arity")
    });
}

#[test]
fn allocator_identity_when_disabled_or_p1() {
    check("allocator identity", 100, |rng| {
        let n = rng.range(4, 40);
        let means: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let b_init = rng.range(4, 512);
        let mut cfg = SqueezeConfig { enabled: false, p: 0.3, groups: 3, min_budget: 1 };
        let plan = allocate(&means, b_init, &cfg);
        ensure(plan.budgets.iter().all(|&b| b == b_init), "disabled => uniform")?;
        cfg.enabled = true;
        cfg.p = 1.0;
        let plan = allocate(&means, b_init, &cfg);
        ensure(plan.budgets.iter().all(|&b| b == b_init), "p=1 => uniform")
    });
}

#[test]
fn allocator_unimportant_layers_get_less() {
    check("allocator direction", 200, |rng| {
        let n = rng.range(6, 48);
        // bimodal means with clear separation
        let means: Vec<f64> = (0..n)
            .map(|i| if i % 3 == 0 { 0.85 + rng.f64() * 0.1 } else { 0.1 + rng.f64() * 0.2 })
            .collect();
        let b_init = rng.range(16, 1024);
        let cfg = SqueezeConfig { enabled: true, p: 0.3, groups: 3, min_budget: 1 };
        let plan = allocate(&means, b_init, &cfg);
        if !plan.reallocated {
            return Ok(());
        }
        let gmax = *plan.groups.iter().max().unwrap();
        for i in 0..n {
            if plan.groups[i] == gmax {
                ensure(plan.budgets[i] <= b_init, format!("G3 layer {i} not squeezed"))?;
            } else {
                ensure(plan.budgets[i] >= b_init, format!("important layer {i} shrank"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn kmeans_is_order_preserving() {
    check("kmeans monotone", 200, |rng| {
        let n = rng.range(3, 64);
        let vals: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let k = rng.range(1, 5.min(n));
        let c = kmeans_1d(&vals, k, 60);
        for i in 0..n {
            for j in 0..n {
                if vals[i] < vals[j] && c.assignment[i] > c.assignment[j] {
                    return Err(format!(
                        "v[{i}]={} < v[{j}]={} but group {} > {}",
                        vals[i], vals[j], c.assignment[i], c.assignment[j]
                    ));
                }
            }
        }
        ensure(c.assignment.iter().all(|&a| a < k), "groups in range")
    });
}

fn policies() -> Vec<Box<dyn EvictionPolicy>> {
    vec![
        Box::new(SlidingWindow),
        Box::new(StreamingLlm::new(4)),
        Box::new(H2o::new(0.5)),
        Box::new(H2o::new(0.0)),
        Box::new(H2o::new(1.0)),
    ]
}

#[test]
fn eviction_policies_respect_contract() {
    check("eviction contract", 200, |rng| {
        let n = rng.range(1, 256);
        let meta = random_meta(rng, n);
        let budget = rng.range(1, 300);
        for p in policies() {
            let keep = p.keep(&meta, budget);
            ensure(keep.len() <= n, format!("{}: keep > len", p.name()))?;
            if budget <= n {
                ensure(
                    keep.len() == budget.min(n),
                    format!("{}: kept {} of {n} at budget {budget}", p.name(), keep.len()),
                )?;
            } else {
                ensure_eq(keep.len(), n, p.name())?;
            }
            ensure(keep.windows(2).all(|w| w[0] < w[1]),
                   format!("{}: keep not strictly ascending", p.name()))?;
            ensure(keep.iter().all(|&i| i < n), format!("{}: out of range", p.name()))?;
        }
        // Full cache always keeps everything.
        ensure_eq(FullCache.keep(&meta, budget).len(), n, "full")
    });
}

#[test]
fn sliding_window_keeps_suffix() {
    check("sliding window recency", 100, |rng| {
        let n = rng.range(2, 128);
        let meta = random_meta(rng, n);
        let budget = rng.range(1, n);
        let keep = SlidingWindow.keep(&meta, budget);
        ensure_eq(keep, (n - budget..n).collect::<Vec<_>>(), "suffix")
    });
}

#[test]
fn streaming_llm_keeps_sinks() {
    check("streaming sinks", 100, |rng| {
        let n = rng.range(8, 200);
        let sinks = rng.range(1, 6);
        let meta = random_meta(rng, n);
        let budget = rng.range(sinks + 1, n);
        let keep = StreamingLlm::new(sinks).keep(&meta, budget);
        for s in 0..sinks {
            ensure(keep.contains(&s), format!("sink {s} evicted"))?;
        }
        ensure(keep.contains(&(n - 1)), "most recent evicted")
    });
}

#[test]
fn h2o_keeps_top_scores() {
    check("h2o heavy hitters", 100, |rng| {
        let n = rng.range(8, 128);
        let meta = random_meta(rng, n);
        let budget = rng.range(2, n);
        let keep = H2o::new(0.0).keep(&meta, budget);
        // every kept slot's score >= every dropped slot's score (pure-heavy mode)
        let kept_min = keep.iter().map(|&i| meta[i].score).fold(f64::INFINITY, f64::min);
        for i in 0..n {
            if !keep.contains(&i) {
                ensure(
                    meta[i].score <= kept_min + 1e-12,
                    format!("dropped slot {i} outranks kept"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn cache_retain_preserves_selected_rows() {
    check("cache compaction", 150, |rng| {
        let row = rng.range(1, 16);
        let n = rng.range(1, 64);
        let mut cache = SequenceCache::new(1, row);
        for i in 0..n {
            let k: Vec<f32> = (0..row).map(|j| (i * row + j) as f32).collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            cache.append(0, &k, &v, i as u32).map_err(|e| e.to_string())?;
        }
        // random keep set (sorted, unique)
        let mut keep: Vec<usize> =
            (0..n).filter(|_| rng.bool(0.6)).collect();
        keep.dedup();
        let expected: Vec<u32> = keep.iter().map(|&i| i as u32).collect();
        cache.retain(0, &keep).map_err(|e| e.to_string())?;
        ensure_eq(cache.layer_len(0), keep.len(), "len after retain")?;
        let positions: Vec<u32> = cache.layers[0].meta.iter().map(|m| m.position).collect();
        ensure_eq(positions, expected, "positions")?;
        // payload rows moved with metadata
        for (slot, &orig) in keep.iter().enumerate() {
            let got = &cache.layers[0].k[slot * row..(slot + 1) * row];
            let want: Vec<f32> = (0..row).map(|j| (orig * row + j) as f32).collect();
            ensure_eq(got.to_vec(), want, "payload row")?;
        }
        Ok(())
    });
}

#[test]
fn pool_accounting_balances() {
    use squeezeattention::kvcache::KvPool;
    check("pool balance", 100, |rng| {
        let cap = rng.range(1000, 100_000);
        let pool = KvPool::new(cap);
        let mut held: Vec<usize> = Vec::new();
        for _ in 0..200 {
            if rng.bool(0.6) {
                let want = rng.range(1, cap / 4);
                if pool.reserve(want).is_ok() {
                    held.push(want);
                }
            } else if let Some(b) = held.pop() {
                pool.release(b);
            }
            let sum: usize = held.iter().sum();
            ensure_eq(pool.in_use(), sum, "in_use == sum(held)")?;
            ensure(pool.in_use() <= cap, "never exceeds capacity")?;
        }
        Ok(())
    });
}

#[test]
fn json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::num((rng.range_i32(-100_000, 100_000) as f64) / 4.0),
            3 => {
                let n = rng.range(0, 12);
                Json::str((0..n).map(|_| rng.range_i32(32, 126) as u8 as char).collect::<String>())
            }
            4 => Json::arr((0..rng.range(0, 5)).map(|_| random_json(rng, depth - 1))),
            _ => Json::obj(
                (0..rng.range(0, 5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect::<Vec<_>>()
                    .iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect(),
            ),
        }
    }
    check("json roundtrip", 200, |rng| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).map_err(|e| format!("{e}: {text}"))?;
        ensure_eq(back, v, "roundtrip")
    });
}

#[test]
fn budget_spec_monotone_in_prompt() {
    use squeezeattention::coordinator::BudgetSpec;
    check("budget spec", 100, |rng| {
        let f = rng.f64();
        let p1 = rng.range(8, 512);
        let p2 = p1 + rng.range(1, 128);
        let b1 = BudgetSpec::Fraction(f).resolve(p1, 640);
        let b2 = BudgetSpec::Fraction(f).resolve(p2, 640);
        ensure(b2 >= b1, "fraction monotone in prompt length")?;
        ensure(b1 >= 4, "floor")
    });
}

#[test]
fn allocator_conserves_and_respects_min_budget_random_groups() {
    // Conservation and the min-budget floor over the full random surface:
    // (layer_means, p, groups, b_init, min_budget) all drawn together.
    check("allocator min budget", 300, |rng| {
        let n = rng.range(4, 80);
        let means: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let b_init = rng.range(4, 2048);
        let groups = rng.range(2, 6);
        let min_budget = rng.range(1, 64);
        let cfg = SqueezeConfig {
            enabled: true,
            p: 0.05 + rng.f64() * 0.9,
            groups,
            min_budget,
        };
        let plan = allocate(&means, b_init, &cfg);
        ensure_eq(plan.total(), n * b_init, "total budget conserved")?;
        ensure_eq(plan.budgets.len(), n, "plan arity")?;
        ensure(plan.budgets.iter().all(|&b| b > 0), "all budgets positive")?;
        if plan.reallocated {
            // When budget actually moved, every squeezed (G-last) layer must
            // still be at or above the floor, and no boosted layer below the
            // uniform baseline.
            let gmax = *plan.groups.iter().max().unwrap();
            for i in 0..n {
                if plan.groups[i] == gmax {
                    ensure(
                        plan.budgets[i] >= min_budget.min(b_init),
                        format!("G3 layer {i} got {} < floor {min_budget}", plan.budgets[i]),
                    )?;
                    ensure_le(plan.budgets[i], b_init, "squeezed layer above b_init")?;
                } else {
                    ensure(plan.budgets[i] >= b_init, format!("boosted layer {i} shrank"))?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn pool_reservation_interleavings_never_overflow_or_underflow() {
    use squeezeattention::kvcache::{KvPool, Reservation};
    // Randomized reserve/resize/release interleavings against a shadow
    // model: in_use must always equal the sum of live reservations, never
    // exceed capacity, and return to zero when everything drops.
    check("pool reservation interleavings", 150, |rng| {
        let cap = rng.range(10_000, 1_000_000);
        let pool = KvPool::new(cap);
        let mut held: Vec<Reservation> = Vec::new();
        let mut expect: Vec<usize> = Vec::new();
        for _ in 0..300 {
            match rng.range(0, 3) {
                0 => {
                    let want = rng.range(0, cap / 2);
                    match Reservation::new(&pool, want) {
                        Ok(r) => {
                            held.push(r);
                            expect.push(want);
                        }
                        Err(_) => ensure(pool.in_use() + want > cap, "spurious reserve OOM")?,
                    }
                }
                1 if !held.is_empty() => {
                    let i = rng.below(held.len());
                    let new = rng.range(0, cap / 2);
                    match held[i].resize(new) {
                        Ok(()) => expect[i] = new,
                        Err(_) => {
                            ensure(new > expect[i], "shrink must never fail")?;
                            ensure(
                                pool.in_use() + (new - expect[i]) > cap,
                                "spurious resize OOM",
                            )?;
                        }
                    }
                }
                _ if !held.is_empty() => {
                    let i = rng.below(held.len());
                    held.swap_remove(i);
                    expect.swap_remove(i);
                }
                _ => {}
            }
            let sum: usize = expect.iter().sum();
            ensure_eq(pool.in_use(), sum, "in_use == sum of live reservations")?;
            ensure_le(pool.in_use(), cap, "capacity respected")?;
            ensure(pool.peak() >= pool.in_use(), "peak covers in_use")?;
        }
        drop(held);
        ensure_eq(pool.in_use(), 0, "all bytes released on drop")
    });
}

#[test]
fn two_tier_pool_conserves_bytes_under_random_migrations() {
    use squeezeattention::kvcache::{KvPool, Reservation, Tier};
    // Device+host conservation under random reserve/resize/migrate/release
    // interleavings against a shadow model: each tier's in_use must always
    // equal the sum of the live reservations currently on that tier, no
    // tier may exceed its capacity, a failed migrate must leave both tiers
    // untouched, and everything drains to zero on drop.
    check("two-tier migrations", 150, |rng| {
        let dev_cap = rng.range(10_000, 500_000);
        let host_cap = rng.range(10_000, 500_000);
        let pool = KvPool::tiered(dev_cap, host_cap);
        let cap_of = |t: Tier| if t == Tier::Device { dev_cap } else { host_cap };
        let mut held: Vec<Reservation> = Vec::new();
        let mut expect: Vec<(Tier, usize)> = Vec::new();
        for _ in 0..300 {
            match rng.range(0, 4) {
                0 => {
                    let tier = if rng.bool(0.5) { Tier::Device } else { Tier::Host };
                    let want = rng.range(0, cap_of(tier) / 2);
                    match Reservation::on(&pool, tier, want) {
                        Ok(r) => {
                            held.push(r);
                            expect.push((tier, want));
                        }
                        Err(e) => {
                            ensure_eq(e.tier, tier, "OOM names the failing tier")?;
                            ensure(
                                pool.in_use_of(tier) + want > cap_of(tier),
                                "spurious reserve OOM",
                            )?;
                        }
                    }
                }
                1 if !held.is_empty() => {
                    let i = rng.below(held.len());
                    let (tier, old) = expect[i];
                    let new = rng.range(0, cap_of(tier) / 2);
                    match held[i].resize(new) {
                        Ok(()) => expect[i].1 = new,
                        Err(_) => {
                            ensure(new > old, "shrink must never fail")?;
                            ensure(
                                pool.in_use_of(tier) + (new - old) > cap_of(tier),
                                "spurious resize OOM",
                            )?;
                        }
                    }
                }
                2 if !held.is_empty() => {
                    let i = rng.below(held.len());
                    let (from, bytes) = expect[i];
                    let to = if from == Tier::Device { Tier::Host } else { Tier::Device };
                    let (dev_before, host_before) =
                        (pool.in_use_of(Tier::Device), pool.in_use_of(Tier::Host));
                    match held[i].migrate(to) {
                        Ok(()) => {
                            expect[i].0 = to;
                            ensure_eq(held[i].tier(), to, "reservation tier updated")?;
                        }
                        Err(e) => {
                            ensure_eq(e.tier, to, "migrate OOM names target tier")?;
                            ensure(
                                bytes + pool.in_use_of(to) > cap_of(to),
                                "spurious migrate OOM",
                            )?;
                            ensure_eq(
                                pool.in_use_of(Tier::Device),
                                dev_before,
                                "failed migrate left device unchanged",
                            )?;
                            ensure_eq(
                                pool.in_use_of(Tier::Host),
                                host_before,
                                "failed migrate left host unchanged",
                            )?;
                        }
                    }
                }
                _ if !held.is_empty() => {
                    let i = rng.below(held.len());
                    held.swap_remove(i);
                    expect.swap_remove(i);
                }
                _ => {}
            }
            for tier in [Tier::Device, Tier::Host] {
                let sum: usize = expect.iter().filter(|(t, _)| *t == tier).map(|(_, b)| b).sum();
                ensure_eq(pool.in_use_of(tier), sum, "in_use == sum of live reservations")?;
                ensure_le(pool.in_use_of(tier), cap_of(tier), "capacity respected")?;
                ensure(pool.peak_of(tier) >= pool.in_use_of(tier), "peak covers in_use")?;
            }
        }
        drop(held);
        ensure_eq(pool.in_use_of(Tier::Device), 0, "device drained on drop")?;
        ensure_eq(pool.in_use_of(Tier::Host), 0, "host drained on drop")
    });
}

#[test]
fn page_table_interleavings_conserve_refcounts_and_bytes() {
    use squeezeattention::kvcache::{KvPool, PageId, PageTable, PagedKvPool, Tier};
    use std::collections::HashMap;
    // Random grow/shrink/share/migrate/drop interleavings over a set of
    // page tables against a shadow model: every live page's refcount must
    // equal the number of tables referencing it, each tier's in_use must be
    // exactly page_bytes × (live pages on that tier), nothing may leak or
    // double-free, and the registry must drain to zero when the last table
    // drops.
    check("page table interleavings", 80, |rng| {
        let token_bytes = 16;
        let page_bytes = token_bytes * rng.range(1, 6); // 1..5 slots/page
        let pool = PagedKvPool::new(KvPool::unlimited(), page_bytes);
        let mut tables: Vec<PageTable> = Vec::new();
        let mut lens: Vec<Vec<usize>> = Vec::new();
        for _ in 0..80 {
            match rng.range(0, 6) {
                0 => {
                    if tables.len() < 6 {
                        let n_layer = rng.range(1, 4);
                        tables.push(PageTable::new(&pool, Tier::Device, n_layer, token_bytes));
                        lens.push(vec![0; n_layer]);
                    }
                }
                1 if !tables.is_empty() => {
                    let i = rng.below(tables.len());
                    let old = lens[i].clone();
                    let new: Vec<usize> = old.iter().map(|&l| l + rng.range(0, 12)).collect();
                    tables[i].grow(&old, &new).map_err(|e| e.to_string())?;
                    lens[i] = new;
                }
                2 if !tables.is_empty() => {
                    // Shrink: excess pages unmap; retained shared pages COW.
                    let i = rng.below(tables.len());
                    let new: Vec<usize> = lens[i].iter().map(|&l| rng.range(0, l + 1)).collect();
                    tables[i].shrink(&new).map_err(|e| e.to_string())?;
                    lens[i] = new;
                }
                3 if !tables.is_empty() && tables.len() < 6 => {
                    // Fork a prefix-sharing table (full pages only).
                    let i = rng.below(tables.len());
                    let maxp = lens[i].iter().copied().max().unwrap_or(0);
                    let prefix = rng.range(0, maxp + 1);
                    let spp = tables[i].slots_per_page();
                    let fork = tables[i].share_prefix(prefix);
                    let forked: Vec<usize> =
                        (0..fork.n_layer()).map(|l| fork.layer_pages(l).len() * spp).collect();
                    tables.push(fork);
                    lens.push(forked);
                }
                4 if !tables.is_empty() => {
                    // Suspend/resume: unshared pages change tier, ids stay.
                    let i = rng.below(tables.len());
                    let to = if rng.bool(0.5) { Tier::Device } else { Tier::Host };
                    let before: Vec<PageId> = (0..tables[i].n_layer())
                        .flat_map(|l| tables[i].layer_pages(l).to_vec())
                        .collect();
                    tables[i].migrate(to).map_err(|e| e.to_string())?;
                    let after: Vec<PageId> = (0..tables[i].n_layer())
                        .flat_map(|l| tables[i].layer_pages(l).to_vec())
                        .collect();
                    ensure_eq(before, after, "migrate must not remap pages")?;
                }
                _ if !tables.is_empty() => {
                    let i = rng.below(tables.len());
                    tables.swap_remove(i);
                    lens.swap_remove(i);
                }
                _ => {}
            }
            // Shadow refcounts from the tables themselves.
            let mut refs: HashMap<PageId, usize> = HashMap::new();
            for t in &tables {
                for l in 0..t.n_layer() {
                    for &id in t.layer_pages(l) {
                        *refs.entry(id).or_insert(0) += 1;
                    }
                }
            }
            ensure_eq(pool.live_pages(), refs.len(), "live pages == referenced pages")?;
            let mut by_tier = [0usize; 2];
            for (&id, &n) in &refs {
                ensure_eq(pool.refs_of(id), Some(n), "refcount == referencing tables")?;
                match pool.tier_of(id) {
                    Some(Tier::Device) => by_tier[0] += 1,
                    Some(Tier::Host) => by_tier[1] += 1,
                    None => return Err("referenced page has no tier".into()),
                }
            }
            let expected_shared = refs.values().filter(|&&n| n > 1).count();
            ensure_eq(pool.shared_pages(), expected_shared, "shared-page gauge")?;
            ensure_eq(
                pool.pool().in_use_of(Tier::Device),
                by_tier[0] * page_bytes,
                "device bytes == device pages × page_bytes",
            )?;
            ensure_eq(
                pool.pool().in_use_of(Tier::Host),
                by_tier[1] * page_bytes,
                "host bytes == host pages × page_bytes",
            )?;
        }
        drop(tables);
        ensure_eq(pool.live_pages(), 0, "no leaked pages")?;
        ensure_eq(pool.pool().in_use(), 0, "all bytes released")?;
        ensure_eq(pool.pages_allocated(), pool.pages_freed(), "alloc/free balance")?;
        ensure_eq(pool.pool().accounting_errors(), 0, "no double-frees detected")
    });
}

#[test]
fn eviction_bounds_every_layer_to_its_budget() {
    // The 2-D contract: applying any sequence-wise policy per layer with
    // that layer's own (heterogeneous) budget leaves every layer's cached
    // tokens at min(len, budget), with payload and metadata compacted in
    // lockstep.
    check("eviction bounds cache", 120, |rng| {
        let row = rng.range(1, 8);
        let n_layer = rng.range(1, 6);
        let mut cache = SequenceCache::new(n_layer, row);
        let mut lens = Vec::with_capacity(n_layer);
        for layer in 0..n_layer {
            let n = rng.range(1, 96);
            lens.push(n);
            for i in 0..n {
                let k: Vec<f32> = (0..row).map(|_| rng.f64() as f32).collect();
                let v = k.clone();
                cache.append(layer, &k, &v, i as u32).map_err(|e| e.to_string())?;
            }
            // Give H2O a realistic score distribution to rank.
            let scores: Vec<f32> = (0..n).map(|_| rng.f64() as f32).collect();
            cache.add_scores(layer, &scores).map_err(|e| e.to_string())?;
        }
        for p in policies() {
            let mut c = cache.clone();
            for layer in 0..n_layer {
                let budget = rng.range(1, 128);
                let keep = p.keep(&c.layers[layer].meta, budget);
                c.retain(layer, &keep).map_err(|e| e.to_string())?;
                ensure_eq(
                    c.layer_len(layer),
                    budget.min(lens[layer]),
                    &format!("{}: layer {layer} size", p.name()),
                )?;
                ensure_le(c.layer_len(layer), budget, "budget bound")?;
                ensure_eq(
                    c.layers[layer].k.len(),
                    c.layer_len(layer) * row,
                    "payload compacted with metadata",
                )?;
            }
        }
        Ok(())
    });
}
