//! Speculative-decoding parity suite (on `sim://tiny`, so it always runs).
//!
//! The contract under test: a draft→verify→rollback burst commits exactly
//! the tokens non-speculative decode would have committed, under every
//! eviction policy's squeezed cache — because each verify micro-step runs
//! the engine's single per-token commit path from a byte-exact rollback.
//!
//! * every policy × draft_k ∈ {1, 4, 8} is token-identical to the
//!   non-speculative run, budget plans included;
//! * parity survives a suspend/resume cycle (capped device pool + host
//!   spill forces swap-outs mid-generation);
//! * a cancel mid-generation keeps a prefix of the non-speculative stream,
//!   emits `Token` events only for committed tokens (rollback never emits,
//!   positions stay dense), and drains the pool;
//! * acceptance metrics: bursts commit more than one token per step on the
//!   paired draft model;
//! * `SequenceCache::truncate` rollback is byte-exact against a shadow
//!   cache under random append/score/retain/truncate/snapshot-restore
//!   interleavings, and the paged tables conserve page refcounts.

use std::collections::BTreeMap;

use squeezeattention::config::{PolicyKind, ServeConfig};
use squeezeattention::coordinator::{
    Engine, FinishReason, Request, RequestEvent, RequestHandle, RequestOutput,
};
use squeezeattention::kvcache::{KvPool, PageTable, PagedKvPool, SequenceCache, Tier};
use squeezeattention::util::prop::{check, ensure, ensure_eq};
use squeezeattention::workload::{Task, TaskGen, TraceSpec};

const ARTIFACTS: &str = "sim://tiny";

fn cfg(policy: PolicyKind) -> ServeConfig {
    ServeConfig::new(ARTIFACTS).with_budget(48).with_policy(policy)
}

fn requests(n: usize, prompt_len: usize, max_new: usize, seed: u64) -> Vec<Request> {
    TraceSpec::closed(n, prompt_len, max_new, seed)
        .generate()
        .iter()
        .enumerate()
        .map(|(i, it)| Request::new(i as u64, it.sample.prompt.clone(), max_new))
        .collect()
}

fn by_id(outs: Vec<RequestOutput>) -> BTreeMap<u64, RequestOutput> {
    outs.into_iter().map(|o| (o.id, o)).collect()
}

/// Closed-batch run on a fresh engine; asserts the pool drains.
fn run(cfg: ServeConfig, reqs: Vec<Request>) -> BTreeMap<u64, RequestOutput> {
    let mut eng = Engine::new(cfg).unwrap();
    let outs = eng.generate_batch(reqs);
    assert_eq!(eng.pool().in_use(), 0, "pool not fully released");
    by_id(outs)
}

fn assert_parity(
    base: &BTreeMap<u64, RequestOutput>,
    spec: &BTreeMap<u64, RequestOutput>,
    label: &str,
) {
    assert_eq!(base.len(), spec.len(), "{label}: output count diverged");
    for (id, b) in base {
        let s = &spec[id];
        assert!(
            matches!(b.finish, FinishReason::Eos | FinishReason::Length),
            "{label} id={id}: baseline finish {:?}",
            b.finish
        );
        assert_eq!(b.finish, s.finish, "{label} id={id}: finish reason diverged");
        assert_eq!(
            b.generated, s.generated,
            "{label} id={id}: speculative decode changed the generated tokens"
        );
        assert_eq!(b.plan.budgets, s.plan.budgets, "{label} id={id}: budget plan diverged");
    }
}

#[test]
fn spec_is_token_identical_for_every_policy_and_draft_k() {
    for policy in PolicyKind::ALL {
        let reqs = requests(6, 80, 16, 11);
        let base = run(cfg(policy), reqs.clone());
        for k in [1usize, 4, 8] {
            let spec = run(cfg(policy).with_spec_k(k), reqs.clone());
            assert_parity(&base, &spec, &format!("{} k={k}", policy.name()));
        }
    }
}

#[test]
fn spec_parity_survives_suspend_resume() {
    // Same pressure shape as the lifecycle suite: a 600 KiB device pool
    // over 6 growing sequences at max_batch 4 forces suspensions to the
    // host tier mid-generation. Resume must land the verify micro-steps on
    // exactly the swapped cache state. H2O is the hardest policy here (the
    // score accumulators must survive both rollback and the swap).
    for policy in [PolicyKind::SlidingWindow, PolicyKind::H2o] {
        let make_cfg = |k: usize| {
            let mut c = cfg(policy).with_host_spill(8 * 1024 * 1024).with_spec_k(k);
            c.max_batch = 4;
            c.kv_pool_bytes = 600 * 1024;
            c
        };
        let reqs = requests(6, 16, 48, 31);
        let base = run(make_cfg(0), reqs.clone());
        for k in [1usize, 4, 8] {
            let mut eng = Engine::new(make_cfg(k)).unwrap();
            let outs = eng.generate_batch(reqs.clone());
            let m = eng.sched_metrics();
            assert!(
                m.preemptions > 0,
                "{} k={k}: pool pressure never preempted — resize the workload",
                policy.name()
            );
            assert!(
                m.swap_ins > 0,
                "{} k={k}: nothing ever resumed from the host tier",
                policy.name()
            );
            assert_eq!(eng.pool().in_use(), 0, "device pool not drained");
            assert_eq!(eng.pool().in_use_of(Tier::Host), 0, "host tier not drained");
            assert_parity(&base, &by_id(outs), &format!("{} swap k={k}", policy.name()));
        }
    }
}

#[test]
fn spec_commits_more_than_one_token_per_step() {
    let mut eng = Engine::new(cfg(PolicyKind::SlidingWindow).with_spec_k(4)).unwrap();
    let outs = eng.generate_batch(requests(6, 80, 24, 13));
    assert_eq!(outs.len(), 6);
    let m = eng.sched_metrics();
    assert!(m.spec_steps > 0, "no speculative bursts ran");
    assert!(m.spec_drafted > 0, "no tokens were ever drafted");
    assert!(
        m.spec_accepted > 0,
        "draft model never agreed with the target — check the sim draft perturbation"
    );
    assert!(
        m.spec_accepted_per_step() > 1.0,
        "bursts must beat one token per step; got {}",
        m.spec_accepted_per_step()
    );
    let rate = m.spec_acceptance_rate();
    assert!((0.0..=1.0).contains(&rate), "acceptance rate out of range: {rate}");
    assert_eq!(
        m.spec_accepted + m.spec_rollback_tokens,
        m.spec_drafted,
        "every drafted token is either accepted or rolled back"
    );
}

#[test]
fn cancel_mid_generation_keeps_prefix_and_never_emits_rolled_back_tokens() {
    let spec_cfg = || cfg(PolicyKind::SlidingWindow).with_spec_k(4);
    let mut gen = TaskGen::new(5);
    let prompt = gen.sample(Task::Copy, 64).prompt;

    // Reference stream: the full non-speculative run of the same request.
    let full = run(cfg(PolicyKind::SlidingWindow), vec![Request::new(0, prompt.clone(), 200)]);
    let full = &full[&0].generated;
    assert!(full.len() > 20, "reference run too short to cancel inside");

    // Deterministic cancel between bursts: step a few times, cancel, drain.
    let mut eng = Engine::new(spec_cfg()).unwrap();
    let mut req = Request::new(0, prompt.clone(), 200);
    let handle = RequestHandle::attach(&mut req);
    eng.submit(req).unwrap();
    let mut outs = Vec::new();
    for _ in 0..3 {
        outs.extend(eng.step().unwrap());
        assert!(outs.is_empty(), "request finished before it could be cancelled");
    }
    handle.cancel();
    while eng.has_work() {
        outs.extend(eng.step().unwrap());
    }
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].finish, FinishReason::Cancelled);
    let got = &outs[0].generated;
    assert!(!got.is_empty(), "partial output must be preserved");
    assert!(got.len() < 200, "cancel did not stop decode early");
    assert_eq!(
        &full[..got.len()],
        &got[..],
        "cancelled speculative output is not a prefix of the non-speculative stream"
    );
    assert_eq!(eng.pool().in_use(), 0, "cancel did not release the reservation");

    // Token events must match the committed output exactly — one event per
    // committed token with dense positions; rolled-back drafts never emit.
    let evs: Vec<RequestEvent> = handle.events().try_iter().collect();
    assert!(matches!(evs.last(), Some(RequestEvent::Cancelled(_))));
    let mut toks = Vec::new();
    for ev in &evs {
        if let RequestEvent::Token { token, pos, .. } = ev {
            assert_eq!(*pos, toks.len(), "token positions must stay dense across bursts");
            toks.push(*token);
        }
    }
    assert_eq!(toks, *got, "token events diverge from the committed output");

    // Asynchronous cancel: fire the token from another thread while the
    // engine steps, so the flag can land between verify micro-steps
    // (mid-burst). Whenever it lands, the output must still be a prefix of
    // the reference stream with exactly matching token events.
    let mut eng = Engine::new(spec_cfg()).unwrap();
    let mut req = Request::new(1, prompt.clone(), 200);
    let handle = RequestHandle::attach(&mut req);
    let token = req.cancel.clone().expect("attach installs a cancel token");
    eng.submit(req).unwrap();
    let canceller = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(3));
        token.cancel();
    });
    let mut outs = Vec::new();
    while eng.has_work() {
        outs.extend(eng.step().unwrap());
    }
    canceller.join().unwrap();
    assert_eq!(outs.len(), 1);
    assert!(matches!(
        outs[0].finish,
        // Cancelled when the flag lands in time; on a very fast host the
        // run may legitimately complete first — the prefix check below
        // still pins correctness.
        FinishReason::Cancelled | FinishReason::Length | FinishReason::Eos
    ));
    let got = &outs[0].generated;
    assert_eq!(&full[..got.len()], &got[..], "async cancel broke the prefix property");
    let toks: Vec<i32> = handle
        .events()
        .try_iter()
        .filter_map(|e| match e {
            RequestEvent::Token { token, .. } => Some(token),
            _ => None,
        })
        .collect();
    assert_eq!(toks, *got, "async cancel leaked rolled-back token events");
    assert_eq!(eng.pool().in_use(), 0);
}

/// Shadow model for one layer: positions, H2O scores, and payload rows.
#[derive(Clone, Default)]
struct ShadowLayer {
    pos: Vec<u32>,
    score: Vec<f64>,
    k: Vec<f32>,
    v: Vec<f32>,
}

#[test]
fn truncate_rollback_is_byte_exact_and_conserves_pages() {
    check("truncate rollback", 120, |rng| {
        let row = rng.range(1, 8);
        let n_layer = rng.range(1, 5);
        let token_bytes = SequenceCache::token_bytes(row);
        let page_bytes = token_bytes * rng.range(1, 5); // 1..4 slots/page
        let pool = PagedKvPool::new(KvPool::unlimited(), page_bytes);
        let mut table = PageTable::new(&pool, Tier::Device, n_layer, token_bytes);
        let mut cache = SequenceCache::new(n_layer, row);
        let mut shadow: Vec<ShadowLayer> = vec![ShadowLayer::default(); n_layer];
        let mut next_pos: u32 = 0;
        // (snapshot, shadow, next_pos) saved for a later rollback-restore.
        let mut saved = None;

        let lens_of = |sh: &[ShadowLayer]| -> Vec<usize> {
            sh.iter().map(|l| l.pos.len()).collect()
        };

        for _ in 0..40 {
            match rng.range(0, 6) {
                // Append a burst of 1..5 tokens to every layer (the engine's
                // draft/commit shape), charging the table first.
                0 | 1 => {
                    let n = rng.range(1, 6);
                    let old = lens_of(&shadow);
                    let new: Vec<usize> = old.iter().map(|&l| l + n).collect();
                    table.grow(&old, &new).map_err(|e| e.to_string())?;
                    for _ in 0..n {
                        for (layer, sh) in shadow.iter_mut().enumerate() {
                            let k: Vec<f32> = (0..row).map(|_| rng.f64() as f32).collect();
                            let v: Vec<f32> = (0..row).map(|_| rng.f64() as f32).collect();
                            cache.append(layer, &k, &v, next_pos).map_err(|e| e.to_string())?;
                            sh.pos.push(next_pos);
                            sh.score.push(0.0);
                            sh.k.extend_from_slice(&k);
                            sh.v.extend_from_slice(&v);
                        }
                        next_pos += 1;
                    }
                }
                // Fold an H2O score vector into every non-empty layer.
                2 => {
                    for (layer, sh) in shadow.iter_mut().enumerate() {
                        if sh.pos.is_empty() {
                            continue;
                        }
                        let scores: Vec<f32> =
                            (0..sh.pos.len()).map(|_| rng.f64() as f32).collect();
                        cache.add_scores(layer, &scores).map_err(|e| e.to_string())?;
                        for (acc, s) in sh.score.iter_mut().zip(&scores) {
                            *acc += *s as f64;
                        }
                    }
                }
                // Evict a random sorted subset per layer (any policy's
                // output shape), then return whole pages.
                3 => {
                    for (layer, sh) in shadow.iter_mut().enumerate() {
                        let keep: Vec<usize> =
                            (0..sh.pos.len()).filter(|_| rng.bool(0.7)).collect();
                        cache.retain(layer, &keep).map_err(|e| e.to_string())?;
                        let pick = |xs: &[u32]| keep.iter().map(|&i| xs[i]).collect::<Vec<_>>();
                        sh.pos = pick(&sh.pos);
                        sh.score = keep.iter().map(|&i| sh.score[i]).collect();
                        sh.k = keep
                            .iter()
                            .flat_map(|&i| sh.k[i * row..(i + 1) * row].to_vec())
                            .collect();
                        sh.v = keep
                            .iter()
                            .flat_map(|&i| sh.v[i * row..(i + 1) * row].to_vec())
                            .collect();
                    }
                    table.shrink(&lens_of(&shadow)).map_err(|e| e.to_string())?;
                }
                // The rollback op itself: truncate to a random cut.
                4 => {
                    let cut = rng.range(0, next_pos as usize + 1);
                    let dropped = cache.truncate(cut);
                    let mut expect_dropped = 0usize;
                    for sh in shadow.iter_mut() {
                        let keep = sh.pos.iter().take_while(|&&p| p < cut as u32).count();
                        expect_dropped += sh.pos.len() - keep;
                        sh.pos.truncate(keep);
                        sh.score.truncate(keep);
                        sh.k.truncate(keep * row);
                        sh.v.truncate(keep * row);
                    }
                    ensure_eq(dropped, expect_dropped, "truncate drop count")?;
                    table.shrink(&lens_of(&shadow)).map_err(|e| e.to_string())?;
                }
                // Snapshot now, or restore a snapshot taken earlier (the
                // suspend/resume path composed with rollback).
                _ => {
                    match saved.take() {
                        None => saved = Some((cache.clone().snapshot(), shadow.clone(), next_pos)),
                        Some((snap, sh, pos)) => {
                            cache = snap.restore();
                            shadow = sh;
                            next_pos = pos;
                            // Resume builds a fresh table for the restored
                            // lengths, exactly like swap-in does.
                            table = PageTable::new(&pool, Tier::Device, n_layer, token_bytes);
                            let zeros = vec![0usize; n_layer];
                            let lens = lens_of(&shadow);
                            table.grow(&zeros, &lens).map_err(|e| e.to_string())?;
                        }
                    }
                }
            }

            // Byte-exact state check against the shadow, every step.
            let spp = table.slots_per_page();
            let mut live = 0usize;
            for (layer, sh) in shadow.iter().enumerate() {
                ensure_eq(cache.layer_len(layer), sh.pos.len(), "layer len")?;
                let pos: Vec<u32> = cache.layers[layer].meta.iter().map(|m| m.position).collect();
                ensure_eq(pos, sh.pos.clone(), "positions")?;
                let score: Vec<f64> = cache.layers[layer].meta.iter().map(|m| m.score).collect();
                ensure_eq(score, sh.score.clone(), "H2O score accumulators")?;
                ensure_eq(cache.layers[layer].k.clone(), sh.k.clone(), "K payload")?;
                ensure_eq(cache.layers[layer].v.clone(), sh.v.clone(), "V payload")?;
                // Table pages track ceil(len / slots_per_page) exactly.
                ensure_eq(
                    table.layer_pages(layer).len(),
                    sh.pos.len().div_ceil(spp),
                    "pages per layer",
                )?;
                live += table.layer_pages(layer).len();
            }
            // One unshared table (+ possibly a parked snapshot, which holds
            // no pages): live pages and pool bytes must agree exactly.
            ensure_eq(pool.live_pages(), live, "live pages == mapped pages")?;
            ensure_eq(pool.pool().in_use(), live * page_bytes, "pool bytes == pages")?;
        }

        drop(table);
        ensure_eq(pool.live_pages(), 0, "no leaked pages")?;
        ensure_eq(pool.pool().in_use(), 0, "all bytes released")?;
        ensure_eq(pool.pages_allocated(), pool.pages_freed(), "alloc/free balance")?;
        ensure(pool.pool().accounting_errors() == 0, "no double-frees detected")
    });
}
