//! Request-lifecycle subsystem integration tests (on `sim://tiny`, so they
//! always run):
//!
//! * cancellation mid-decode releases the device reservation (pool `in_use`
//!   returns to the pre-admission level) and preserves the partial output;
//! * cancel-while-suspended frees the host tier directly — no swap-in;
//! * deadlines are enforced at step boundaries (`DeadlineExceeded`), both
//!   per-request and via the `request_deadline_ms` config default;
//! * a streamed connection's token lines concatenate to exactly the
//!   non-streamed `generated` array for the same pipelined workload;
//! * a client disconnect cancels that connection's in-flight requests
//!   (observed through the wire metrics snapshot);
//! * the router forwards lifecycle events across the worker boundary under
//!   the caller's original ids and exports TTFT/ITL histograms.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{
    server, Engine, FinishReason, Request, RequestEvent, RequestHandle, RoutePolicy, Router,
};
use squeezeattention::kvcache::Tier;
use squeezeattention::util::Json;
use squeezeattention::workload::{Task, TaskGen, TraceSpec};

const ARTIFACTS: &str = "sim://tiny";

fn base_cfg() -> ServeConfig {
    ServeConfig::new(ARTIFACTS).with_budget(48).with_squeeze(false)
}

/// Boot a 1-worker router + TCP server on an ephemeral port.
fn boot_server(cfg: ServeConfig) -> std::net::SocketAddr {
    let router = Arc::new(Router::spawn(cfg, 1, RoutePolicy::RoundRobin).unwrap());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = server::serve(listener, router);
    });
    addr
}

fn json_ints(prompt: &[i32]) -> String {
    prompt.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",")
}

#[test]
fn cancel_mid_decode_releases_pool_bytes() {
    let mut eng = Engine::new(base_cfg()).unwrap();
    let mut gen = TaskGen::new(5);
    let sample = gen.sample(Task::Copy, 64);
    let mut req = Request::new(0, sample.prompt.clone(), 200);
    let handle = RequestHandle::attach(&mut req);
    let baseline = eng.pool().in_use(); // pre-admission level
    eng.submit(req).unwrap();
    for _ in 0..4 {
        let outs = eng.step().unwrap();
        assert!(outs.is_empty(), "request finished before it could be cancelled");
    }
    assert!(eng.pool().in_use() > baseline, "no KV bytes held mid-decode");

    handle.cancel();
    let outs = eng.step().unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].finish, FinishReason::Cancelled);
    assert!(!outs[0].generated.is_empty(), "partial output must be preserved");
    assert!(outs[0].generated.len() < 200, "cancel did not stop decode early");
    assert_eq!(eng.pool().in_use(), baseline, "device reservation not fully released");
    assert!(!eng.has_work());
    assert_eq!(eng.sched_metrics().cancelled, 1);

    // Event stream: Started first, Tokens matching the partial output,
    // Cancelled terminal last.
    let evs: Vec<RequestEvent> = handle.events().try_iter().collect();
    assert!(matches!(evs.first(), Some(RequestEvent::Started { .. })));
    assert!(matches!(evs.last(), Some(RequestEvent::Cancelled(_))));
    let toks: Vec<i32> = evs
        .iter()
        .filter_map(|e| match e {
            RequestEvent::Token { token, .. } => Some(*token),
            _ => None,
        })
        .collect();
    assert_eq!(toks, outs[0].generated, "token events diverge from the output");
}

#[test]
fn cancel_while_suspended_frees_host_tier_without_swap_in() {
    // Same pressure shape as the oom_preemption suite: a 600 KiB device
    // pool over 6 growing sequences forces suspensions to the host tier.
    let mut cfg = base_cfg().with_host_spill(8 * 1024 * 1024);
    cfg.max_batch = 4;
    cfg.kv_pool_bytes = 600 * 1024;
    let mut eng = Engine::new(cfg).unwrap();
    let items = TraceSpec::closed(6, 16, 48, 31).generate();
    let mut handles = Vec::new();
    for (i, it) in items.iter().enumerate() {
        let mut req = Request::new(i as u64, it.sample.prompt.clone(), 48);
        handles.push(RequestHandle::attach(&mut req));
        eng.submit(req).unwrap();
    }

    let mut outs = Vec::new();
    let mut steps = 0;
    while eng.suspended_len() == 0 {
        assert!(eng.has_work(), "workload drained without ever suspending — resize it");
        outs.extend(eng.step().unwrap());
        steps += 1;
        assert!(steps < 10_000, "pool pressure never suspended a sequence");
    }
    assert!(eng.pool().in_use_of(Tier::Host) > 0, "suspended sequence holds no host bytes");
    let swap_ins_before = eng.sched_metrics().swap_ins;

    // Cancel everything: suspended entries must release their host bytes
    // directly, never migrating back to the device tier first.
    for h in &handles {
        h.cancel();
    }
    while eng.has_work() {
        outs.extend(eng.step().unwrap());
    }
    assert_eq!(outs.len(), 6);
    assert!(outs.iter().all(|o| matches!(
        o.finish,
        FinishReason::Eos | FinishReason::Length | FinishReason::Cancelled
    )));
    assert!(outs.iter().any(|o| o.finish == FinishReason::Cancelled));
    let m = eng.sched_metrics();
    assert_eq!(m.swap_ins, swap_ins_before, "cancel-while-suspended must not swap in");
    assert!(m.cancelled > 0);
    assert_eq!(eng.pool().in_use_of(Tier::Host), 0, "host tier not freed");
    assert_eq!(eng.pool().in_use(), 0, "device tier not freed");
}

#[test]
fn deadline_exceeded_at_step_boundary() {
    let mut eng = Engine::new(base_cfg()).unwrap();
    let mut gen = TaskGen::new(7);
    let sample = gen.sample(Task::Copy, 48);
    let req =
        Request::new(0, sample.prompt.clone(), 500).with_deadline(Duration::from_millis(20));
    eng.submit(req).unwrap();
    let mut outs = Vec::new();
    while eng.has_work() {
        outs.extend(eng.step().unwrap());
        // Give each step observable wall time so the deadline reliably
        // lapses mid-generation regardless of host speed.
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].finish, FinishReason::DeadlineExceeded);
    assert!(!outs[0].generated.is_empty(), "deadline kept no partial output");
    assert!(outs[0].generated.len() < 500, "deadline never fired");
    assert_eq!(eng.sched_metrics().deadline_exceeded, 1);
    assert_eq!(eng.pool().in_use(), 0, "deadline did not release the reservation");
}

#[test]
fn config_default_deadline_applies_when_request_has_none() {
    let mut eng = Engine::new(base_cfg().with_request_deadline_ms(15)).unwrap();
    let mut gen = TaskGen::new(9);
    let sample = gen.sample(Task::Copy, 48);
    eng.submit(Request::new(0, sample.prompt.clone(), 500)).unwrap();
    let mut outs = Vec::new();
    while eng.has_work() {
        outs.extend(eng.step().unwrap());
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].finish, FinishReason::DeadlineExceeded);
    assert_eq!(eng.sched_metrics().deadline_exceeded, 1);
}

#[test]
fn streamed_tokens_match_non_streamed_generation() {
    let addr = boot_server(ServeConfig::new(ARTIFACTS).with_budget(48));
    let mut gen = TaskGen::new(11);
    let prompts: Vec<Vec<i32>> = (0..3).map(|_| gen.sample(Task::Copy, 40).prompt).collect();

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // Pipeline the same workload twice on one connection: first streamed,
    // then plain — all six share the worker's continuous batch.
    for (i, p) in prompts.iter().enumerate() {
        writeln!(
            writer,
            "{{\"id\": {}, \"prompt\": [{}], \"max_new_tokens\": 12, \"stream\": true}}",
            i + 1,
            json_ints(p)
        )
        .unwrap();
    }
    for (i, p) in prompts.iter().enumerate() {
        writeln!(
            writer,
            "{{\"id\": {}, \"prompt\": [{}], \"max_new_tokens\": 12}}",
            i + 101,
            json_ints(p)
        )
        .unwrap();
    }

    let mut read_json = move || {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(&line).unwrap()
    };
    let ints = |j: &Json, key: &str| -> Vec<i64> {
        j.get(key).unwrap().as_arr().unwrap().iter().map(|v| v.as_i64().unwrap()).collect()
    };

    // Streamed requests: token lines in order, summary last, concatenation
    // byte-identical to the summary's generated array.
    let mut streamed: Vec<Vec<i64>> = Vec::new();
    for expect in 1..=3i64 {
        let mut toks: Vec<i64> = Vec::new();
        loop {
            let j = read_json();
            assert_eq!(j.get("id").unwrap().as_i64(), Some(expect), "responses out of order");
            if let Some(t) = j.get("token") {
                assert_eq!(
                    j.get("pos").unwrap().as_usize(),
                    Some(toks.len()),
                    "token pos out of order"
                );
                toks.push(t.as_i64().unwrap());
            } else {
                let generated = ints(&j, "generated");
                assert!(!generated.is_empty());
                assert_eq!(generated, toks, "streamed tokens != summary generated");
                break;
            }
        }
        streamed.push(toks);
    }

    // Non-streamed requests over the same prompts: byte-identical output.
    for (i, want) in streamed.iter().enumerate() {
        let j = read_json();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(i + 101));
        assert!(j.get("token").is_none(), "plain request must not stream");
        assert_eq!(&ints(&j, "generated"), want, "streamed vs non-streamed divergence");
    }
}

#[test]
fn client_disconnect_cancels_in_flight_requests() {
    let addr = boot_server(ServeConfig::new(ARTIFACTS).with_budget(48));
    let mut gen = TaskGen::new(13);
    let prompt = gen.sample(Task::Copy, 40).prompt;

    // Start a long streamed generation, read a couple of token lines to be
    // sure it is decoding, then drop the connection.
    {
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writeln!(
            writer,
            "{{\"id\": 1, \"prompt\": [{}], \"max_new_tokens\": 600, \"stream\": true}}",
            json_ints(&prompt)
        )
        .unwrap();
        for _ in 0..2 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(Json::parse(&line).unwrap().get("token").is_some());
        }
    } // connection dropped here

    // The server's next token write fails, which must cancel the request.
    // Observe it through the wire metrics snapshot on a fresh connection.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writeln!(writer, "{{\"metrics\": true}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        let cancelled = j.get("workers").unwrap().as_arr().unwrap()[0]
            .get("scheduler")
            .unwrap()
            .get("cancelled")
            .unwrap()
            .as_usize()
            .unwrap();
        if cancelled >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "disconnect never cancelled the in-flight request: {j}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn router_forwards_events_with_original_ids_and_exports_latency_metrics() {
    let router = Router::spawn(base_cfg(), 1, RoutePolicy::LeastLoaded).unwrap();
    let mut gen = TaskGen::new(17);
    let sample = gen.sample(Task::Copy, 40);
    let h1 = router.submit_stream(Request::new(7_000, sample.prompt.clone(), 8)).unwrap();
    let h2 = router.submit_stream(Request::new(7_001, sample.prompt.clone(), 8)).unwrap();

    fn collect(h: &RequestHandle) -> (Vec<i32>, squeezeattention::coordinator::RequestOutput) {
        let mut toks = Vec::new();
        loop {
            let ev = h.recv().expect("stream must end with a terminal event");
            assert_eq!(ev.id(), h.id(), "event escaped with a worker-local ticket id");
            match ev {
                RequestEvent::Token { token, pos, .. } => {
                    assert_eq!(pos, toks.len());
                    toks.push(token);
                }
                other => {
                    if other.is_terminal() {
                        return (toks, other.into_output().unwrap());
                    }
                }
            }
        }
    }
    let (t1, o1) = collect(&h1);
    let (t2, o2) = collect(&h2);
    assert_eq!(o1.id, 7_000);
    assert_eq!(o2.id, 7_001);
    assert!(matches!(o1.finish, FinishReason::Eos | FinishReason::Length));
    assert_eq!(t1, o1.generated, "forwarded tokens diverge from the output");
    assert_eq!(t2, o2.generated);
    assert_eq!(o1.generated, o2.generated, "same prompt, same greedy tokens");

    // The worker snapshot (refreshed post-step) must surface the TTFT and
    // inter-token-latency histograms in the router's JSON metrics export.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let j = router.metrics_json();
        let w = &j.get("workers").unwrap().as_arr().unwrap()[0];
        let completed =
            w.get("scheduler").unwrap().get("completed").unwrap().as_usize().unwrap();
        let ttft_count = w.get("ttft_s").unwrap().get("count").unwrap().as_usize().unwrap();
        let itl_count = w.get("itl_s").unwrap().get("count").unwrap().as_usize().unwrap();
        if completed >= 2 && ttft_count >= 2 && itl_count > 0 {
            assert!(w.get("queue_latency_s").is_some());
            break;
        }
        assert!(Instant::now() < deadline, "metrics snapshot never caught up: {j}");
        std::thread::sleep(Duration::from_millis(5));
    }
}
