//! OOM-preemption regression: a capacity-capped KV pool holding fewer
//! concurrent sequences than the scheduler admits must complete ALL
//! requests via preemption — nobody fails, nothing is lost or duplicated,
//! and the pool records real OOM pressure along the way. With the host
//! spill tier disabled (`host_spill_bytes = 0`, the default) preemption is
//! restart-from-scratch; with it enabled, preempted sequences suspend to
//! host memory and resume token-identically (swap-out/swap-in).
//!
//! Sizing (sim://tiny: 8 layers x 128 f32 row elems = 1024 B per
//! token-layer): uniform budget 48 with prompt 16 admits at ~131 KB per
//! sequence but grows toward ~400 KB (budget+1 rows x 8 layers). A 600 KB
//! pool therefore admits several sequences and then runs out as they grow:
//! exactly the condition preemption must resolve. One sequence always fits
//! alone, so forward progress (oldest never preempted) guarantees
//! completion.

use std::collections::BTreeSet;

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{Engine, FinishReason, Request};
use squeezeattention::kvcache::Tier;
use squeezeattention::workload::TraceSpec;

const POOL_BYTES: usize = 600 * 1024;
const N_REQUESTS: usize = 6;
const PROMPT_LEN: usize = 16;
const MAX_NEW: usize = 48;

fn capped_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::new("sim://tiny")
        .with_budget(48)
        .with_squeeze(false); // uniform budgets -> predictable growth
    cfg.max_batch = 4;
    cfg.kv_pool_bytes = POOL_BYTES;
    cfg
}

fn trace_requests() -> Vec<Request> {
    TraceSpec::closed(N_REQUESTS, PROMPT_LEN, MAX_NEW, 31)
        .generate()
        .iter()
        .enumerate()
        .map(|(i, it)| Request::new(i as u64, it.sample.prompt.clone(), MAX_NEW))
        .collect()
}

#[test]
fn capped_pool_completes_all_requests_via_preemption() {
    let mut eng = Engine::new(capped_cfg()).unwrap();
    let outs = eng.generate_batch(trace_requests());

    // No lost or duplicated outputs.
    assert_eq!(outs.len(), N_REQUESTS);
    let ids: BTreeSet<u64> = outs.iter().map(|o| o.id).collect();
    assert_eq!(ids.len(), N_REQUESTS, "duplicate request ids in outputs");
    assert_eq!(ids, (0..N_REQUESTS as u64).collect::<BTreeSet<u64>>());

    // Every request completed — preemption, not failure, resolved the
    // contention.
    for out in &outs {
        assert!(
            matches!(out.finish, FinishReason::Eos | FinishReason::Length),
            "request {} finished with {:?} instead of completing",
            out.id,
            out.finish
        );
        assert!(!out.generated.is_empty(), "request {} lost its output", out.id);
    }

    // The pool really was under pressure and preemptions really happened.
    assert!(eng.pool().oom_events() > 0, "pool never hit OOM — test is under-sized");
    let m = eng.sched_metrics();
    assert!(m.preemptions > 0, "no preemptions despite OOM pressure");
    assert_eq!(m.oom_failures, 0, "a request was failed instead of preempted");
    assert!(eng.last_run.preemptions > 0);

    // Accounting stayed balanced: everything was released.
    assert_eq!(eng.pool().in_use(), 0);
    assert!(eng.pool().peak() <= POOL_BYTES);
}

#[test]
fn preempted_requests_produce_identical_tokens() {
    // Preemption is restart-from-scratch, so a preempted-then-readmitted
    // request must emit exactly what it would have in a roomy pool.
    let mut eng = Engine::new(capped_cfg()).unwrap();
    let capped = eng.generate_batch(trace_requests());

    let mut roomy_cfg = capped_cfg();
    roomy_cfg.kv_pool_bytes = 0; // unlimited
    let mut roomy_eng = Engine::new(roomy_cfg).unwrap();
    let roomy = roomy_eng.generate_batch(trace_requests());

    assert!(eng.sched_metrics().preemptions > 0, "capped run never preempted");
    for (c, r) in capped.iter().zip(&roomy) {
        assert_eq!(c.id, r.id);
        assert_eq!(
            c.generated, r.generated,
            "request {}: preemption changed the generated tokens",
            c.id
        );
    }
}

#[test]
fn restart_mode_never_swaps() {
    // host_spill_bytes = 0 (the default) must reproduce the pre-swap
    // restart-from-scratch semantics exactly: preemptions happen, swap
    // counters stay zero, and the host tier is never touched.
    let mut eng = Engine::new(capped_cfg()).unwrap();
    let outs = eng.generate_batch(trace_requests());
    assert!(outs.iter().all(|o| matches!(o.finish, FinishReason::Eos | FinishReason::Length)));
    let m = eng.sched_metrics();
    assert!(m.preemptions > 0, "workload no longer preempts — resize it");
    assert_eq!(m.swap_outs, 0);
    assert_eq!(m.swap_ins, 0);
    assert_eq!(m.restarts_avoided, 0);
    assert_eq!(m.host_bytes_peak, 0);
    assert_eq!(eng.pool().peak_of(Tier::Host), 0);
}

#[test]
fn host_spill_resumes_all_requests_token_identically() {
    // The two-tier acceptance case: same capped device pool, but preempted
    // sequences suspend to a roomy host tier and swap back in. Everything
    // completes, restarts are avoided, and every resumed sequence's output
    // is byte-identical to an uninterrupted (unlimited-pool) run.
    let mut cfg = capped_cfg().with_host_spill(4 * 1024 * 1024);
    cfg.kv_pool_bytes = POOL_BYTES;
    let mut eng = Engine::new(cfg).unwrap();
    let outs = eng.generate_batch(trace_requests());

    assert_eq!(outs.len(), N_REQUESTS);
    let ids: BTreeSet<u64> = outs.iter().map(|o| o.id).collect();
    assert_eq!(ids, (0..N_REQUESTS as u64).collect::<BTreeSet<u64>>());
    for out in &outs {
        assert!(
            matches!(out.finish, FinishReason::Eos | FinishReason::Length),
            "request {} finished with {:?} instead of completing",
            out.id,
            out.finish
        );
        assert!(!out.generated.is_empty(), "request {} lost its output", out.id);
    }

    // Swap really happened: preemptions were served by suspend/resume, not
    // restart-from-scratch.
    let m = eng.sched_metrics();
    assert!(eng.pool().oom_events() > 0, "device pool never hit OOM — test is under-sized");
    assert!(m.preemptions > 0, "no preemptions despite OOM pressure");
    assert!(m.swap_outs > 0, "preemption never swapped out");
    assert!(m.swap_ins > 0, "no suspended sequence ever resumed");
    assert!(m.restarts_avoided > 0, "no restart was avoided");
    assert_eq!(m.oom_failures, 0, "a request was failed instead of suspended");
    assert!(m.host_bytes_peak > 0, "host peak not recorded");
    assert!(m.host_bytes_peak <= 4 * 1024 * 1024);

    // Byte-identical resume: compare with an unlimited-pool run that never
    // preempts (greedy sampling; the decode output is a pure function of
    // the cache, so a restored snapshot must continue identically).
    let mut roomy_cfg = capped_cfg();
    roomy_cfg.kv_pool_bytes = 0;
    let mut roomy_eng = Engine::new(roomy_cfg).unwrap();
    let roomy = roomy_eng.generate_batch(trace_requests());
    assert_eq!(roomy_eng.sched_metrics().preemptions, 0);
    for (c, r) in outs.iter().zip(&roomy) {
        assert_eq!(c.id, r.id);
        assert_eq!(
            c.generated, r.generated,
            "request {}: suspend/resume changed the generated tokens",
            c.id
        );
    }

    // Both tiers drained: accounting balanced across every migration.
    assert_eq!(eng.pool().in_use(), 0);
    assert_eq!(eng.pool().in_use_of(Tier::Host), 0);
    assert!(eng.pool().peak() <= POOL_BYTES);
    assert_eq!(eng.pool().peak_of(Tier::Host), m.host_bytes_peak);

    // Suspended time is observable in the queue-latency export.
    let hist = eng.queue_latency();
    assert_eq!(hist.len(), N_REQUESTS);
    assert!(hist.max() >= 0.0);
}

#[test]
fn tiny_host_tier_falls_back_to_restart() {
    // A host tier too small for any snapshot (1 KB < the ~131 KB a
    // sequence holds) must degrade gracefully: every preemption falls back
    // to restart-from-scratch and the workload still completes.
    let mut cfg = capped_cfg().with_host_spill(1024);
    cfg.kv_pool_bytes = POOL_BYTES;
    let mut eng = Engine::new(cfg).unwrap();
    let outs = eng.generate_batch(trace_requests());
    assert!(outs.iter().all(|o| matches!(o.finish, FinishReason::Eos | FinishReason::Length)));
    let m = eng.sched_metrics();
    assert!(m.preemptions > 0);
    assert_eq!(m.swap_outs, 0, "a snapshot cannot fit in a 1 KB host tier");
    assert_eq!(m.swap_ins, 0);
    assert!(eng.pool().oom_events_of(Tier::Host) > 0, "host tier never refused a swap");
    assert_eq!(eng.pool().in_use_of(Tier::Host), 0);
}

#[test]
fn paged_swap_traffic_is_page_granular_and_token_identical() {
    // The paged-allocator acceptance case: with 4 KiB pages (4 tokens per
    // page at sim://tiny's 1 KiB token rows), suspend/resume must (a) keep
    // greedy decode token-identical to an uninterrupted unlimited-pool run,
    // and (b) charge migration traffic of exactly page_bytes × pages moved
    // in both directions — swaps move page-table entries, not byte blobs.
    // (Admission parks, which create pages directly on the host tier, add
    // to `swap_outs` but move nothing, so they must not show up here.)
    const PAGE: usize = 4096;
    let mut cfg = capped_cfg().with_host_spill(4 * 1024 * 1024).with_kv_page_bytes(PAGE);
    cfg.kv_pool_bytes = POOL_BYTES;
    let mut eng = Engine::new(cfg).unwrap();
    let outs = eng.generate_batch(trace_requests());
    assert!(outs.iter().all(|o| matches!(o.finish, FinishReason::Eos | FinishReason::Length)));

    let m = eng.sched_metrics().clone();
    assert!(m.swap_outs > 0 && m.swap_ins > 0, "workload no longer swaps — resize it");
    assert!(m.pages_swapped_out > 0 && m.pages_swapped_in > 0);
    assert_eq!(
        eng.pool().migrated_into(Tier::Host),
        m.pages_swapped_out as usize * PAGE,
        "host-bound traffic must be page_bytes x pages_moved"
    );
    assert_eq!(
        eng.pool().migrated_into(Tier::Device),
        m.pages_swapped_in as usize * PAGE,
        "device-bound traffic must be page_bytes x pages_moved"
    );

    // Gauges drained with the pool, and no accounting fault was absorbed.
    assert_eq!(m.kv_alloc_bytes, 0);
    assert_eq!(m.host_alloc_bytes, 0);
    assert_eq!(m.accounting_errors, 0);
    assert_eq!(eng.pool().in_use(), 0);
    assert_eq!(eng.paged_pool().live_pages(), 0);

    // Greedy decode over the paged pool matches the unpaged-style baseline
    // (unlimited pool, default page size, no preemption) token for token.
    let mut roomy_cfg = capped_cfg();
    roomy_cfg.kv_pool_bytes = 0;
    let mut roomy_eng = Engine::new(roomy_cfg).unwrap();
    let roomy = roomy_eng.generate_batch(trace_requests());
    assert_eq!(roomy_eng.sched_metrics().preemptions, 0);
    for (c, r) in outs.iter().zip(&roomy) {
        assert_eq!(c.id, r.id);
        assert_eq!(c.generated, r.generated, "request {}: paging changed the tokens", c.id);
    }
}

#[test]
fn preemption_disabled_reproduces_hard_oom() {
    // With the paper-style hard-OOM mode, the same workload must fail some
    // requests instead of completing them all.
    let mut cfg = capped_cfg().with_preemption(false);
    cfg.kv_pool_bytes = POOL_BYTES;
    let mut eng = Engine::new(cfg).unwrap();
    let outs = eng.generate_batch(trace_requests());
    assert_eq!(outs.len(), N_REQUESTS);
    assert!(
        outs.iter().any(|o| o.finish == FinishReason::Oom),
        "hard-OOM mode unexpectedly completed everything"
    );
    assert_eq!(eng.sched_metrics().preemptions, 0);
    assert_eq!(eng.pool().in_use(), 0);
}
