//! OOM-preemption regression: a capacity-capped KV pool holding fewer
//! concurrent sequences than the scheduler admits must complete ALL
//! requests via preempt-and-requeue — nobody fails, nothing is lost or
//! duplicated, and the pool records real OOM pressure along the way.
//!
//! Sizing (sim://tiny: 8 layers x 128 f32 row elems = 1024 B per
//! token-layer): uniform budget 48 with prompt 16 admits at ~131 KB per
//! sequence but grows toward ~400 KB (budget+1 rows x 8 layers). A 600 KB
//! pool therefore admits several sequences and then runs out as they grow:
//! exactly the condition preemption must resolve. One sequence always fits
//! alone, so forward progress (oldest never preempted) guarantees
//! completion.

use std::collections::BTreeSet;

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{Engine, FinishReason, Request};
use squeezeattention::workload::TraceSpec;

const POOL_BYTES: usize = 600 * 1024;
const N_REQUESTS: usize = 6;
const PROMPT_LEN: usize = 16;
const MAX_NEW: usize = 48;

fn capped_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::new("sim://tiny")
        .with_budget(48)
        .with_squeeze(false); // uniform budgets -> predictable growth
    cfg.max_batch = 4;
    cfg.kv_pool_bytes = POOL_BYTES;
    cfg
}

fn trace_requests() -> Vec<Request> {
    TraceSpec::closed(N_REQUESTS, PROMPT_LEN, MAX_NEW, 31)
        .generate()
        .iter()
        .enumerate()
        .map(|(i, it)| Request::new(i as u64, it.sample.prompt.clone(), MAX_NEW))
        .collect()
}

#[test]
fn capped_pool_completes_all_requests_via_preemption() {
    let mut eng = Engine::new(capped_cfg()).unwrap();
    let outs = eng.generate_batch(trace_requests());

    // No lost or duplicated outputs.
    assert_eq!(outs.len(), N_REQUESTS);
    let ids: BTreeSet<u64> = outs.iter().map(|o| o.id).collect();
    assert_eq!(ids.len(), N_REQUESTS, "duplicate request ids in outputs");
    assert_eq!(ids, (0..N_REQUESTS as u64).collect::<BTreeSet<u64>>());

    // Every request completed — preemption, not failure, resolved the
    // contention.
    for out in &outs {
        assert!(
            matches!(out.finish, FinishReason::Eos | FinishReason::Length),
            "request {} finished with {:?} instead of completing",
            out.id,
            out.finish
        );
        assert!(!out.generated.is_empty(), "request {} lost its output", out.id);
    }

    // The pool really was under pressure and preemptions really happened.
    assert!(eng.pool().oom_events() > 0, "pool never hit OOM — test is under-sized");
    let m = eng.sched_metrics();
    assert!(m.preemptions > 0, "no preemptions despite OOM pressure");
    assert_eq!(m.oom_failures, 0, "a request was failed instead of preempted");
    assert!(eng.last_run.preemptions > 0);

    // Accounting stayed balanced: everything was released.
    assert_eq!(eng.pool().in_use(), 0);
    assert!(eng.pool().peak() <= POOL_BYTES);
}

#[test]
fn preempted_requests_produce_identical_tokens() {
    // Preemption is restart-from-scratch, so a preempted-then-readmitted
    // request must emit exactly what it would have in a roomy pool.
    let mut eng = Engine::new(capped_cfg()).unwrap();
    let capped = eng.generate_batch(trace_requests());

    let mut roomy_cfg = capped_cfg();
    roomy_cfg.kv_pool_bytes = 0; // unlimited
    let mut roomy_eng = Engine::new(roomy_cfg).unwrap();
    let roomy = roomy_eng.generate_batch(trace_requests());

    assert!(eng.sched_metrics().preemptions > 0, "capped run never preempted");
    for (c, r) in capped.iter().zip(&roomy) {
        assert_eq!(c.id, r.id);
        assert_eq!(
            c.generated, r.generated,
            "request {}: preemption changed the generated tokens",
            c.id
        );
    }
}

#[test]
fn preemption_disabled_reproduces_hard_oom() {
    // With the paper-style hard-OOM mode, the same workload must fail some
    // requests instead of completing them all.
    let mut cfg = capped_cfg().with_preemption(false);
    cfg.kv_pool_bytes = POOL_BYTES;
    let mut eng = Engine::new(cfg).unwrap();
    let outs = eng.generate_batch(trace_requests());
    assert_eq!(outs.len(), N_REQUESTS);
    assert!(
        outs.iter().any(|o| o.finish == FinishReason::Oom),
        "hard-OOM mode unexpectedly completed everything"
    );
    assert_eq!(eng.sched_metrics().preemptions, 0);
    assert_eq!(eng.pool().in_use(), 0);
}
