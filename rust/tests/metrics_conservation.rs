//! Metrics conservation + schema stability.
//!
//! The conservation identity under test: every submission is accounted for
//! exactly once at all times —
//!
//! ```text
//! submitted == completed + cancelled + deadline_exceeded + oom_failures
//!            + requests_failed + rejected + in_flight
//! ```
//!
//! where `in_flight = queued + running + suspended`. The identity must hold
//! *mid-drain* (not just at rest) across arbitrary interleavings of
//! submission bursts, queue-cap rejections, cancels, zero deadlines,
//! injected step faults (retry and retry-exhaustion paths), and
//! suspend/resume churn.
//!
//! The schema test pins `SchedulerMetrics::to_json`'s key set: renaming or
//! dropping a counter silently breaks the Prometheus exposition (scrapers
//! alert on series that stop existing), so it must fail a test instead.

use std::time::{Duration, Instant};

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{
    Engine, FinishReason, Request, RequestHandle, RequestOutput, RoutePolicy, Router,
};
use squeezeattention::metrics::SchedulerMetrics;
use squeezeattention::util::Json;
use squeezeattention::workload::{Task, TaskGen};

fn base_cfg() -> ServeConfig {
    ServeConfig::new("sim://tiny").with_budget(48).with_squeeze(false)
}

/// Assert the conservation identity right now (mid-drain or at rest).
fn assert_conserved(eng: &Engine, ctx: &str) {
    let m = eng.sched_metrics();
    let retired = m.completed
        + m.cancelled
        + m.deadline_exceeded
        + m.oom_failures
        + m.requests_failed
        + m.rejected;
    assert_eq!(
        m.submitted,
        retired + eng.in_flight() as u64,
        "conservation identity broken {ctx}: submitted={} retired={} in_flight={}",
        m.submitted,
        retired,
        eng.in_flight()
    );
}

#[test]
fn submitted_requests_are_conserved_across_chaos_interleavings() {
    for (seed, rate) in [(3u64, 0.0), (11, 0.15), (17, 0.35)] {
        let mut cfg = base_cfg().with_host_spill(4 * 1024 * 1024);
        cfg.queue_depth = 4; // small cap: the burst below must shed
        cfg.max_batch = 2; // small batch: admission stays contended
        cfg.max_retries = 1; // rate 0.35 should exhaust some budgets
        cfg.faults.step_error_rate = rate;
        cfg.faults.seed = seed;
        let mut eng = Engine::new(cfg).unwrap();
        let mut gen = TaskGen::new(seed);
        let mut handles: Vec<Option<RequestHandle>> = Vec::new();
        let mut outs: Vec<RequestOutput> = Vec::new();
        let mut rejected_at_submit = 0u64;

        for i in 0..16u64 {
            let mut req = Request::new(i, gen.sample(Task::Copy, 24).prompt, 12);
            if i % 5 == 3 {
                // Expires at the next lifecycle sweep (if not shed first).
                req.deadline = Some(Duration::from_millis(0));
            }
            let h = RequestHandle::attach(&mut req);
            match eng.submit(req) {
                Ok(()) => handles.push(Some(h)),
                Err(out) => {
                    assert_eq!(out.finish, FinishReason::Rejected, "queue-cap reject expected");
                    rejected_at_submit += 1;
                    outs.push(out);
                    handles.push(None);
                }
            }
            assert_conserved(&eng, &format!("after submit {i} (rate {rate})"));
            // No steps during the first 8 submissions: with queue_depth=4
            // the burst deterministically overflows the queue.
            if i >= 8 && i % 2 == 0 {
                outs.extend(eng.step().unwrap());
                assert_conserved(&eng, &format!("mid-drain after submit {i} (rate {rate})"));
            }
            if i == 10 {
                // Cancel churn mid-flight (some victims may already have
                // retired or been rejected — both must stay conserved).
                for j in [1usize, 6] {
                    if let Some(h) = &handles[j] {
                        h.cancel();
                    }
                }
            }
        }
        assert!(rejected_at_submit >= 1, "burst over queue_depth=4 never shed (rate {rate})");

        let mut steps = 0;
        while eng.has_work() {
            outs.extend(eng.step().unwrap());
            assert_conserved(&eng, &format!("mid-drain step {steps} (rate {rate})"));
            steps += 1;
            assert!(steps < 100_000, "engine did not drain at rate {rate}");
        }

        let m = eng.sched_metrics();
        assert_eq!(m.submitted, 16, "every submit() call counts once (rate {rate})");
        assert_eq!(outs.len(), 16, "terminal outputs lost or duplicated (rate {rate})");
        assert_eq!(eng.in_flight(), 0);
        assert_eq!(m.rejected, rejected_at_submit, "rejected counter diverged (rate {rate})");
        assert_conserved(&eng, &format!("at rest (rate {rate})"));
        if rate >= 0.35 {
            assert!(m.faults_injected > 0, "rate {rate} never injected a fault");
        }
    }
}

#[test]
fn scheduler_metrics_json_schema_is_stable() {
    let j = SchedulerMetrics::default().to_json();
    let Json::Obj(map) = &j else { panic!("SchedulerMetrics::to_json must be an object") };
    let keys: Vec<&str> = map.keys().map(|s| s.as_str()).collect();
    let mut expected = vec![
        "slots",
        "queue_depth",
        "queue_peak",
        "running",
        "peak_occupancy",
        "steps",
        "mean_occupancy",
        "submitted",
        "admitted",
        "deferred_admissions",
        "preemptions",
        "suspended",
        "swap_outs",
        "swap_ins",
        "restarts_avoided",
        "host_bytes_peak",
        "pages_swapped_out",
        "pages_swapped_in",
        "kv_alloc_bytes",
        "kv_used_bytes",
        "host_alloc_bytes",
        "host_used_bytes",
        "shared_pages",
        "cow_copies",
        "accounting_errors",
        "completed",
        "rejected",
        "oom_failures",
        "cancelled",
        "deadline_exceeded",
        "spec_steps",
        "spec_drafted",
        "spec_accepted",
        "spec_rollback_tokens",
        "spec_acceptance_rate",
        "spec_accepted_per_step",
        "spec_rollback_depth",
        "kv_bytes_copied",
        "gather_full_refills",
        "gather_incremental_appends",
        "scratch_retained_bytes",
        "scratch_tiers_evicted",
        "worker_errors",
        "requests_retried",
        "requests_failed",
        "requests_shed",
        "faults_injected",
        "worker_restarts",
    ];
    // Json objects are BTreeMaps, so compare as sorted sets: a rename shows
    // up as one key vanishing and another appearing.
    expected.sort_unstable();
    assert_eq!(
        keys, expected,
        "SchedulerMetrics::to_json key set changed — renames/drops break \
         Prometheus scrapers; update this snapshot only for deliberate \
         schema changes"
    );
}

#[test]
fn killed_worker_leaves_flight_dump_with_victim_spans() {
    let mut cfg = base_cfg();
    cfg.max_worker_restarts = 1;
    // Slow every decode call so the victim is reliably mid-decode.
    cfg.faults.latency_spike_ms = 2;
    cfg.faults.latency_spike_rate = 1.0;
    let router = Router::spawn(cfg, 1, RoutePolicy::RoundRobin).unwrap();
    let mut gen = TaskGen::new(51);
    let handle =
        router.submit_async(Request::new(77, gen.sample(Task::Copy, 40).prompt, 400)).unwrap();
    std::thread::sleep(Duration::from_millis(30));
    assert!(router.kill_worker(0), "worker queue refused the poison job");
    let out = handle.recv().expect("caller hung on a dead worker");
    assert_eq!(out.finish, FinishReason::WorkerError);

    // The death protocol must leave a structured crash dump behind.
    let deadline = Instant::now() + Duration::from_secs(10);
    let dump = loop {
        if let Some(d) = router.last_flight_dump(0) {
            break d;
        }
        assert!(Instant::now() < deadline, "no flight dump after worker death");
        std::thread::sleep(Duration::from_millis(5));
    };
    assert_eq!(dump.get("flight_recorder").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(dump.get("reason").and_then(|v| v.as_str()), Some("worker_death"));
    let spans = dump.get("spans").unwrap().as_arr().unwrap();
    assert!(!spans.is_empty(), "crash dump carries no spans");

    // The victim's spans are in the dump, recorded under its worker-local
    // ticket; the alias table maps the public id (77) to that ticket.
    let aliases = dump.get("aliases").unwrap().as_arr().unwrap();
    let local = aliases
        .iter()
        .find(|a| a.get("public").and_then(|v| v.as_usize()) == Some(77))
        .and_then(|a| a.get("local").and_then(|v| v.as_f64()))
        .expect("victim id missing from the dump's alias table");
    assert!(
        spans.iter().any(|s| s.get("id").and_then(|v| v.as_f64()) == Some(local)),
        "victim's spans missing from the crash dump"
    );
    // The live trace query resolves the public id through the same table.
    let t = router.trace_json(77);
    assert_eq!(t.get("found").and_then(|v| v.as_bool()), Some(true));
    assert!(!t.get("spans").unwrap().as_arr().unwrap().is_empty());
}
