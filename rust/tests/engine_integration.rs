//! Integration tests over the full engine stack on the simulated runtime
//! backend (`sim://tiny`), so they always run — no compiled artifacts
//! needed.
//!
//! All scenarios run inside ONE `#[test]` over ONE `Engine`, sharing the
//! runtime and swapping policy via `Engine::reconfigure` — which is also the
//! production path for policy sweeps (and, on the PJRT backend, the only
//! safe one: the PJRT CPU client in xla_extension 0.5.1 is not safe to
//! destroy and re-create within a process).

use squeezeattention::config::{PolicyKind, ServeConfig};
use squeezeattention::coordinator::{Engine, FinishReason, Request, RequestOutput};
use squeezeattention::model::tokenizer;
use squeezeattention::workload::{Task, TaskGen, TraceSpec};

const ARTIFACTS: &str = "sim://tiny";

fn base_cfg() -> ServeConfig {
    ServeConfig::new(ARTIFACTS).with_budget(48)
}

fn run(eng: &mut Engine, cfg: ServeConfig, reqs: Vec<Request>) -> Vec<RequestOutput> {
    eng.reconfigure(cfg).unwrap();
    eng.generate_batch(reqs)
}

fn trace_requests(n: usize, prompt_len: usize, max_new: usize, seed: u64) -> Vec<Request> {
    TraceSpec::closed(n, prompt_len, max_new, seed)
        .generate()
        .iter()
        .enumerate()
        .map(|(i, it)| Request::new(i as u64, it.sample.prompt.clone(), max_new))
        .collect()
}

#[test]
fn engine_integration_suite() {
    let mut eng = Engine::new(base_cfg()).expect("engine boots on the sim backend");

    scenario_batch_with_squeeze(&mut eng);
    scenario_baseline_uniform(&mut eng);
    scenario_full_cache_never_evicts(&mut eng);
    scenario_budgets_bound_cache(&mut eng);
    scenario_h2o_serves(&mut eng);
    scenario_oom_finish(&mut eng);
    scenario_oversized_prompt_rejected(&mut eng);
    scenario_continuous_batching(&mut eng);
    scenario_cosine_collection(&mut eng);
    scenario_deterministic_greedy(&mut eng);
    scenario_jnp_kernel_matches_pallas(&mut eng);
}

fn scenario_batch_with_squeeze(eng: &mut Engine) {
    let outs = run(
        eng,
        base_cfg().with_policy(PolicyKind::SlidingWindow),
        trace_requests(4, 96, 12, 7),
    );
    assert_eq!(outs.len(), 4);
    for out in &outs {
        assert!(matches!(out.finish, FinishReason::Eos | FinishReason::Length));
        assert!(!out.generated.is_empty());
        // Algorithm-1 conservation on the actual serving plan.
        let n_layer = out.plan.budgets.len();
        assert_eq!(out.plan.total(), n_layer * 48);
        assert!(out.generated.iter().all(|&t| (0..272).contains(&t)));
    }
    assert!(outs.iter().any(|o| o.plan.reallocated), "no request reallocated budgets");
    assert!(eng.last_run.evictions > 0, "sliding window never evicted");
    println!("OK scenario_batch_with_squeeze");
}

fn scenario_baseline_uniform(eng: &mut Engine) {
    let mut gen = TaskGen::new(3);
    let s = gen.sample(Task::Copy, 80);
    let outs = run(eng, base_cfg().with_squeeze(false), vec![Request::new(0, s.prompt, 8)]);
    let plan = &outs[0].plan;
    assert!(!plan.reallocated);
    assert!(plan.budgets.iter().all(|&b| b == plan.budgets[0]));
    println!("OK scenario_baseline_uniform");
}

fn scenario_full_cache_never_evicts(eng: &mut Engine) {
    let mut gen = TaskGen::new(5);
    let s = gen.sample(Task::Lm, 60);
    let plen = s.prompt.len();
    let outs = run(
        eng,
        base_cfg().with_policy(PolicyKind::Full),
        vec![Request::new(0, s.prompt, 10)],
    );
    assert_eq!(eng.last_run.evictions, 0);
    // The cache holds the prompt plus every *processed* token; the final
    // sampled token is returned but never fed back (request finished).
    let expected = plen + outs[0].generated.len() - 1;
    let n_layer = outs[0].plan.budgets.len();
    assert_eq!(outs[0].final_kv_tokens, expected * n_layer);
    println!("OK scenario_full_cache_never_evicts");
}

fn scenario_budgets_bound_cache(eng: &mut Engine) {
    let mut gen = TaskGen::new(11);
    let s = gen.sample(Task::Copy, 120);
    let outs = run(
        eng,
        base_cfg().with_policy(PolicyKind::StreamingLlm).with_budget(24),
        vec![Request::new(0, s.prompt, 16)],
    );
    let out = &outs[0];
    assert!(
        out.final_kv_tokens <= out.plan.total(),
        "cache {} exceeds plan {}",
        out.final_kv_tokens,
        out.plan.total()
    );
    assert!(out.peak_kv_bytes > 0);
    println!("OK scenario_budgets_bound_cache");
}

fn scenario_h2o_serves(eng: &mut Engine) {
    let outs = run(
        eng,
        base_cfg().with_policy(PolicyKind::H2o).with_budget(32),
        trace_requests(2, 100, 10, 13),
    );
    assert_eq!(outs.len(), 2);
    assert!(outs.iter().all(|o| !o.generated.is_empty()));
    assert!(eng.last_run.evictions > 0, "h2o at budget 32 over 100-token prompts must evict");
    println!("OK scenario_h2o_serves");
}

fn scenario_oom_finish(eng: &mut Engine) {
    let mut cfg = base_cfg().with_policy(PolicyKind::Full);
    cfg.kv_pool_bytes = 200_000; // a 96-token prompt at 8 layers ≈ 786 KB
    let mut gen = TaskGen::new(17);
    let s = gen.sample(Task::Copy, 96);
    let outs = run(eng, cfg, vec![Request::new(0, s.prompt, 8)]);
    assert_eq!(outs[0].finish, FinishReason::Oom);
    assert_eq!(eng.pool().in_use(), 0, "pool must be fully released");
    println!("OK scenario_oom_finish");
}

fn scenario_oversized_prompt_rejected(eng: &mut Engine) {
    let prompt = vec![tokenizer::BOS; 600]; // > largest prefill bucket (512)
    let outs = run(eng, base_cfg(), vec![Request::new(0, prompt, 4)]);
    assert_eq!(outs[0].finish, FinishReason::Rejected);
    println!("OK scenario_oversized_prompt_rejected");
}

fn scenario_continuous_batching(eng: &mut Engine) {
    let mut cfg = base_cfg();
    cfg.max_batch = 4;
    let outs = run(eng, cfg, trace_requests(7, 64, 6, 23));
    assert_eq!(outs.len(), 7);
    let mut ids: Vec<u64> = outs.iter().map(|o| o.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..7).collect::<Vec<u64>>());
    println!("OK scenario_continuous_batching");
}

fn scenario_cosine_collection(eng: &mut Engine) {
    eng.reconfigure(base_cfg()).unwrap();
    eng.enable_cosine_collection();
    let mut gen = TaskGen::new(29);
    let s = gen.sample(Task::Lookup, 90);
    let plen = s.prompt.len();
    eng.generate_batch(vec![Request::new(0, s.prompt, 4)]);
    let stats = eng.cosine_stats().unwrap();
    let means = stats.layer_means();
    assert_eq!(means.len(), 8);
    assert!(means.iter().all(|m| m.is_finite() && (-1.0..=1.01).contains(m)));
    let row = stats.heatmap_row(0);
    assert!(row.len() >= plen - 1);
    println!("OK scenario_cosine_collection");
}

fn scenario_deterministic_greedy(eng: &mut Engine) {
    let run_once = |eng: &mut Engine| {
        let mut gen = TaskGen::new(37);
        let s = gen.sample(Task::Copy, 72);
        run(eng, base_cfg(), vec![Request::new(0, s.prompt, 10)])[0].generated.clone()
    };
    let a = run_once(eng);
    let b = run_once(eng);
    assert_eq!(a, b);
    println!("OK scenario_deterministic_greedy");
}

/// Kernel ablation: the jnp-lowered decode/prefill artifacts must produce the
/// same greedy generations as the pallas-lowered ones (same math).
fn scenario_jnp_kernel_matches_pallas(eng: &mut Engine) {
    let manifest = eng.runtime().manifest.clone();
    if manifest.prefill_buckets("jnp").is_empty() {
        println!("SKIP scenario_jnp_kernel_matches_pallas (no jnp artifacts)");
        return;
    }
    let mut gen = TaskGen::new(41);
    let s = gen.sample(Task::Lookup, 200);
    let pallas_out = run(
        eng,
        base_cfg().with_budget(64),
        vec![Request::new(0, s.prompt.clone(), 8)],
    );
    // A second engine in the same process is fine on the sim backend (and on
    // PJRT as long as the first client stays alive — no destroy/re-create).
    let mut eng_jnp = Engine::new(base_cfg().with_budget(64).with_kernel("jnp"))
        .expect("jnp engine boots");
    let jnp_out = eng_jnp.generate_batch(vec![Request::new(0, s.prompt, 8)]);
    assert_eq!(pallas_out[0].generated, jnp_out[0].generated,
               "pallas vs jnp kernel generations diverged");
    println!("OK scenario_jnp_kernel_matches_pallas");
}
