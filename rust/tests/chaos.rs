//! Chaos suite: fault injection, containment, supervision, and load
//! shedding (on `sim://tiny`, so it always runs). The contract under test
//! is uniform across every fault class:
//!
//! * every request gets exactly one terminal event — no caller or
//!   subscriber ever hangs, no double completion;
//! * pool bytes (both tiers) return to baseline once the engine drains;
//! * a faulted-then-retried request that never exhausts its retry budget
//!   completes token-identically to a fault-free run (greedy decode is a
//!   pure function of cache + token + position, so both the suspend-resume
//!   and the restart-from-scratch retry paths preserve the output);
//! * a request whose retry budget is spent retires with `WorkerError`,
//!   keeping its partial generation;
//! * a killed worker's in-flight callers unblock with synthesized
//!   `WorkerError` terminals, the worker respawns (bounded by
//!   `max_worker_restarts`), and subsequent submits succeed;
//! * with the restart budget exhausted the worker stays dead: its snapshot
//!   exports `"healthy": false` and routing fails fast with
//!   `NoHealthyWorker` instead of stranding work;
//! * admission control sheds with `Overloaded` + a sane Retry-After hint
//!   while admitted requests still complete;
//! * dropping a `ReplyHandle` cancels the abandoned request server-side.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{
    Engine, FinishReason, Request, RequestHandle, RequestOutput, RouteError, RoutePolicy, Router,
};
use squeezeattention::kvcache::Tier;
use squeezeattention::workload::{Task, TaskGen};

const ARTIFACTS: &str = "sim://tiny";

fn base_cfg() -> ServeConfig {
    ServeConfig::new(ARTIFACTS).with_budget(48).with_squeeze(false)
}

fn drain(eng: &mut Engine) -> Vec<RequestOutput> {
    let mut outs = Vec::new();
    let mut steps = 0;
    while eng.has_work() {
        outs.extend(eng.step().unwrap());
        steps += 1;
        assert!(steps < 100_000, "engine did not drain under fault injection");
    }
    outs
}

fn is_success(f: FinishReason) -> bool {
    matches!(f, FinishReason::Eos | FinishReason::Length)
}

/// One arm of the fault-rate sweep: 8 requests with event handles, 2
/// cancelled mid-flight, suspend-capable retries, full drain. Returns the
/// outputs by id plus the number of faults actually injected.
fn run_fault_arm(rate: f64) -> (HashMap<u64, RequestOutput>, u64) {
    // Host spill on, so the retry path suspends (keeps progress) rather
    // than restarting — any step-error rate then converges.
    let mut cfg = base_cfg().with_host_spill(8 * 1024 * 1024);
    cfg.max_retries = 1_000; // nobody may hit the retry bound in this arm
    cfg.faults.step_error_rate = rate;
    if rate > 0.0 {
        cfg.faults.latency_spike_ms = 1;
        cfg.faults.latency_spike_rate = rate;
    }
    let mut eng = Engine::new(cfg).unwrap();
    let baseline = eng.pool().in_use();
    let mut gen = TaskGen::new(21);
    let mut handles = Vec::new();
    for i in 0..8u64 {
        let mut req = Request::new(i, gen.sample(Task::Copy, 40).prompt, 24);
        handles.push(RequestHandle::attach(&mut req));
        eng.submit(req).unwrap();
    }
    let mut outs = Vec::new();
    for _ in 0..3 {
        outs.extend(eng.step().unwrap());
    }
    // Cancel churn: two requests abandoned mid-decode, same ids every arm.
    handles[6].cancel();
    handles[7].cancel();
    outs.extend(drain(&mut eng));

    assert_eq!(outs.len(), 8, "terminal outputs lost or duplicated at rate {rate}");
    assert_eq!(eng.pool().in_use(), baseline, "device bytes leaked at rate {rate}");
    assert_eq!(eng.pool().in_use_of(Tier::Host), 0, "host bytes leaked at rate {rate}");
    for (i, h) in handles.iter().enumerate() {
        let terminals = h.events().try_iter().filter(|e| e.is_terminal()).count();
        assert_eq!(terminals, 1, "request {i} saw {terminals} terminal events at rate {rate}");
    }
    let injected = eng.sched_metrics().faults_injected;
    (outs.into_iter().map(|o| (o.id, o)).collect(), injected)
}

#[test]
fn fault_sweep_is_token_identical_to_fault_free_run() {
    let (reference, injected) = run_fault_arm(0.0);
    assert_eq!(injected, 0, "fault-free arm must not inject");
    assert!(is_success(reference[&0].finish));
    assert_eq!(reference[&6].finish, FinishReason::Cancelled);
    assert_eq!(reference[&7].finish, FinishReason::Cancelled);

    let mut total_injected = 0;
    for rate in [0.05, 0.25] {
        let (outs, injected) = run_fault_arm(rate);
        total_injected += injected;
        // The never-cancelled requests had 1000 retries — far more than any
        // arm consumes — so all must succeed, token-identically.
        for id in 0..6u64 {
            let (r, o) = (&reference[&id], &outs[&id]);
            assert!(is_success(o.finish), "request {id} failed at rate {rate}: {:?}", o.finish);
            assert_eq!(o.finish, r.finish, "finish diverged for {id} at rate {rate}");
            assert_eq!(
                o.generated, r.generated,
                "tokens diverged under injected faults for request {id} at rate {rate}"
            );
        }
        assert_eq!(outs[&6].finish, FinishReason::Cancelled);
        assert_eq!(outs[&7].finish, FinishReason::Cancelled);
    }
    // Deterministic given the seed; at these rates the sweep decides
    // hundreds of coin flips, so zero injections means the plan is dead.
    assert!(total_injected > 0, "faulted arms never injected anything");
}

#[test]
fn injected_oom_is_contained_and_restart_is_token_identical() {
    let mut gen = TaskGen::new(23);
    let prompts: Vec<Vec<i32>> = (0..4).map(|_| gen.sample(Task::Copy, 40).prompt).collect();

    // Fault-free reference for the restart-identity check.
    let mut clean = Engine::new(base_cfg()).unwrap();
    for (i, p) in prompts.iter().enumerate() {
        clean.submit(Request::new(i as u64, p.clone(), 16)).unwrap();
    }
    let mut want: Vec<RequestOutput> = drain(&mut clean);
    want.sort_by_key(|o| o.id);

    // No host tier: the contained error exercises restart-from-scratch.
    let mut cfg = base_cfg();
    cfg.max_retries = 2;
    cfg.faults.oom_at = 3; // decode call 3 fails, once
    let mut eng = Engine::new(cfg).unwrap();
    let baseline = eng.pool().in_use();
    for (i, p) in prompts.iter().enumerate() {
        eng.submit(Request::new(i as u64, p.clone(), 16)).unwrap();
    }
    let mut outs = drain(&mut eng);
    outs.sort_by_key(|o| o.id);

    assert_eq!(outs.len(), 4);
    for (o, w) in outs.iter().zip(&want) {
        assert!(is_success(o.finish), "retry did not recover: {:?}", o.finish);
        assert_eq!(o.generated, w.generated, "restarted request {} diverged", o.id);
    }
    let m = eng.sched_metrics();
    assert_eq!(m.faults_injected, 1);
    assert_eq!(m.worker_errors, 1, "one contained step error expected");
    assert!(m.requests_retried >= 1, "the failed batch must have been retried");
    assert_eq!(eng.pool().in_use(), baseline, "failed step leaked device bytes");
}

#[test]
fn exhausted_retry_budget_retires_with_worker_error() {
    let mut cfg = base_cfg();
    cfg.max_retries = 0;
    cfg.faults.oom_at = 2; // one clean step first, so partial output exists
    let mut eng = Engine::new(cfg).unwrap();
    let baseline = eng.pool().in_use();
    let mut gen = TaskGen::new(29);
    for i in 0..3u64 {
        eng.submit(Request::new(i, gen.sample(Task::Copy, 40).prompt, 16)).unwrap();
    }
    let outs = drain(&mut eng);

    assert_eq!(outs.len(), 3);
    let failed: Vec<&RequestOutput> =
        outs.iter().filter(|o| o.finish == FinishReason::WorkerError).collect();
    assert!(!failed.is_empty(), "no request retired with WorkerError");
    for o in &failed {
        assert!(!o.generated.is_empty(), "WorkerError dropped the partial generation");
    }
    assert!(outs.iter().all(|o| is_success(o.finish) || o.finish == FinishReason::WorkerError));
    let m = eng.sched_metrics();
    assert_eq!(m.worker_errors, 1);
    assert_eq!(m.requests_retried, 0, "retries must be off at max_retries = 0");
    assert_eq!(eng.pool().in_use(), baseline, "WorkerError retirement leaked device bytes");
}

#[test]
fn killed_worker_respawns_and_in_flight_callers_unblock() {
    let mut cfg = base_cfg();
    cfg.max_worker_restarts = 3;
    // Slow every decode call down so the victim is reliably mid-decode.
    cfg.faults.latency_spike_ms = 2;
    cfg.faults.latency_spike_rate = 1.0;
    let router = Router::spawn(cfg, 1, RoutePolicy::RoundRobin).unwrap();
    let mut gen = TaskGen::new(33);
    let prompt = gen.sample(Task::Copy, 40).prompt;

    let handle = router.submit_async(Request::new(7, prompt.clone(), 400)).unwrap();
    std::thread::sleep(Duration::from_millis(30));
    assert!(router.kill_worker(0), "worker queue refused the poison job");

    // The in-flight caller must unblock with a synthesized terminal.
    let out = handle.recv().expect("caller hung on a dead worker");
    assert_eq!(out.id, 7);
    assert_eq!(out.finish, FinishReason::WorkerError);

    // The supervisor respawns the worker; routing then works again.
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.worker_restarts() != 1 || router.worker_state(0) != Some("healthy") {
        assert!(
            Instant::now() < deadline,
            "worker never respawned: restarts={} state={:?}",
            router.worker_restarts(),
            router.worker_state(0)
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let out = router.submit(Request::new(8, prompt, 8)).unwrap();
    assert!(is_success(out.finish), "post-respawn submit failed: {:?}", out.finish);
    let j = router.metrics_json();
    assert_eq!(j.get("worker_restarts").unwrap().as_usize(), Some(1));
}

#[test]
fn dead_worker_without_restart_budget_is_unroutable_and_snapshot_says_so() {
    let mut cfg = base_cfg();
    cfg.max_worker_restarts = 0;
    let router = Router::spawn(cfg, 1, RoutePolicy::LeastLoaded).unwrap();
    assert_eq!(router.worker_state(0), Some("healthy"));
    assert!(router.kill_worker(0));

    // The snapshot must degrade to unhealthy/dead (the worker died holding
    // its metrics mutex — the poisoned-lock path) instead of panicking.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let snap = router.snapshots().remove(0);
        if !snap.healthy && snap.state == "dead" {
            break;
        }
        assert!(Instant::now() < deadline, "snapshot never marked the worker dead");
        std::thread::sleep(Duration::from_millis(5));
    }
    let mut gen = TaskGen::new(41);
    let err = router.submit(Request::new(1, gen.sample(Task::Copy, 40).prompt, 8)).unwrap_err();
    assert_eq!(err, RouteError::NoHealthyWorker, "routing to a dead fleet must fail fast");
}

#[test]
fn load_shedding_rejects_with_retry_hint_and_admitted_requests_complete() {
    let mut cfg = base_cfg();
    cfg.shed_queue_depth = 2;
    // Slow decode keeps the two admitted requests in flight for the burst.
    cfg.faults.latency_spike_ms = 1;
    cfg.faults.latency_spike_rate = 1.0;
    let router = Router::spawn(cfg, 1, RoutePolicy::RoundRobin).unwrap();
    let mut gen = TaskGen::new(37);
    let prompt = gen.sample(Task::Copy, 40).prompt;

    let mut admitted = Vec::new();
    let mut shed = 0usize;
    for i in 0..10u64 {
        match router.submit_async(Request::new(i, prompt.clone(), 48)) {
            Ok(h) => admitted.push(h),
            Err(RouteError::Overloaded { retry_after_ms }) => {
                assert!(
                    (50..=5000).contains(&retry_after_ms),
                    "retry hint out of range: {retry_after_ms}"
                );
                shed += 1;
            }
            Err(other) => panic!("unexpected route error: {other}"),
        }
    }
    assert!(admitted.len() >= 2, "queue-depth bound shed the whole burst");
    assert!(shed >= 1, "burst over the bound never shed");
    for h in &admitted {
        let out = h.recv().expect("admitted request never completed");
        assert!(is_success(out.finish), "admitted request failed: {:?}", out.finish);
    }
    assert_eq!(router.requests_shed() as usize, shed);
    let j = router.metrics_json();
    assert_eq!(j.get("requests_shed").unwrap().as_usize(), Some(shed));
}

#[test]
fn spawn_partial_failure_reports_failed_worker() {
    let mut cfg = base_cfg();
    cfg.faults.spawn_fail_worker = Some(1);
    let err = match Router::spawn(cfg, 3, RoutePolicy::RoundRobin) {
        Ok(_) => panic!("spawn must fail when a worker cannot start"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("worker 1"), "error does not name the failed worker: {msg}");
}

#[test]
fn dropped_reply_handle_cancels_abandoned_request() {
    let mut cfg = base_cfg();
    // Every decode call sleeps, so the 4000-token request is still decoding
    // when the handle is dropped, whatever the host speed.
    cfg.faults.latency_spike_ms = 1;
    cfg.faults.latency_spike_rate = 1.0;
    let router = Router::spawn(cfg, 1, RoutePolicy::RoundRobin).unwrap();
    let mut gen = TaskGen::new(43);
    let handle =
        router.submit_async(Request::new(5, gen.sample(Task::Copy, 40).prompt, 4000)).unwrap();
    std::thread::sleep(Duration::from_millis(20));
    drop(handle); // abandon the caller — must cancel server-side

    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let cancelled = router.sched_metrics().first().map_or(0, |m| m.cancelled);
        if cancelled >= 1 && router.inflight() == 0 {
            break;
        }
        assert!(Instant::now() < deadline, "abandoned request was never cancelled");
        std::thread::sleep(Duration::from_millis(10));
    }
}
