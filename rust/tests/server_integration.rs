//! TCP server integration: boot the router + server on an ephemeral port,
//! drive it over a real socket with the JSON-lines protocol. Runs on the
//! simulated backend, so it always executes.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{server, RoutePolicy, Router};
use squeezeattention::util::Json;
use squeezeattention::workload::{Task, TaskGen};

const ARTIFACTS: &str = "sim://tiny";

#[test]
fn tcp_roundtrip() {
    let cfg = ServeConfig::new(ARTIFACTS).with_budget(48);
    let router = std::sync::Arc::new(Router::spawn(cfg, 1, RoutePolicy::RoundRobin).unwrap());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = server::serve(listener, router);
    });

    let mut gen = TaskGen::new(0);
    let sample = gen.sample(Task::Lookup, 60);
    let prompt_json: Vec<String> = sample.prompt.iter().map(|t| t.to_string()).collect();

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // two pipelined requests on one connection
    for id in [1, 2] {
        writeln!(
            writer,
            "{{\"id\": {id}, \"prompt\": [{}], \"max_new_tokens\": 6}}",
            prompt_json.join(",")
        )
        .unwrap();
    }
    for expect_id in [1usize, 2] {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(expect_id));
        let generated = j.get("generated").unwrap().as_arr().unwrap();
        assert!(!generated.is_empty());
        assert!(j.get("total_s").unwrap().as_f64().unwrap() >= 0.0);
    }

    // malformed line -> error object, connection stays usable
    writeln!(writer, "{{nope").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(Json::parse(&line).unwrap().get("error").is_some());
}
