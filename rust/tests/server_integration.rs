//! TCP server integration: boot the router + server on an ephemeral port,
//! drive it over a real socket with the JSON-lines protocol. Runs on the
//! simulated backend, so it always executes.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{server, Request, RoutePolicy, Router};
use squeezeattention::util::Json;
use squeezeattention::workload::{Task, TaskGen};

const ARTIFACTS: &str = "sim://tiny";

#[test]
fn tcp_roundtrip() {
    let cfg = ServeConfig::new(ARTIFACTS).with_budget(48);
    let router = std::sync::Arc::new(Router::spawn(cfg, 1, RoutePolicy::RoundRobin).unwrap());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = server::serve(listener, router);
    });

    let mut gen = TaskGen::new(0);
    let sample = gen.sample(Task::Lookup, 60);
    let prompt_json: Vec<String> = sample.prompt.iter().map(|t| t.to_string()).collect();

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // two pipelined requests on one connection
    for id in [1, 2] {
        writeln!(
            writer,
            "{{\"id\": {id}, \"prompt\": [{}], \"max_new_tokens\": 6}}",
            prompt_json.join(",")
        )
        .unwrap();
    }
    for expect_id in [1usize, 2] {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(expect_id));
        let generated = j.get("generated").unwrap().as_arr().unwrap();
        assert!(!generated.is_empty());
        assert!(j.get("total_s").unwrap().as_f64().unwrap() >= 0.0);
    }

    // malformed line -> error object, connection stays usable
    writeln!(writer, "{{nope").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(Json::parse(&line).unwrap().get("error").is_some());
}

#[test]
fn batch_wait_joins_delayed_arrival_into_same_step() {
    // With batch_wait_ms, a worker forming a batch from idle holds its
    // first decode step until more arrivals show up (or the deadline
    // passes), so a request arriving shortly after the first one decodes
    // alongside it from step one. Pinned via the worker's scheduler
    // metrics: both sequences occupy every step, so the step count is that
    // of a single sequence (max_new - 1; the first token comes from
    // prefill) instead of roughly twice that for two back-to-back solo
    // runs.
    const MAX_NEW: usize = 24;
    let mut cfg = ServeConfig::new(ARTIFACTS).with_budget(48).with_batch_wait_ms(3000);
    cfg.max_batch = 2; // slot_count 2: the wait ends as soon as both arrive
    let router = Router::spawn(cfg, 1, RoutePolicy::RoundRobin).unwrap();

    let mut gen = TaskGen::new(1);
    let sample = gen.sample(Task::Copy, 40);
    let mk = |id: u64| Request::new(id, sample.prompt.clone(), MAX_NEW);
    let rx1 = router.submit_async(mk(1)).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(150));
    let rx2 = router.submit_async(mk(2)).unwrap();
    let o1 = rx1.recv().unwrap();
    let o2 = rx2.recv().unwrap();
    assert!(!o1.generated.is_empty());
    assert_eq!(o1.generated, o2.generated, "same prompt, same greedy tokens");

    let ms = router.sched_metrics();
    let m = &ms[0];
    assert_eq!(m.peak_occupancy, 2, "delayed arrival did not join the batch");
    assert_eq!(
        m.steps,
        (MAX_NEW - 1) as u64,
        "the two requests did not share every decode step"
    );
}
