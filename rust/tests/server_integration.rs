//! TCP server integration: boot the router + server on an ephemeral port,
//! drive it over a real socket with the JSON-lines protocol. Runs on the
//! simulated backend, so it always executes.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{server, Request, RoutePolicy, Router};
use squeezeattention::util::Json;
use squeezeattention::workload::{Task, TaskGen};

const ARTIFACTS: &str = "sim://tiny";

#[test]
fn tcp_roundtrip() {
    let cfg = ServeConfig::new(ARTIFACTS).with_budget(48);
    let router = std::sync::Arc::new(Router::spawn(cfg, 1, RoutePolicy::RoundRobin).unwrap());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = server::serve(listener, router);
    });

    let mut gen = TaskGen::new(0);
    let sample = gen.sample(Task::Lookup, 60);
    let prompt_json: Vec<String> = sample.prompt.iter().map(|t| t.to_string()).collect();

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // two pipelined requests on one connection
    for id in [1, 2] {
        writeln!(
            writer,
            "{{\"id\": {id}, \"prompt\": [{}], \"max_new_tokens\": 6}}",
            prompt_json.join(",")
        )
        .unwrap();
    }
    for expect_id in [1usize, 2] {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(expect_id));
        let generated = j.get("generated").unwrap().as_arr().unwrap();
        assert!(!generated.is_empty());
        assert!(j.get("total_s").unwrap().as_f64().unwrap() >= 0.0);
    }

    // malformed line -> error object, connection stays usable
    writeln!(writer, "{{nope").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(Json::parse(&line).unwrap().get("error").is_some());
}

#[test]
fn metrics_prom_and_trace_answer_over_live_socket() {
    let mut cfg = ServeConfig::new(ARTIFACTS).with_budget(48);
    // Slow every decode call so the request is observably in flight.
    cfg.faults.latency_spike_ms = 2;
    cfg.faults.latency_spike_rate = 1.0;
    let router = std::sync::Arc::new(Router::spawn(cfg, 1, RoutePolicy::RoundRobin).unwrap());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = server::serve(listener, router);
    });

    let mut gen = TaskGen::new(9);
    let prompt: Vec<String> =
        gen.sample(Task::Copy, 40).prompt.iter().map(|t| t.to_string()).collect();

    // Connection A: one long-running request.
    let stream_a = TcpStream::connect(addr).unwrap();
    let mut writer_a = stream_a.try_clone().unwrap();
    let mut reader_a = BufReader::new(stream_a);
    writeln!(writer_a, "{{\"id\": 1, \"prompt\": [{}], \"max_new_tokens\": 200}}", prompt.join(","))
        .unwrap();

    // Connection B: control lines, polled while A decodes.
    let stream_b = TcpStream::connect(addr).unwrap();
    let mut writer_b = stream_b.try_clone().unwrap();
    let mut reader_b = BufReader::new(stream_b);
    let mut query = |line: &str| -> Json {
        writeln!(writer_b, "{line}").unwrap();
        let mut buf = String::new();
        reader_b.read_line(&mut buf).unwrap();
        Json::parse(&buf).unwrap()
    };

    // Poll until the worker snapshot shows the active sequence's squeeze
    // table (stamped after each engine step), then check the budget
    // identity: per-sequence budgets sum to the sequence's plan total.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    let seqs = loop {
        let m = query("{\"metrics\": true}");
        let w0 = &m.get("workers").unwrap().as_arr().unwrap()[0];
        let seqs = w0.get("squeeze").and_then(|s| s.get("sequences")).and_then(|s| s.as_arr());
        if let Some(seqs) = seqs {
            if !seqs.is_empty() {
                break seqs.to_vec();
            }
        }
        assert!(std::time::Instant::now() < deadline, "squeeze table never showed a sequence");
        std::thread::sleep(std::time::Duration::from_millis(5));
    };
    for sq in &seqs {
        let total = sq.get("total_budget").unwrap().as_f64().unwrap();
        let budgets = sq.get("budgets").unwrap().as_arr().unwrap();
        let sum: f64 = budgets.iter().map(|b| b.as_f64().unwrap()).sum();
        assert_eq!(sum, total, "per-layer budgets do not sum to the plan total");
        assert!(!budgets.is_empty());
    }

    // Prometheus exposition: one wire line wrapping well-formed text 0.0.4.
    let prom = query("{\"metrics_prom\": true}");
    assert_eq!(prom.get("content_type").unwrap().as_str(), Some("text/plain; version=0.0.4"));
    let body = prom.get("body").unwrap().as_str().unwrap().to_string();
    assert!(
        squeezeattention::metrics::is_well_formed_prometheus(&body),
        "metrics_prom body is not valid Prometheus exposition:\n{body}"
    );
    for series in ["sa_sched_submitted", "sa_worker_up", "sa_layer_budget_rows", "sa_inflight"] {
        assert!(body.contains(series), "exposition missing series {series}:\n{body}");
    }

    // Drain the request, then its trace must resolve by public id.
    let mut line = String::new();
    reader_a.read_line(&mut line).unwrap();
    let out = Json::parse(&line).unwrap();
    assert_eq!(out.get("id").unwrap().as_usize(), Some(1));
    let t = query("{\"trace\": 1}");
    assert_eq!(t.get("found").and_then(|v| v.as_bool()), Some(true), "trace 1 not found: {t}");
    assert!(!t.get("spans").unwrap().as_arr().unwrap().is_empty());

    // Unknown worker index: flight_dump answers found=false, not an error.
    let fd = query("{\"flight_dump\": 0}");
    assert!(fd.get("found").is_some() || fd.get("flight_recorder").is_some());
}

#[test]
fn batch_wait_joins_delayed_arrival_into_same_step() {
    // With batch_wait_ms, a worker forming a batch from idle holds its
    // first decode step until more arrivals show up (or the deadline
    // passes), so a request arriving shortly after the first one decodes
    // alongside it from step one. Pinned via the worker's scheduler
    // metrics: both sequences occupy every step, so the step count is that
    // of a single sequence (max_new - 1; the first token comes from
    // prefill) instead of roughly twice that for two back-to-back solo
    // runs.
    const MAX_NEW: usize = 24;
    let mut cfg = ServeConfig::new(ARTIFACTS).with_budget(48).with_batch_wait_ms(3000);
    cfg.max_batch = 2; // slot_count 2: the wait ends as soon as both arrive
    let router = Router::spawn(cfg, 1, RoutePolicy::RoundRobin).unwrap();

    let mut gen = TaskGen::new(1);
    let sample = gen.sample(Task::Copy, 40);
    let mk = |id: u64| Request::new(id, sample.prompt.clone(), MAX_NEW);
    let rx1 = router.submit_async(mk(1)).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(150));
    let rx2 = router.submit_async(mk(2)).unwrap();
    let o1 = rx1.recv().unwrap();
    let o2 = rx2.recv().unwrap();
    assert!(!o1.generated.is_empty());
    assert_eq!(o1.generated, o2.generated, "same prompt, same greedy tokens");

    let ms = router.sched_metrics();
    let m = &ms[0];
    assert_eq!(m.peak_occupancy, 2, "delayed arrival did not join the batch");
    assert_eq!(
        m.steps,
        (MAX_NEW - 1) as u64,
        "the two requests did not share every decode step"
    );
}
