//! Scheduler-vs-static parity: with greedy sampling and an uncapped pool,
//! the step-driven continuous-batching path must produce token-identical
//! outputs to the closed-batch `generate_batch` path — each sequence's cache
//! evolution depends only on its own prompt and budget plan, never on what
//! it was co-scheduled with. Also proves that late requests join a running
//! batch mid-flight (the whole point of continuous batching).
//!
//! Runs on the simulated backend (`sim://tiny`): deterministic, artifact-
//! free, and with logits that genuinely depend on cache contents, so any
//! scheduling bug that corrupts a cache shows up as diverging tokens.

use std::collections::BTreeMap;

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{Engine, FinishReason, Request, RequestOutput};
use squeezeattention::workload::TraceSpec;

const ARTIFACTS: &str = "sim://tiny";

fn cfg() -> ServeConfig {
    ServeConfig::new(ARTIFACTS).with_budget(48)
}

fn requests(n: usize, prompt_len: usize, max_new: usize, seed: u64) -> Vec<Request> {
    TraceSpec::closed(n, prompt_len, max_new, seed)
        .generate()
        .iter()
        .enumerate()
        .map(|(i, it)| Request::new(i as u64, it.sample.prompt.clone(), max_new))
        .collect()
}

fn by_id(outs: Vec<RequestOutput>) -> BTreeMap<u64, RequestOutput> {
    outs.into_iter().map(|o| (o.id, o)).collect()
}

#[test]
fn continuous_batching_matches_static_generate_batch() {
    let reqs = requests(12, 96, 12, 7);

    // Static path: the closed-batch compatibility wrapper.
    let mut eng = Engine::new(cfg()).unwrap();
    let static_outs = by_id(eng.generate_batch(reqs.clone()));

    // Continuous path: same requests submitted in staggered waves across
    // explicit step() calls, so they join a batch already in flight.
    eng.reconfigure(cfg()).unwrap();
    let mut outs: Vec<RequestOutput> = Vec::new();
    let mut pending = reqs.clone().into_iter();
    for req in pending.by_ref().take(3) {
        eng.submit(req).expect("no backpressure expected");
    }
    outs.extend(eng.step().unwrap());
    for req in pending.by_ref().take(5) {
        eng.submit(req).expect("no backpressure expected");
    }
    outs.extend(eng.step().unwrap());
    outs.extend(eng.step().unwrap());
    for req in pending {
        eng.submit(req).expect("no backpressure expected");
    }
    outs.extend(eng.drain());
    let continuous_outs = by_id(outs);

    assert_eq!(static_outs.len(), 12);
    assert_eq!(continuous_outs.len(), 12, "an output was lost or duplicated");
    for id in 0..12u64 {
        let s = &static_outs[&id];
        let c = &continuous_outs[&id];
        assert!(
            matches!(s.finish, FinishReason::Eos | FinishReason::Length),
            "request {id} static finish {:?}",
            s.finish
        );
        assert_eq!(s.finish, c.finish, "request {id} finish reason diverged");
        assert_eq!(
            s.generated, c.generated,
            "request {id}: continuous batching changed the generated tokens"
        );
        assert_eq!(s.plan.budgets, c.plan.budgets, "request {id} budget plan diverged");
    }
    assert!(eng.pool().in_use() == 0, "pool not fully released");
}

#[test]
fn late_requests_join_running_batch() {
    let mut c = cfg();
    c.max_batch = 4;
    let mut eng = Engine::new(c).unwrap();
    let reqs = requests(4, 80, 24, 23);

    // First wave: two long-running requests.
    eng.submit(reqs[0].clone()).unwrap();
    eng.submit(reqs[1].clone()).unwrap();
    let mut outs = Vec::new();
    for _ in 0..3 {
        outs.extend(eng.step().unwrap());
    }
    assert!(outs.is_empty(), "first wave finished before the second arrived");
    assert_eq!(eng.sched_metrics().running, 2);

    // Second wave arrives mid-flight and must join the SAME running batch.
    eng.submit(reqs[2].clone()).unwrap();
    eng.submit(reqs[3].clone()).unwrap();
    outs.extend(eng.step().unwrap());
    let m = eng.sched_metrics();
    assert_eq!(m.running, 4, "late requests did not join the running batch");
    assert_eq!(m.peak_occupancy, 4);
    assert_eq!(m.admitted, 4);

    outs.extend(eng.drain());
    let joined = by_id(outs);
    assert_eq!(joined.len(), 4);

    // Joining an in-flight batch must not change anyone's tokens: compare
    // every request against its solo closed-batch run.
    for (id, req) in reqs.iter().enumerate() {
        let mut solo_cfg = cfg();
        solo_cfg.max_batch = 4;
        let mut solo_eng = Engine::new(solo_cfg).unwrap();
        let solo = solo_eng.generate_batch(vec![req.clone()]);
        assert_eq!(
            solo[0].generated, joined[&(id as u64)].generated,
            "request {id}: joining a running batch changed its tokens"
        );
    }

    // Occupancy accounting: 2 slots for 3 steps, then 4.
    let m = eng.sched_metrics();
    assert!(m.steps >= 4);
    assert!(m.mean_occupancy() > 1.0);
    assert!(m.batch_utilization() <= 1.0);
}
