//! Batch-resident scratch acceptance suite.
//!
//! The resident gather path (`ServeConfig::resident_scratch`, the default)
//! must be an invisible optimization: under every eviction policy, with and
//! without speculative decoding, and across suspend/resume preemption
//! cycles, the generated tokens must be byte-identical to the always-refill
//! baseline (`with_resident_scratch(false)`). The exact-accounting
//! regression pins the structural win itself: a steady-state decode step
//! copies O(rows appended) bytes, not O(cache size).

use squeezeattention::config::{PolicyKind, ServeConfig};
use squeezeattention::coordinator::{Engine, FinishReason, Request};
use squeezeattention::workload::TraceSpec;

const PROMPT_LEN: usize = 80;
const MAX_NEW: usize = 32;
const N_REQUESTS: usize = 8;

fn requests(n: usize, prompt_len: usize, max_new: usize, seed: u64) -> Vec<Request> {
    TraceSpec::closed(n, prompt_len, max_new, seed)
        .generate()
        .iter()
        .enumerate()
        .map(|(i, it)| Request::new(i as u64, it.sample.prompt.clone(), max_new))
        .collect()
}

/// Run one closed batch and return (outputs, engine) for metric inspection.
fn run(cfg: ServeConfig) -> (Vec<squeezeattention::coordinator::RequestOutput>, Engine) {
    let mut eng = Engine::new(cfg).unwrap();
    let outs = eng.generate_batch(requests(N_REQUESTS, PROMPT_LEN, MAX_NEW, 53));
    (outs, eng)
}

#[test]
fn resident_matches_refill_across_policies_and_spec_depths() {
    for policy in PolicyKind::ALL {
        for spec_k in [0usize, 4] {
            let cfg = ServeConfig::new("sim://tiny")
                .with_policy(policy)
                .with_budget(48)
                .with_spec_k(spec_k);
            let (resident, eng) = run(cfg.clone());
            let (refill, _) = run(cfg.with_resident_scratch(false));
            assert_eq!(resident.len(), refill.len());
            for (a, b) in resident.iter().zip(&refill) {
                assert_eq!(a.id, b.id);
                assert_eq!(
                    a.generated, b.generated,
                    "policy {} spec_k {spec_k}: resident scratch changed request {}'s tokens",
                    policy.name(),
                    a.id
                );
                assert_eq!(a.finish, b.finish);
            }
            // The resident run must actually exercise the incremental path
            // somewhere (the Full arms decode steadily; eviction-heavy arms
            // still get incremental steps between evictions at spec_k 0 —
            // but never require it: the contract is correctness first).
            let m = eng.sched_metrics();
            assert!(
                m.gather_full_refills + m.gather_incremental_appends > 0,
                "gather counters never moved"
            );
        }
    }
}

#[test]
fn resident_matches_refill_through_suspend_resume_cycles() {
    // The oom_preemption sizing: a 600 KB device pool under uniform budget
    // 48 forces preemption, and a roomy host tier turns every preemption
    // into a suspend/resume cycle — each of which must invalidate slot
    // residency and still decode token-identically.
    let capped = |resident: bool| {
        let mut cfg = ServeConfig::new("sim://tiny")
            .with_budget(48)
            .with_squeeze(false)
            .with_host_spill(4 * 1024 * 1024)
            .with_resident_scratch(resident);
        cfg.max_batch = 4;
        cfg.kv_pool_bytes = 600 * 1024;
        cfg
    };
    let reqs = || {
        TraceSpec::closed(6, 16, 48, 31)
            .generate()
            .iter()
            .enumerate()
            .map(|(i, it)| Request::new(i as u64, it.sample.prompt.clone(), 48))
            .collect::<Vec<Request>>()
    };
    let mut eng_res = Engine::new(capped(true)).unwrap();
    let resident = eng_res.generate_batch(reqs());
    let mut eng_ref = Engine::new(capped(false)).unwrap();
    let refill = eng_ref.generate_batch(reqs());

    for eng in [&eng_res, &eng_ref] {
        let m = eng.sched_metrics();
        assert!(m.preemptions > 0, "workload no longer preempts — resize it");
        assert!(m.swap_ins > 0, "no suspend/resume cycle happened");
    }
    assert_eq!(resident.len(), refill.len());
    for (a, b) in resident.iter().zip(&refill) {
        assert_eq!(a.id, b.id);
        assert!(matches!(a.finish, FinishReason::Eos | FinishReason::Length));
        assert_eq!(
            a.generated, b.generated,
            "request {}: resident scratch changed tokens across suspend/resume",
            a.id
        );
    }
}

#[test]
fn steady_state_step_copies_rows_appended_not_cache_size() {
    // Exact accounting on sim://tiny (1024 B per token-layer row): one Full
    // policy sequence with a 40-token prompt refills its slot once —
    // 40 rows x 8 layers — and every later step appends exactly 8 rows
    // (one per layer), independent of how large the cache has grown.
    const TOKEN_BYTES: u64 = 1024;
    const N_LAYER: u64 = 8;
    const PROMPT: usize = 40;
    let cfg = ServeConfig::new("sim://tiny").with_policy(PolicyKind::Full);
    let mut eng = Engine::new(cfg).unwrap();
    let outs = eng.generate_batch(requests(1, PROMPT, 16, 7));
    assert_eq!(outs.len(), 1);
    assert!(matches!(outs[0].finish, FinishReason::Eos | FinishReason::Length));

    let steps = eng.last_run.decode_steps;
    assert!(steps > 1, "need steady-state steps to measure");
    let m = eng.sched_metrics();
    assert_eq!(m.gather_full_refills, 1, "exactly one refill: the slot's first gather");
    assert_eq!(
        m.gather_incremental_appends,
        steps - 1,
        "every later step must take the incremental path"
    );
    assert_eq!(
        m.kv_bytes_copied,
        (PROMPT as u64 * N_LAYER + (steps - 1) * N_LAYER) * TOKEN_BYTES,
        "steady-state step cost must be rows-appended, not cache-size"
    );

    // The always-refill baseline re-copies the whole growing cache each
    // step; the resident path must undercut it by a wide margin even on
    // this short run.
    let mut base =
        Engine::new(ServeConfig::new("sim://tiny")
            .with_policy(PolicyKind::Full)
            .with_resident_scratch(false))
        .unwrap();
    let base_outs = base.generate_batch(requests(1, PROMPT, 16, 7));
    assert_eq!(outs[0].generated, base_outs[0].generated);
    let bm = base.sched_metrics();
    assert_eq!(bm.gather_incremental_appends, 0);
    assert_eq!(bm.gather_full_refills, steps);
    assert!(
        m.kv_bytes_copied * 4 < bm.kv_bytes_copied,
        "resident copied {} B, refill {} B — expected a >4x gap",
        m.kv_bytes_copied,
        bm.kv_bytes_copied
    );
}

#[test]
fn gather_counters_reset_per_closed_batch() {
    // generate_batch resets the gather counters with the run stats, so
    // bytes-copied/step is well-defined per batch even on a reused engine.
    let cfg = ServeConfig::new("sim://tiny").with_policy(PolicyKind::Full);
    let mut eng = Engine::new(cfg).unwrap();
    let first = eng.generate_batch(requests(1, 40, 16, 7));
    let copied_first = eng.sched_metrics().kv_bytes_copied;
    let second = eng.generate_batch(requests(1, 40, 16, 7));
    let copied_second = eng.sched_metrics().kv_bytes_copied;
    assert_eq!(first[0].generated, second[0].generated);
    // The second batch lands in a new slot sequence ordinal, so its first
    // gather is a full refill too — identical accounting, not accumulation.
    assert_eq!(copied_first, copied_second);
}
