//! Figure 3 + Table 2 reproduction: model accuracy vs KV budget (10–100% of
//! prompt length), best sequence-wise baseline with and without
//! SqueezeAttention, plus the Full Cache reference.
//!
//! Output: reports/fig3_<task>.csv, one row per budget point, and the
//! Table-2 summary (min budget reaching within 5% of Full Cache accuracy).
//! Expected shape: the +Squeeze curve dominates the uniform-baseline curve at
//! equal budget, so its Table-2 budget is lower. SA_QUICK=1 shrinks the sweep.

use squeezeattention::config::{PolicyKind, ServeConfig};
use squeezeattention::coordinator::Engine;
use squeezeattention::util::bench::Table;
use squeezeattention::workload::{best_baseline_for, evaluate, EvalSpec, ALL_TASKS};

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        eprintln!("SKIP bench_accuracy_sweep: run `make artifacts` first");
        return Ok(());
    }
    let quick = std::env::var("SA_QUICK").is_ok();
    let budgets: Vec<f64> =
        if quick { vec![0.2, 0.5] } else { vec![0.1, 0.2, 0.3, 0.5, 0.75, 1.0] };
    let n_req = if quick { 3 } else { 5 };
    let prompt_len = 160;
    let max_new = 40;

    let mut eng = Engine::new(ServeConfig::new("artifacts/tiny"))?;
    let mut table2 = Table::new(&[
        "task", "best_baseline", "full_acc", "squeeze_acc@best", "squeeze_budget",
        "baseline_acc@best", "baseline_budget",
    ]);

    for task in ALL_TASKS {
        let spec = EvalSpec::new(task, n_req, prompt_len, max_new, 77);
        let policy = best_baseline_for(task);

        let full = evaluate(
            &mut eng,
            ServeConfig::new("artifacts/tiny").with_policy(PolicyKind::Full),
            &spec,
        )?;
        println!(
            "\n== task {} (best baseline: {}) full-cache acc={:.3} ==",
            task.name(),
            policy.name(),
            full.accuracy
        );

        let mut csv = Table::new(&[
            "budget_frac", "baseline_acc", "squeeze_acc", "full_acc",
            "baseline_kv_tokens", "squeeze_kv_tokens",
        ]);
        let mut curves: Vec<(f64, f64, f64)> = Vec::new();
        for &frac in &budgets {
            let base_cfg = ServeConfig::new("artifacts/tiny")
                .with_policy(policy)
                .with_budget_frac(frac)
                .with_squeeze(false);
            let sq_cfg = base_cfg.clone().with_squeeze(true);
            let base = evaluate(&mut eng, base_cfg, &spec)?;
            let sq = evaluate(&mut eng, sq_cfg, &spec)?;
            println!(
                "  budget {:>4.0}%  baseline {:.3}  +squeeze {:.3}   (kv tokens {:.0} vs {:.0})",
                frac * 100.0,
                base.accuracy,
                sq.accuracy,
                base.mean_kv_tokens,
                sq.mean_kv_tokens
            );
            csv.row(vec![
                format!("{frac}"),
                format!("{:.4}", base.accuracy),
                format!("{:.4}", sq.accuracy),
                format!("{:.4}", full.accuracy),
                format!("{:.0}", base.mean_kv_tokens),
                format!("{:.0}", sq.mean_kv_tokens),
            ]);
            curves.push((frac, base.accuracy, sq.accuracy));
        }
        csv.write_csv(&format!("reports/fig3_{}.csv", task.name()))?;

        // Table 2: min budget whose accuracy >= full - 5% (absolute).
        let target = full.accuracy - 0.05;
        let min_budget = |select: &dyn Fn(&(f64, f64, f64)) -> f64| {
            curves
                .iter()
                .filter(|c| select(c) >= target)
                .map(|c| c.0)
                .fold(f64::NAN, |acc, x| if acc.is_nan() { x } else { acc.min(x) })
        };
        let bb = min_budget(&|c: &(f64, f64, f64)| c.1);
        let sb = min_budget(&|c: &(f64, f64, f64)| c.2);
        let acc_at = |frac: f64, select: &dyn Fn(&(f64, f64, f64)) -> f64| {
            curves.iter().find(|c| c.0 == frac).map(select).unwrap_or(f64::NAN)
        };
        table2.row(vec![
            task.name().into(),
            policy.name().into(),
            format!("{:.3}", full.accuracy),
            if sb.is_nan() { "n/a".into() } else { format!("{:.3}", acc_at(sb, &|c| c.2)) },
            if sb.is_nan() { "n/a".into() } else { format!("{:.0}%", sb * 100.0) },
            if bb.is_nan() { "n/a".into() } else { format!("{:.3}", acc_at(bb, &|c| c.1)) },
            if bb.is_nan() { "n/a".into() } else { format!("{:.0}%", bb * 100.0) },
        ]);
    }

    println!("\nTable 2 — budget required to (approximately) match Full Cache:");
    table2.print();
    table2.write_csv("reports/table2.csv")?;
    Ok(())
}
