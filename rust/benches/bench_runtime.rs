//! Runtime micro-benchmarks — the §Perf instrumentation for Layer 3.
//!
//! Measures each stage of the decode hot path in isolation so the perf pass
//! can attribute wall time: prefill per bucket, decode step per tier, host
//! batch assembly (write_into_batch), eviction + compaction, and the H2O
//! score fold. Also reports the Runtime's cumulative h2d/d2h split.

use squeezeattention::config::ServeConfig;
use squeezeattention::kvcache::{H2o, EvictionPolicy, SequenceCache};
use squeezeattention::runtime::{Runtime, Tensor, TensorI32};
use squeezeattention::util::bench::{bench, fmt_duration, Table};
use squeezeattention::util::Rng;
use squeezeattention::workload::{Task, TaskGen};

fn main() -> anyhow::Result<()> {
    // -------- host-side pieces (no artifacts needed) -----------------------
    println!("host-side hot-path pieces:");
    let row = 128usize; // tiny model: 4 heads x 32
    let n_layer = 8;
    let mut rng = Rng::seed_from_u64(1);
    let mut cache = SequenceCache::new(n_layer, row);
    let krow: Vec<f32> = (0..row).map(|_| rng.f64() as f32).collect();
    for l in 0..n_layer {
        for p in 0..160 {
            cache.append(l, &krow, &krow, p as u32)?;
        }
    }
    let (b, m) = (8usize, 192usize);
    let mut k_buf = Tensor::zeros(&[n_layer, b, m, 4, 32]);
    let mut v_buf = Tensor::zeros(&[n_layer, b, m, 4, 32]);
    let mut lens = vec![0i32; n_layer * b];
    bench("write_into_batch 8L x160tok", 5, 200, || {
        cache.write_into_batch(&mut k_buf, &mut v_buf, &mut lens, 3).unwrap();
    });

    let policy = H2o::new(0.5);
    bench("h2o keep-set 160->64", 5, 500, || {
        std::hint::black_box(policy.keep(&cache.layers[0].meta, 64));
    });
    let keep: Vec<usize> = (96..160).collect();
    bench("retain/compact 160->64 x8 layers", 5, 100, || {
        let mut c = cache.clone();
        for l in 0..n_layer {
            c.retain(l, &keep).unwrap();
        }
    });
    let scores: Vec<f32> = (0..160).map(|_| rng.f64() as f32).collect();
    bench("add_scores 160 slots x8 layers", 5, 500, || {
        let mut c = cache.clone();
        for l in 0..n_layer {
            c.add_scores(l, &scores).unwrap();
        }
    });

    // -------- XLA execution per shape tier ---------------------------------
    if !std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        eprintln!("SKIP runtime half: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::load("artifacts/tiny", "pallas")?;
    let mut gen = TaskGen::new(3);
    let mut table = Table::new(&["stage", "mean", "min"]);

    for bucket in rt.manifest.prefill_buckets("pallas") {
        let s = gen.sample(Task::Lm, bucket - 8);
        let prompt = s.prompt.clone();
        let st = bench(&format!("prefill bucket {bucket}"), 1, 5, || {
            std::hint::black_box(rt.prefill(&prompt).unwrap());
        });
        table.row(vec![st.name.clone(), fmt_duration(st.mean_s), fmt_duration(st.min_s)]);
    }

    let n_layer = rt.manifest.model.n_layer;
    let (h, d) = (rt.manifest.model.n_head, rt.manifest.model.head_dim);
    for tier in rt.manifest.decode_tiers("pallas") {
        let (tb, tm) = tier;
        if tb > 8 {
            continue; // keep default run short; b16 covered by SA_ALL_TIERS
        }
        let tokens = TensorI32::from_vec(&[tb], vec![7; tb])?;
        let positions = TensorI32::from_vec(&[tb], vec![100; tb])?;
        let k_cache = Tensor::zeros(&[n_layer, tb, tm, h, d]);
        let v_cache = Tensor::zeros(&[n_layer, tb, tm, h, d]);
        let lens = TensorI32::from_vec(&[n_layer, tb], vec![100; n_layer * tb])?;
        let st = bench(&format!("decode tier b{tb} m{tm}"), 1, 5, || {
            std::hint::black_box(
                rt.decode(tier, &tokens, &positions, &k_cache, &v_cache, &lens).unwrap(),
            );
        });
        table.row(vec![st.name.clone(), fmt_duration(st.mean_s), fmt_duration(st.min_s)]);
    }

    let stats = rt.stats();
    println!(
        "\ncumulative runtime split: compile {:.2}s | h2d {:.2}s | d2h {:.2}s | prefill {:.2}s | decode {:.2}s",
        stats.compile_secs, stats.h2d_secs, stats.d2h_secs, stats.prefill_secs, stats.decode_secs
    );

    // -------- kernel ablation: pallas-lowered HLO vs plain-jnp HLO ---------
    // (same math — engine_integration asserts identical generations; here we
    // compare the CPU execution cost of the two lowerings.)
    if !rt.manifest.decode_tiers("jnp").is_empty() {
        println!("\nkernel ablation (same shapes, pallas- vs jnp-lowered HLO):");
        let rt2 = Runtime::load("artifacts/tiny", "jnp")?;
        for (label, r) in [("pallas", &rt), ("jnp", &rt2)] {
            let tier = (8usize, 192usize);
            if r.manifest.find_decode(label, tier.0, tier.1).is_err() {
                continue;
            }
            let tokens = TensorI32::from_vec(&[8], vec![7; 8])?;
            let positions = TensorI32::from_vec(&[8], vec![100; 8])?;
            let k_cache = Tensor::zeros(&[n_layer, 8, 192, h, d]);
            let v_cache = Tensor::zeros(&[n_layer, 8, 192, h, d]);
            let lens = TensorI32::from_vec(&[n_layer, 8], vec![100; n_layer * 8])?;
            let st = bench(&format!("decode b8 m192 [{label}]"), 1, 5, || {
                std::hint::black_box(
                    r.decode(tier, &tokens, &positions, &k_cache, &v_cache, &lens).unwrap(),
                );
            });
            table.row(vec![st.name.clone(), fmt_duration(st.mean_s), fmt_duration(st.min_s)]);
        }
    }
    table.write_csv("reports/runtime_micro.csv")?;
    Ok(())
}
