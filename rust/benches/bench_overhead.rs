//! Table 4 + Table 5 reproduction: the one-time cost of SqueezeAttention.
//!
//! Table 4: prefill wall time with vs without the squeeze bookkeeping
//! (cosine-stat reduction + k-means + reallocation happen at admission).
//! Table 5: micro-breakdown of the two host-side operations.
//! Expected shape: overhead is a few percent of prefill, and the host ops
//! are microseconds — a one-time price per request.

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{Engine, Request};
use squeezeattention::squeeze::{allocate, kmeans_1d, cosine, CosineStats};
use squeezeattention::util::bench::{bench, fmt_duration, Table};
use squeezeattention::util::Rng;
use squeezeattention::workload::{Task, TaskGen};

fn main() -> anyhow::Result<()> {
    // ---------------- Table 5: host-op micro-benches ----------------------
    println!("Table 5 — host-side op costs:");
    let mut rng = Rng::seed_from_u64(0);
    // cosine over two 4096-dim vectors x 32 layers (paper's Mistral shape)
    let a: Vec<f32> = (0..4096).map(|_| rng.f64() as f32).collect();
    let b: Vec<f32> = (0..4096).map(|_| rng.f64() as f32).collect();
    let s_cos = bench("cosine 4096-dim x32 layers", 3, 30, || {
        for _ in 0..32 {
            std::hint::black_box(cosine(&a, &b));
        }
    });
    // kmeans of 32 layer means into 3 groups
    let means: Vec<f64> = (0..32).map(|_| rng.f64()).collect();
    let s_km = bench("kmeans 32 values k=3", 3, 200, || {
        std::hint::black_box(kmeans_1d(&means, 3, 100));
    });
    // full Algorithm-1 allocation
    let cfg = squeezeattention::config::SqueezeConfig::default();
    let s_alloc = bench("allocate (Algorithm 1)", 3, 200, || {
        std::hint::black_box(allocate(&means, 1000, &cfg));
    });
    // CosineStats reduction of a [32, 512] probe tensor
    let probe = squeezeattention::runtime::Tensor::from_vec(
        &[32, 512],
        (0..32 * 512).map(|i| (i % 97) as f32 / 97.0).collect(),
    )?;
    let s_stats = bench("CosineStats.observe 32x512", 3, 100, || {
        let mut st = CosineStats::new(32);
        st.observe(&probe, 512);
        std::hint::black_box(st.layer_means());
    });
    let mut t5 = Table::new(&["op", "mean"]);
    for s in [&s_cos, &s_km, &s_alloc, &s_stats] {
        t5.row(vec![s.name.clone(), fmt_duration(s.mean_s)]);
    }
    t5.print();
    t5.write_csv("reports/table5.csv")?;

    // ---------------- Table 4: prefill ± squeeze --------------------------
    if !std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        eprintln!("SKIP Table 4 half: run `make artifacts` first");
        return Ok(());
    }
    let n = std::env::var("SA_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(8usize);
    let mut eng = Engine::new(ServeConfig::new("artifacts/tiny"))?;
    let measure = |eng: &mut Engine, squeeze: bool| -> anyhow::Result<(f64, f64)> {
        eng.reconfigure(ServeConfig::new("artifacts/tiny").with_squeeze(squeeze))?;
        let mut gen = TaskGen::new(5);
        let mut prefill = 0.0;
        let mut sq = 0.0;
        for i in 0..n {
            let s = gen.sample(Task::Lookup, 200);
            let outs = eng.generate_batch(vec![Request::new(i as u64, s.prompt, 1)]);
            prefill += outs[0].timing.prefill_s;
            sq += outs[0].timing.squeeze_s;
        }
        Ok((prefill / n as f64, sq / n as f64))
    };
    // warm the executables so compile time doesn't pollute the measurement
    let _ = measure(&mut eng, true)?;
    let (p_without, _) = measure(&mut eng, false)?;
    let (p_with, sq_part) = measure(&mut eng, true)?;
    let overhead = (p_with + sq_part) / p_without - 1.0;
    let mut t4 = Table::new(&["arm", "prefill (mean)", "squeeze ops", "overhead"]);
    t4.row(vec!["w/o squeeze".into(), fmt_duration(p_without), "-".into(), "-".into()]);
    t4.row(vec![
        "w/ squeeze".into(),
        fmt_duration(p_with),
        fmt_duration(sq_part),
        format!("{:.1}%", overhead * 100.0),
    ]);
    println!("\nTable 4 — prefill overhead of SqueezeAttention ({n} prompts of ~200 tokens):");
    t4.print();
    t4.write_csv("reports/table4.csv")?;
    Ok(())
}
