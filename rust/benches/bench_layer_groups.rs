//! Tables 7/8 reproduction: how many layers land in the important vs
//! unimportant groups across different tasks — is layer importance an
//! intrinsic property of the model or task-dependent?
//!
//! Expected shape: a stable core with task-specific fluctuation (the paper
//! sees 17–21 important layers for Llama2-70B across Xsum/Samsum/LCC).

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{Engine, Request};
use squeezeattention::squeeze::kmeans_1d;
use squeezeattention::util::bench::Table;
use squeezeattention::workload::{TaskGen, ALL_TASKS};

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        eprintln!("SKIP bench_layer_groups: run `make artifacts` first");
        return Ok(());
    }
    let n_prompts = std::env::var("SA_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(6usize);
    let mut eng = Engine::new(ServeConfig::new("artifacts/tiny"))?;
    let mut table = Table::new(&["task", "important (G1+G2)", "unimportant (G3)", "G3 layers"]);
    let mut per_task_groups: Vec<(String, Vec<usize>)> = Vec::new();

    for task in ALL_TASKS {
        eng.reconfigure(ServeConfig::new("artifacts/tiny"))?;
        eng.enable_cosine_collection();
        let mut gen = TaskGen::new(4242);
        for i in 0..n_prompts {
            let s = gen.sample(task, 180);
            eng.generate_batch(vec![Request::new(i as u64, s.prompt, 2)]);
        }
        let means = eng.cosine_stats().unwrap().layer_means();
        let clustering = kmeans_1d(&means, 3, 100);
        let g3 = clustering.members(2);
        let important = means.len() - g3.len();
        println!(
            "task {:9}: {} important / {} unimportant  G3={:?}",
            task.name(),
            important,
            g3.len(),
            g3
        );
        table.row(vec![
            task.name().into(),
            important.to_string(),
            g3.len().to_string(),
            format!("{g3:?}"),
        ]);
        per_task_groups.push((task.name().into(), g3));
    }

    println!("\nTables 7/8 — layer-group sizes across tasks ({n_prompts} prompts each):");
    table.print();
    table.write_csv("reports/table7_8_layer_groups.csv")?;

    // Stability analysis: layers that are unimportant for every task vs some.
    let n_layer = 8;
    let mut always = Vec::new();
    let mut sometimes = Vec::new();
    for l in 0..n_layer {
        let count = per_task_groups.iter().filter(|(_, g)| g.contains(&l)).count();
        if count == per_task_groups.len() {
            always.push(l);
        } else if count > 0 {
            sometimes.push(l);
        }
    }
    println!("\nalways-unimportant layers: {always:?}");
    println!("task-sensitive layers:     {sometimes:?}");
    Ok(())
}
