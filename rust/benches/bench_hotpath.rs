//! Decode hot-path microbench: batch-resident scratch vs full per-step
//! re-gather, swept over batch size x prompt length x eviction policy.
//!
//! Per arm we report decode steps, decode-steps/s, KV bytes copied into the
//! scratch buffers, bytes-copied/step (the headline), and the refill vs
//! incremental-append split. The kilocontext arms run on `sim://long`
//! (max_seq 1536) where the cache is large and stable under the Full
//! policy — the regime the resident path targets; the eviction arms run on
//! `sim://tiny` with a tight budget, where `retain` invalidates residency
//! every step and the two modes honestly converge.
//!
//! Asserts the acceptance bar in-process: at batch 8 x 1k-token contexts
//! (Full policy) the resident path must copy < 20% of the re-gather
//! baseline's bytes per step. Emits `reports/BENCH_hotpath.json`.
//! `SA_QUICK=1` shrinks the secondary arms but keeps that headline arm.

use std::time::Instant;

use squeezeattention::config::{PolicyKind, ServeConfig};
use squeezeattention::coordinator::{Engine, Request};
use squeezeattention::util::bench::Table;
use squeezeattention::util::Json;
use squeezeattention::workload::TraceSpec;

struct Arm {
    label: String,
    artifacts: &'static str,
    policy: PolicyKind,
    budget: usize,
    batch: usize,
    prompt_len: usize,
    max_new: usize,
    n_requests: usize,
    /// The batch-8 x 1k-context arm the CI assertion gates on.
    headline: bool,
}

struct ArmResult {
    label: String,
    resident: bool,
    wall_s: f64,
    decode_steps: u64,
    kv_bytes_copied: u64,
    full_refills: u64,
    incremental_appends: u64,
    headline: bool,
}

impl ArmResult {
    fn bytes_per_step(&self) -> f64 {
        self.kv_bytes_copied as f64 / (self.decode_steps.max(1)) as f64
    }

    fn steps_per_s(&self) -> f64 {
        self.decode_steps as f64 / self.wall_s.max(1e-9)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arm", Json::str(&self.label)),
            ("resident", Json::Bool(self.resident)),
            ("wall_s", Json::num(self.wall_s)),
            ("decode_steps", Json::num(self.decode_steps as f64)),
            ("steps_per_s", Json::num(self.steps_per_s())),
            ("kv_bytes_copied", Json::num(self.kv_bytes_copied as f64)),
            ("bytes_per_step", Json::num(self.bytes_per_step())),
            ("full_refills", Json::num(self.full_refills as f64)),
            ("incremental_appends", Json::num(self.incremental_appends as f64)),
        ])
    }
}

fn run_arm(arm: &Arm, resident: bool) -> anyhow::Result<ArmResult> {
    let mut cfg = ServeConfig::new(arm.artifacts)
        .with_policy(arm.policy)
        .with_budget(arm.budget)
        .with_resident_scratch(resident);
    cfg.max_batch = arm.batch;
    let reqs: Vec<Request> = TraceSpec::closed(arm.n_requests, arm.prompt_len, arm.max_new, 53)
        .generate()
        .iter()
        .enumerate()
        .map(|(i, it)| Request::new(i as u64, it.sample.prompt.clone(), arm.max_new))
        .collect();
    let mut eng = Engine::new(cfg)?;
    let t0 = Instant::now();
    let outs = eng.generate_batch(reqs);
    let wall_s = t0.elapsed().as_secs_f64();
    assert_eq!(outs.len(), arm.n_requests);
    let m = eng.sched_metrics();
    Ok(ArmResult {
        label: arm.label.clone(),
        resident,
        wall_s,
        decode_steps: eng.last_run.decode_steps,
        kv_bytes_copied: m.kv_bytes_copied,
        full_refills: m.gather_full_refills,
        incremental_appends: m.gather_incremental_appends,
        headline: arm.headline,
    })
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("SA_QUICK").is_ok();
    let tiny_n = if quick { 8 } else { 24 };

    let mut arms: Vec<Arm> = Vec::new();
    // Eviction-policy sweep on sim://tiny: tight budget, retain every step.
    for policy in PolicyKind::ALL {
        arms.push(Arm {
            label: format!("tiny_b8_p80_{}", policy.name()),
            artifacts: "sim://tiny",
            policy,
            budget: 48,
            batch: 8,
            prompt_len: 80,
            max_new: 32,
            n_requests: tiny_n,
            headline: false,
        });
    }
    // Kilocontext sweep on sim://long: large stable caches, Full policy.
    for (batch, prompt_len) in [(1usize, 256usize), (1, 1024), (8, 256), (8, 1024)] {
        if quick && batch == 1 && prompt_len == 1024 {
            continue; // quick mode drops the slowest non-headline arm
        }
        arms.push(Arm {
            label: format!("long_b{batch}_p{prompt_len}_full"),
            artifacts: "sim://long",
            policy: PolicyKind::Full,
            budget: 128,
            batch,
            prompt_len,
            max_new: 32,
            n_requests: batch,
            headline: batch == 8 && prompt_len == 1024,
        });
    }

    let mut results: Vec<ArmResult> = Vec::new();
    for arm in &arms {
        for resident in [true, false] {
            results.push(run_arm(arm, resident)?);
        }
    }

    let mut table = Table::new(&[
        "arm",
        "resident",
        "steps",
        "steps/s",
        "bytes/step",
        "refills",
        "increments",
    ]);
    for r in &results {
        table.row(vec![
            r.label.clone(),
            r.resident.to_string(),
            r.decode_steps.to_string(),
            format!("{:.1}", r.steps_per_s()),
            format!("{:.0}", r.bytes_per_step()),
            r.full_refills.to_string(),
            r.incremental_appends.to_string(),
        ]);
    }
    println!("decode hot path: resident scratch vs full re-gather:");
    table.print();

    // The acceptance bar: batch 8 x 1k context, resident must copy < 20%
    // of the re-gather baseline's bytes per step (it lands near 3%).
    let headline_resident = results
        .iter()
        .find(|r| r.headline && r.resident)
        .expect("headline arm ran");
    let headline_refill = results
        .iter()
        .find(|r| r.headline && !r.resident)
        .expect("headline baseline ran");
    let ratio = headline_resident.bytes_per_step() / headline_refill.bytes_per_step().max(1.0);
    println!(
        "headline (batch 8 x 1k ctx): resident copies {:.1}% of re-gather bytes/step ({:.1}x less)",
        ratio * 100.0,
        1.0 / ratio.max(1e-9)
    );
    assert!(
        ratio < 0.2,
        "resident path copies {:.1}% of the re-gather baseline per step — bar is < 20%",
        ratio * 100.0
    );
    // Sanity on the mechanism itself, not just the ratio.
    assert!(headline_resident.incremental_appends > 0, "incremental path never taken");
    assert_eq!(headline_refill.incremental_appends, 0, "baseline must always refill");

    let report = Json::obj(vec![
        ("bench", Json::str("hotpath_resident_scratch")),
        ("quick", Json::Bool(quick)),
        (
            "headline_bytes_per_step_ratio",
            Json::num(ratio),
        ),
        ("arms", Json::Arr(results.iter().map(|r| r.to_json()).collect())),
    ]);
    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/BENCH_hotpath.json", report.to_string())?;
    println!("wrote reports/BENCH_hotpath.json");
    Ok(())
}
