//! Swap-resume vs restart-from-scratch preemption under open-loop load: a
//! Poisson arrival trace replayed against a capacity-capped device pool,
//! once with the host-spill tier disabled (every preemption re-prefills and
//! discards partial output — the PR 1 semantics) and once with suspend/
//! resume enabled (preempted sequences migrate to host memory and continue
//! where they stopped). Reports tokens/s, preemption/swap counters, decode
//! steps, and the queue+suspended latency quantiles, and emits
//! `reports/BENCH_swap.json`.
//!
//! Swap traffic is no longer treated as free: the pool's `migrated_into`
//! counters meter the bytes a real deployment would push over PCIe, and the
//! simulator cost model prices them (`Cluster::swap_transfer_s` at A100
//! PCIe 4.0 rates) into a projected wall time / throughput alongside the
//! measured one.
//!
//! A second sweep arm charts the `batch_wait_ms` batch-forming knob under
//! Poisson arrivals through the router (the knob lives in the worker loop):
//! first-token latency (TTFT quantiles from the worker snapshot) vs mean
//! step occupancy, the tradeoff the ROADMAP asked to chart.
//!
//! Runs entirely on the simulated backend (`sim://tiny`), so it needs no
//! compiled artifacts. Arrivals are replayed in wall-clock time; the rate is
//! high enough that the replay itself adds well under a second.
//! `SA_QUICK=1` shrinks the trace.

use std::time::{Duration, Instant};

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{Engine, FinishReason, Request, RoutePolicy, Router};
use squeezeattention::kvcache::Tier;
use squeezeattention::simulator::A100_40GB_X1;
use squeezeattention::util::bench::Table;
use squeezeattention::util::Json;
use squeezeattention::workload::TraceSpec;

const POOL_BYTES: usize = 600 * 1024;
const HOST_BYTES: usize = 8 * 1024 * 1024;
const PROMPT_LEN: usize = 16;
const MAX_NEW: usize = 48;
const ARRIVAL_RATE: f64 = 150.0; // requests/s — saturates the capped pool

struct ArmResult {
    name: String,
    wall_s: f64,
    tokens: u64,
    completed: usize,
    oom_failed: usize,
    preemptions: u64,
    swap_outs: u64,
    swap_ins: u64,
    restarts_avoided: u64,
    decode_steps: u64,
    /// Bytes migrated device↔host (both directions) — the PCIe traffic a
    /// real swap would perform.
    swap_bytes: usize,
    /// Projected host-link time for that traffic at A100 PCIe rates.
    projected_swap_s: f64,
    queue_latency: Json,
}

impl ArmResult {
    fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.wall_s.max(1e-9)
    }

    /// Throughput after charging the projected swap-transfer time — the
    /// honest swap-vs-restart comparison once PCIe is priced in.
    fn projected_tokens_per_s(&self) -> f64 {
        self.tokens as f64 / (self.wall_s + self.projected_swap_s).max(1e-9)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("wall_s", Json::num(self.wall_s)),
            ("tokens", Json::num(self.tokens as f64)),
            ("tokens_per_s", Json::num(self.tokens_per_s())),
            ("completed", Json::num(self.completed as f64)),
            ("oom_failed", Json::num(self.oom_failed as f64)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("swap_outs", Json::num(self.swap_outs as f64)),
            ("swap_ins", Json::num(self.swap_ins as f64)),
            ("restarts_avoided", Json::num(self.restarts_avoided as f64)),
            ("decode_steps", Json::num(self.decode_steps as f64)),
            ("swap_bytes", Json::num(self.swap_bytes as f64)),
            ("projected_swap_s", Json::num(self.projected_swap_s)),
            ("projected_tokens_per_s", Json::num(self.projected_tokens_per_s())),
            ("queue_latency_s", self.queue_latency.clone()),
        ])
    }
}

/// Replay the trace open-loop: submit each request once its arrival time
/// passes, stepping the engine in between so arrivals join running batches.
fn run_arm(name: &str, cfg: ServeConfig, n_requests: usize) -> anyhow::Result<ArmResult> {
    let items = TraceSpec::closed(n_requests, PROMPT_LEN, MAX_NEW, 97)
        .poisson(ARRIVAL_RATE)
        .generate();
    let mut eng = Engine::new(cfg)?;
    let t0 = Instant::now();
    let mut next = 0usize;
    let mut outs = Vec::new();
    while next < items.len() || eng.has_work() {
        while next < items.len() && t0.elapsed().as_secs_f64() >= items[next].arrival_s {
            let req = Request::new(next as u64, items[next].sample.prompt.clone(), MAX_NEW);
            if let Err(rejected) = eng.submit(req) {
                outs.push(rejected);
            }
            next += 1;
        }
        if eng.has_work() {
            outs.extend(eng.step()?);
        } else if next < items.len() {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let tokens: u64 = outs.iter().map(|o| o.generated.len() as u64).sum();
    let completed = outs
        .iter()
        .filter(|o| matches!(o.finish, FinishReason::Eos | FinishReason::Length))
        .count();
    let oom_failed = outs.iter().filter(|o| o.finish == FinishReason::Oom).count();
    let m = eng.sched_metrics().clone();
    let run = eng.run_stats().clone();
    let swap_bytes =
        eng.pool().migrated_into(Tier::Host) + eng.pool().migrated_into(Tier::Device);
    let projected_swap_s = A100_40GB_X1.swap_transfer_s(swap_bytes as f64);
    let queue_latency = eng.queue_latency().summary().to_json();
    Ok(ArmResult {
        name: name.to_string(),
        wall_s,
        tokens,
        completed,
        oom_failed,
        preemptions: m.preemptions,
        swap_outs: m.swap_outs,
        swap_ins: m.swap_ins,
        restarts_avoided: m.restarts_avoided,
        decode_steps: run.decode_steps,
        swap_bytes,
        projected_swap_s,
        queue_latency,
    })
}

/// One `batch_wait_ms` sweep point: Poisson arrivals through the router (the
/// knob lives in the worker's batch-forming loop), reporting first-token
/// latency quantiles vs mean step occupancy.
fn run_wait_arm(wait_ms: u64, n_requests: usize, rate: f64) -> anyhow::Result<Json> {
    let mut cfg = ServeConfig::new("sim://tiny")
        .with_budget(48)
        .with_squeeze(false)
        .with_batch_wait_ms(wait_ms);
    cfg.max_batch = 4;
    let router = Router::spawn(cfg, 1, RoutePolicy::RoundRobin)?;
    let items = TraceSpec::closed(n_requests, PROMPT_LEN, MAX_NEW, 131).poisson(rate).generate();
    let t0 = Instant::now();
    let mut replies = Vec::new();
    for (i, it) in items.iter().enumerate() {
        let dt = it.arrival_s - t0.elapsed().as_secs_f64();
        if dt > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(dt));
        }
        let req = Request::new(i as u64, it.sample.prompt.clone(), MAX_NEW);
        replies.push(router.submit_async(req)?);
    }
    let mut tokens = 0u64;
    for rx in replies {
        tokens += rx.recv()?.generated.len() as u64;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let snap = router.snapshots().remove(0);
    Ok(Json::obj(vec![
        ("batch_wait_ms", Json::num(wait_ms as f64)),
        ("tokens_per_s", Json::num(tokens as f64 / wall_s.max(1e-9))),
        ("mean_occupancy", Json::num(snap.sched.mean_occupancy())),
        ("batch_utilization", Json::num(snap.sched.batch_utilization())),
        ("ttft_s", snap.ttft.to_json()),
        ("itl_s", snap.itl.to_json()),
    ]))
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("SA_QUICK").is_ok();
    let n_requests = if quick { 8 } else { 24 };

    let base = {
        let mut cfg = ServeConfig::new("sim://tiny").with_budget(48).with_squeeze(false);
        cfg.max_batch = 4;
        cfg.kv_pool_bytes = POOL_BYTES;
        cfg
    };
    let restart = run_arm("restart", base.clone(), n_requests)?;
    let swap = run_arm("swap", base.with_host_spill(HOST_BYTES), n_requests)?;

    let mut table = Table::new(&[
        "arm",
        "tok/s",
        "proj tok/s (PCIe)",
        "preemptions",
        "swap_ins",
        "swap_MiB",
        "decode_steps",
    ]);
    for arm in [&restart, &swap] {
        table.row(vec![
            arm.name.clone(),
            format!("{:.1}", arm.tokens_per_s()),
            format!("{:.1}", arm.projected_tokens_per_s()),
            arm.preemptions.to_string(),
            arm.swap_ins.to_string(),
            format!("{:.2}", arm.swap_bytes as f64 / (1024.0 * 1024.0)),
            arm.decode_steps.to_string(),
        ]);
    }
    println!(
        "Poisson({ARRIVAL_RATE}/s) x {n_requests} requests on a {} KiB device pool \
         (swap traffic priced at {:.0} GB/s PCIe):",
        POOL_BYTES >> 10,
        A100_40GB_X1.pcie_bw / 1e9
    );
    table.print();

    // batch_wait_ms sweep: first-token latency vs occupancy under a gentler
    // Poisson rate (uncapped pool — the knob is about batch forming, not
    // memory pressure).
    let wait_points: &[u64] = if quick { &[0, 10] } else { &[0, 2, 10, 25] };
    let wait_rate = 120.0;
    let wait_n = if quick { 6 } else { 12 };
    let mut wait_sweep = Vec::new();
    let mut wait_table = Table::new(&["batch_wait_ms", "ttft_p95_ms", "mean_occupancy", "tok/s"]);
    for &w in wait_points {
        let point = run_wait_arm(w, wait_n, wait_rate)?;
        wait_table.row(vec![
            w.to_string(),
            point
                .get("ttft_s")
                .and_then(|t| t.get("p95"))
                .and_then(|v| v.as_f64())
                .map(|v| format!("{:.2}", v * 1e3))
                .unwrap_or_else(|| "-".into()),
            point
                .get("mean_occupancy")
                .and_then(|v| v.as_f64())
                .map(|v| format!("{v:.2}"))
                .unwrap_or_else(|| "-".into()),
            point
                .get("tokens_per_s")
                .and_then(|v| v.as_f64())
                .map(|v| format!("{v:.1}"))
                .unwrap_or_else(|| "-".into()),
        ]);
        wait_sweep.push(point);
    }
    println!("\nbatch_wait_ms sweep (Poisson({wait_rate}/s) x {wait_n} requests, 1 worker):");
    wait_table.print();

    let report = Json::obj(vec![
        ("bench", Json::str("swap_vs_restart")),
        ("n_requests", Json::num(n_requests as f64)),
        ("arrival_rate", Json::num(ARRIVAL_RATE)),
        ("kv_pool_bytes", Json::num(POOL_BYTES as f64)),
        ("host_spill_bytes", Json::num(HOST_BYTES as f64)),
        ("pcie_bw_bytes_per_s", Json::num(A100_40GB_X1.pcie_bw)),
        ("restart", restart.to_json()),
        ("swap", swap.to_json()),
        ("batch_wait_sweep", Json::Arr(wait_sweep)),
    ]);
    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/BENCH_swap.json", report.to_string())?;
    println!("wrote reports/BENCH_swap.json");
    Ok(())
}
