//! Swap-resume vs restart-from-scratch preemption under open-loop load: a
//! Poisson arrival trace replayed against a capacity-capped device pool,
//! once with the host-spill tier disabled (every preemption re-prefills and
//! discards partial output — the PR 1 semantics) and once with suspend/
//! resume enabled (preempted sequences migrate to host memory and continue
//! where they stopped). Reports tokens/s, preemption/swap counters, decode
//! steps, and the queue+suspended latency quantiles, and emits
//! `reports/BENCH_swap.json`.
//!
//! Runs entirely on the simulated backend (`sim://tiny`), so it needs no
//! compiled artifacts. Arrivals are replayed in wall-clock time; the rate is
//! high enough that the replay itself adds well under a second.
//! `SA_QUICK=1` shrinks the trace.

use std::time::{Duration, Instant};

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{Engine, FinishReason, Request};
use squeezeattention::util::bench::Table;
use squeezeattention::util::Json;
use squeezeattention::workload::TraceSpec;

const POOL_BYTES: usize = 600 * 1024;
const HOST_BYTES: usize = 8 * 1024 * 1024;
const PROMPT_LEN: usize = 16;
const MAX_NEW: usize = 48;
const ARRIVAL_RATE: f64 = 150.0; // requests/s — saturates the capped pool

struct ArmResult {
    name: String,
    wall_s: f64,
    tokens: u64,
    completed: usize,
    oom_failed: usize,
    preemptions: u64,
    swap_outs: u64,
    swap_ins: u64,
    restarts_avoided: u64,
    decode_steps: u64,
    queue_latency: Json,
}

impl ArmResult {
    fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.wall_s.max(1e-9)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("wall_s", Json::num(self.wall_s)),
            ("tokens", Json::num(self.tokens as f64)),
            ("tokens_per_s", Json::num(self.tokens_per_s())),
            ("completed", Json::num(self.completed as f64)),
            ("oom_failed", Json::num(self.oom_failed as f64)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("swap_outs", Json::num(self.swap_outs as f64)),
            ("swap_ins", Json::num(self.swap_ins as f64)),
            ("restarts_avoided", Json::num(self.restarts_avoided as f64)),
            ("decode_steps", Json::num(self.decode_steps as f64)),
            ("queue_latency_s", self.queue_latency.clone()),
        ])
    }
}

/// Replay the trace open-loop: submit each request once its arrival time
/// passes, stepping the engine in between so arrivals join running batches.
fn run_arm(name: &str, cfg: ServeConfig, n_requests: usize) -> anyhow::Result<ArmResult> {
    let items = TraceSpec::closed(n_requests, PROMPT_LEN, MAX_NEW, 97)
        .poisson(ARRIVAL_RATE)
        .generate();
    let mut eng = Engine::new(cfg)?;
    let t0 = Instant::now();
    let mut next = 0usize;
    let mut outs = Vec::new();
    while next < items.len() || eng.has_work() {
        while next < items.len() && t0.elapsed().as_secs_f64() >= items[next].arrival_s {
            let req = Request::new(next as u64, items[next].sample.prompt.clone(), MAX_NEW);
            if let Err(rejected) = eng.submit(req) {
                outs.push(rejected);
            }
            next += 1;
        }
        if eng.has_work() {
            outs.extend(eng.step()?);
        } else if next < items.len() {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let tokens: u64 = outs.iter().map(|o| o.generated.len() as u64).sum();
    let completed = outs
        .iter()
        .filter(|o| matches!(o.finish, FinishReason::Eos | FinishReason::Length))
        .count();
    let oom_failed = outs.iter().filter(|o| o.finish == FinishReason::Oom).count();
    let m = eng.sched_metrics().clone();
    let run = eng.run_stats().clone();
    let queue_latency = eng.queue_latency().summary().to_json();
    Ok(ArmResult {
        name: name.to_string(),
        wall_s,
        tokens,
        completed,
        oom_failed,
        preemptions: m.preemptions,
        swap_outs: m.swap_outs,
        swap_ins: m.swap_ins,
        restarts_avoided: m.restarts_avoided,
        decode_steps: run.decode_steps,
        queue_latency,
    })
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("SA_QUICK").is_ok();
    let n_requests = if quick { 8 } else { 24 };

    let base = {
        let mut cfg = ServeConfig::new("sim://tiny").with_budget(48).with_squeeze(false);
        cfg.max_batch = 4;
        cfg.kv_pool_bytes = POOL_BYTES;
        cfg
    };
    let restart = run_arm("restart", base.clone(), n_requests)?;
    let swap = run_arm("swap", base.with_host_spill(HOST_BYTES), n_requests)?;

    let mut table = Table::new(&[
        "arm",
        "tok/s",
        "preemptions",
        "swap_ins",
        "restarts_avoided",
        "decode_steps",
    ]);
    for arm in [&restart, &swap] {
        table.row(vec![
            arm.name.clone(),
            format!("{:.1}", arm.tokens_per_s()),
            arm.preemptions.to_string(),
            arm.swap_ins.to_string(),
            arm.restarts_avoided.to_string(),
            arm.decode_steps.to_string(),
        ]);
    }
    println!(
        "Poisson({ARRIVAL_RATE}/s) x {n_requests} requests on a {} KiB device pool:",
        POOL_BYTES >> 10
    );
    table.print();

    let report = Json::obj(vec![
        ("bench", Json::str("swap_vs_restart")),
        ("n_requests", Json::num(n_requests as f64)),
        ("arrival_rate", Json::num(ARRIVAL_RATE)),
        ("kv_pool_bytes", Json::num(POOL_BYTES as f64)),
        ("host_spill_bytes", Json::num(HOST_BYTES as f64)),
        ("restart", restart.to_json()),
        ("swap", swap.to_json()),
    ]);
    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/BENCH_swap.json", report.to_string())?;
    println!("wrote reports/BENCH_swap.json");
    Ok(())
}
