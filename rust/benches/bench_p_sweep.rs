//! Table 6 reproduction: accuracy as a function of the hyperparameter `p`
//! (fraction of budget the unimportant layers keep), total budget fixed at
//! 20% of the prompt length.
//!
//! Expected shape: unimodal — too-small p starves the unimportant layers,
//! p = 1.0 is the no-reallocation baseline; the paper peaks around 0.3–0.4.

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::Engine;
use squeezeattention::util::bench::Table;
use squeezeattention::workload::{best_baseline_for, evaluate, EvalSpec, Task};

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        eprintln!("SKIP bench_p_sweep: run `make artifacts` first");
        return Ok(());
    }
    let quick = std::env::var("SA_QUICK").is_ok();
    let ps: Vec<f64> = if quick {
        vec![0.3, 1.0]
    } else {
        vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.8, 1.0]
    };
    // Paper's Table 6 uses Mistral+SAMSUM (few-shot) at 20% budget; our
    // analogue is the copy/lookup mixture at 20%.
    let task = Task::Lookup;
    let spec = EvalSpec::new(task, if quick { 3 } else { 6 }, 160, 32, 2025);

    let mut eng = Engine::new(ServeConfig::new("artifacts/tiny"))?;
    let mut table = Table::new(&["p", "accuracy", "reallocated", "mean_kv_tokens"]);
    for &p in &ps {
        let cfg = ServeConfig::new("artifacts/tiny")
            .with_policy(best_baseline_for(task))
            .with_budget_frac(0.2)
            .with_p(p);
        let r = evaluate(&mut eng, cfg, &spec)?;
        println!("p={p:.1}  acc={:.3}  kv_tokens={:.0}", r.accuracy, r.mean_kv_tokens);
        table.row(vec![
            format!("{p:.1}"),
            format!("{:.4}", r.accuracy),
            format!("{:.0}%", r.reallocated_frac * 100.0),
            format!("{:.0}", r.mean_kv_tokens),
        ]);
    }
    println!("\nTable 6 — accuracy vs p (budget fixed at 20% of prompt):");
    table.print();
    table.write_csv("reports/table6_p_sweep.csv")?;
    Ok(())
}
