//! Chaos bench: serving quality under injected faults, worker death, and
//! overload, in three arms (emits `reports/BENCH_chaos.json`):
//!
//! 1. **Fault-rate sweep** — the same closed workload drained at decode
//!    step-error rates {0, 0.01, 0.05} (plus matching latency spikes), with
//!    suspend-capable retries. Reports throughput and the containment
//!    counters, and *asserts* that every faulted run completes
//!    token-identically to the fault-free reference — the paper-level
//!    invariant that greedy decode is a pure function of cache + token +
//!    position, so retries are invisible in the output.
//! 2. **Kill / recovery** — a worker is killed mid-decode through the
//!    router's chaos hook. Reports the time until the supervisor has the
//!    slot healthy again and asserts the in-flight caller unblocked with a
//!    `WorkerError` terminal and a post-respawn submit succeeds.
//! 3. **Load shedding** — a Poisson burst against one worker with a low
//!    queue-depth bound, vs the same burst unbounded. Reports shed counts
//!    and admitted-request TTFT quantiles, and asserts the admitted p95
//!    TTFT stays under the bound — shedding converts queue delay into fast
//!    `Overloaded` rejections instead of serving everyone late.
//!
//! Runs entirely on the simulated backend (`sim://tiny`); fault injection
//! is deterministic (seeded), so every run replays. `SA_QUICK=1` shrinks
//! the workloads.

use std::time::{Duration, Instant};

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{
    Engine, FinishReason, Request, RouteError, RoutePolicy, Router,
};
use squeezeattention::util::bench::Table;
use squeezeattention::util::Json;
use squeezeattention::workload::TraceSpec;

const PROMPT_LEN: usize = 16;
const MAX_NEW: usize = 32;
/// Admitted-request p95 TTFT bound for the shedding arm, generous enough
/// for a loaded CI runner while still far below an unbounded queue's wait.
const TTFT_BOUND_S: f64 = 2.0;

fn base_cfg() -> ServeConfig {
    ServeConfig::new("sim://tiny").with_budget(48).with_squeeze(false)
}

fn is_success(f: FinishReason) -> bool {
    matches!(f, FinishReason::Eos | FinishReason::Length)
}

struct FaultArm {
    rate: f64,
    wall_s: f64,
    tokens: u64,
    completed: usize,
    worker_errors: u64,
    requests_retried: u64,
    faults_injected: u64,
    swap_outs: u64,
    /// Per-request generated tokens, by id — the identity payload.
    outputs: Vec<(u64, Vec<i32>)>,
}

impl FaultArm {
    fn to_json(&self, token_identical: bool) -> Json {
        Json::obj(vec![
            ("step_error_rate", Json::num(self.rate)),
            ("wall_s", Json::num(self.wall_s)),
            ("tokens", Json::num(self.tokens as f64)),
            ("tokens_per_s", Json::num(self.tokens as f64 / self.wall_s.max(1e-9))),
            ("completed", Json::num(self.completed as f64)),
            ("worker_errors", Json::num(self.worker_errors as f64)),
            ("requests_retried", Json::num(self.requests_retried as f64)),
            ("faults_injected", Json::num(self.faults_injected as f64)),
            ("swap_outs", Json::num(self.swap_outs as f64)),
            ("token_identical_to_fault_free", Json::Bool(token_identical)),
        ])
    }
}

/// Drain one closed workload at the given decode step-error rate.
fn run_fault_arm(rate: f64, n_requests: usize) -> anyhow::Result<FaultArm> {
    let mut cfg = base_cfg().with_host_spill(16 * 1024 * 1024);
    cfg.max_retries = 1_000;
    cfg.faults.step_error_rate = rate;
    if rate > 0.0 {
        cfg.faults.latency_spike_ms = 1;
        cfg.faults.latency_spike_rate = rate;
    }
    let items = TraceSpec::closed(n_requests, PROMPT_LEN, MAX_NEW, 61).generate();
    let mut eng = Engine::new(cfg)?;
    let t0 = Instant::now();
    for (i, it) in items.iter().enumerate() {
        let req = Request::new(i as u64, it.sample.prompt.clone(), MAX_NEW);
        if let Err(rejected) = eng.submit(req) {
            anyhow::bail!("request {} rejected at submit: {:?}", i, rejected.finish);
        }
    }
    let mut outs = Vec::new();
    while eng.has_work() {
        outs.extend(eng.step()?);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    for o in &outs {
        if !is_success(o.finish) {
            anyhow::bail!("request {} did not survive rate {rate}: {:?}", o.id, o.finish);
        }
    }
    let m = eng.sched_metrics().clone();
    let mut outputs: Vec<(u64, Vec<i32>)> =
        outs.iter().map(|o| (o.id, o.generated.clone())).collect();
    outputs.sort_by_key(|(id, _)| *id);
    Ok(FaultArm {
        rate,
        wall_s,
        tokens: outs.iter().map(|o| o.generated.len() as u64).sum(),
        completed: outs.len(),
        worker_errors: m.worker_errors,
        requests_retried: m.requests_retried,
        faults_injected: m.faults_injected,
        swap_outs: m.swap_outs,
        outputs,
    })
}

/// Kill one worker mid-decode; report how long the supervisor takes to
/// bring the slot back and verify serving resumes.
fn run_kill_arm() -> anyhow::Result<Json> {
    let mut cfg = base_cfg();
    cfg.max_worker_restarts = 3;
    cfg.faults.latency_spike_ms = 2;
    cfg.faults.latency_spike_rate = 1.0; // every decode call sleeps 2ms
    let router = Router::spawn(cfg, 1, RoutePolicy::RoundRobin)?;
    let items = TraceSpec::closed(2, PROMPT_LEN, MAX_NEW, 67).generate();
    let prompt = items[0].sample.prompt.clone();

    let handle = router
        .submit_async(Request::new(0, prompt.clone(), 400))
        .map_err(|e| anyhow::anyhow!("victim submit failed: {e}"))?;
    std::thread::sleep(Duration::from_millis(30));
    let t_kill = Instant::now();
    assert!(router.kill_worker(0), "poison job not accepted");
    let out = handle.recv()?;
    assert_eq!(out.finish, FinishReason::WorkerError, "caller got {:?}", out.finish);
    let unblock_ms = t_kill.elapsed().as_secs_f64() * 1e3;

    while router.worker_restarts() != 1 || router.worker_state(0) != Some("healthy") {
        assert!(t_kill.elapsed() < Duration::from_secs(10), "worker never respawned");
        std::thread::sleep(Duration::from_millis(2));
    }
    let recover_ms = t_kill.elapsed().as_secs_f64() * 1e3;
    let out = router
        .submit(Request::new(1, prompt, 16))
        .map_err(|e| anyhow::anyhow!("post-respawn submit failed: {e}"))?;
    assert!(is_success(out.finish), "post-respawn request failed: {:?}", out.finish);
    println!(
        "kill/recovery: caller unblocked in {unblock_ms:.0}ms, \
         slot healthy again in {recover_ms:.0}ms, post-respawn submit ok"
    );
    Ok(Json::obj(vec![
        ("caller_unblock_ms", Json::num(unblock_ms)),
        ("recover_ms", Json::num(recover_ms)),
        ("worker_restarts", Json::num(router.worker_restarts() as f64)),
        ("post_respawn_submit_ok", Json::Bool(true)),
    ]))
}

/// Replay one Poisson burst through a 1-worker router; returns
/// (shed, admitted, ttft p95 of admitted).
fn run_shed_burst(
    shed_queue_depth: usize,
    n_requests: usize,
    rate: f64,
) -> anyhow::Result<(usize, usize, f64)> {
    let mut cfg = base_cfg();
    cfg.shed_queue_depth = shed_queue_depth;
    cfg.faults.latency_spike_ms = 1;
    cfg.faults.latency_spike_rate = 1.0; // slow decode so the burst queues
    let router = Router::spawn(cfg, 1, RoutePolicy::RoundRobin)?;
    let items = TraceSpec::closed(n_requests, PROMPT_LEN, MAX_NEW, 71).poisson(rate).generate();
    let t0 = Instant::now();
    let mut admitted = Vec::new();
    let mut shed = 0usize;
    for (i, it) in items.iter().enumerate() {
        let dt = it.arrival_s - t0.elapsed().as_secs_f64();
        if dt > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(dt));
        }
        match router.submit_async(Request::new(i as u64, it.sample.prompt.clone(), MAX_NEW)) {
            Ok(h) => admitted.push(h),
            Err(RouteError::Overloaded { .. }) => shed += 1,
            Err(other) => anyhow::bail!("unexpected route error: {other}"),
        }
    }
    let n_admitted = admitted.len();
    for h in &admitted {
        let out = h.recv()?;
        assert!(is_success(out.finish), "admitted request failed: {:?}", out.finish);
    }
    assert_eq!(router.requests_shed() as usize, shed);
    let snap = router.snapshots().remove(0);
    Ok((shed, n_admitted, snap.ttft.p95))
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("SA_QUICK").is_ok();
    let n_fault = if quick { 8 } else { 16 };
    let n_burst = if quick { 16 } else { 40 };
    let burst_rate = 400.0; // requests/s — far beyond one worker's capacity

    // Arm 1: fault-rate sweep with token-identity assertion.
    let reference = run_fault_arm(0.0, n_fault)?;
    let mut arms = vec![(true, reference.outputs.clone(), reference)];
    for rate in [0.01, 0.05] {
        let arm = run_fault_arm(rate, n_fault)?;
        let identical = arm.outputs == arms[0].1;
        assert!(identical, "rate {rate} diverged from the fault-free reference");
        arms.push((identical, arm.outputs.clone(), arm));
    }
    let mut table =
        Table::new(&["rate", "tok/s", "faults", "retried", "worker_errors", "identical"]);
    for (identical, _, arm) in &arms {
        table.row(vec![
            format!("{:.2}", arm.rate),
            format!("{:.1}", arm.tokens as f64 / arm.wall_s.max(1e-9)),
            arm.faults_injected.to_string(),
            arm.requests_retried.to_string(),
            arm.worker_errors.to_string(),
            identical.to_string(),
        ]);
    }
    println!("fault-rate sweep ({n_fault} requests, suspend-capable retries):");
    table.print();

    // Arm 2: kill / recovery.
    let kill = run_kill_arm()?;

    // Arm 3: load shedding vs unbounded queueing under the same burst.
    let (shed, admitted, ttft_p95) = run_shed_burst(3, n_burst, burst_rate)?;
    let (base_shed, base_admitted, base_ttft_p95) = run_shed_burst(0, n_burst, burst_rate)?;
    assert_eq!(base_shed, 0, "unbounded arm must not shed");
    assert!(shed > 0, "burst never tripped the queue-depth bound");
    assert!(
        ttft_p95 <= TTFT_BOUND_S,
        "admitted p95 TTFT {ttft_p95:.3}s exceeds the {TTFT_BOUND_S}s bound"
    );
    println!(
        "shedding (depth 3): {shed}/{n} shed, admitted p95 TTFT {ttft_p95:.3}s; \
         unbounded: 0/{n} shed, p95 TTFT {base_ttft_p95:.3}s",
        n = n_burst
    );

    let fault_sweep = Json::Arr(arms.iter().map(|(ok, _, a)| a.to_json(*ok)).collect());
    let baseline = Json::obj(vec![
        ("shed", Json::num(base_shed as f64)),
        ("admitted", Json::num(base_admitted as f64)),
        ("admitted_ttft_p95_s", Json::num(base_ttft_p95)),
    ]);
    let shedding = Json::obj(vec![
        ("shed_queue_depth", Json::num(3.0)),
        ("shed", Json::num(shed as f64)),
        ("admitted", Json::num(admitted as f64)),
        ("admitted_ttft_p95_s", Json::num(ttft_p95)),
        ("ttft_bound_s", Json::num(TTFT_BOUND_S)),
        ("ttft_within_bound", Json::Bool(true)),
        ("unbounded_baseline", baseline),
    ]);
    let report = Json::obj(vec![
        ("bench", Json::str("chaos")),
        ("n_fault_requests", Json::num(n_fault as f64)),
        ("n_burst_requests", Json::num(n_burst as f64)),
        ("burst_rate", Json::num(burst_rate)),
        ("fault_sweep", fault_sweep),
        ("kill_recovery", kill),
        ("shedding", shedding),
    ]);
    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/BENCH_chaos.json", report.to_string())?;
    println!("wrote reports/BENCH_chaos.json");
    Ok(())
}
