//! Speculative decoding sweep: the same closed batch decoded at
//! `draft_k ∈ {0, 2, 4, 8}` (0 = speculation disabled, the plain one-token
//! decode path). For each arm we report wall time, generated tokens,
//! decode/draft model calls, and the speculation counters — acceptance rate,
//! accepted-tokens-per-engine-step (the headline: > 1 means a verify pass is
//! landing more than one committed token), and mean rollback depth — and
//! emit `reports/BENCH_spec.json`.
//!
//! The sim backend prices a decode step by its batch matmul shape, not by
//! how many tokens the step commits, so accepted-per-step is the structural
//! speedup a real deployment would bank (minus the draft model's own cost,
//! which the `decode_steps` column makes visible: draft and verify passes
//! both count).
//!
//! Runs entirely on the simulated backend (`sim://tiny` target,
//! `sim://tiny-draft` drafter), so it needs no compiled artifacts.
//! `SA_QUICK=1` shrinks the workload.

use std::time::Instant;

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{Engine, FinishReason, Request};
use squeezeattention::util::bench::Table;
use squeezeattention::util::Json;
use squeezeattention::workload::TraceSpec;

const PROMPT_LEN: usize = 80;
const MAX_NEW: usize = 32;

struct ArmResult {
    draft_k: usize,
    wall_s: f64,
    tokens: u64,
    completed: usize,
    decode_steps: u64,
    spec_steps: u64,
    acceptance_rate: f64,
    accepted_per_step: f64,
    rollback_depth: f64,
}

impl ArmResult {
    fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.wall_s.max(1e-9)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("draft_k", Json::num(self.draft_k as f64)),
            ("wall_s", Json::num(self.wall_s)),
            ("tokens", Json::num(self.tokens as f64)),
            ("tokens_per_s", Json::num(self.tokens_per_s())),
            ("completed", Json::num(self.completed as f64)),
            ("decode_steps", Json::num(self.decode_steps as f64)),
            ("spec_steps", Json::num(self.spec_steps as f64)),
            ("acceptance_rate", Json::num(self.acceptance_rate)),
            ("accepted_per_step", Json::num(self.accepted_per_step)),
            ("rollback_depth", Json::num(self.rollback_depth)),
        ])
    }
}

/// Decode one closed batch at the given draft depth (0 disables speculation).
fn run_arm(draft_k: usize, n_requests: usize) -> anyhow::Result<ArmResult> {
    let cfg = ServeConfig::new("sim://tiny").with_budget(48).with_spec_k(draft_k);
    let items = TraceSpec::closed(n_requests, PROMPT_LEN, MAX_NEW, 53).generate();
    let reqs: Vec<Request> = items
        .iter()
        .enumerate()
        .map(|(i, it)| Request::new(i as u64, it.sample.prompt.clone(), MAX_NEW))
        .collect();
    let mut eng = Engine::new(cfg)?;
    let t0 = Instant::now();
    let outs = eng.generate_batch(reqs);
    let wall_s = t0.elapsed().as_secs_f64();
    let tokens: u64 = outs.iter().map(|o| o.generated.len() as u64).sum();
    let completed = outs
        .iter()
        .filter(|o| matches!(o.finish, FinishReason::Eos | FinishReason::Length))
        .count();
    let m = eng.sched_metrics().clone();
    let run = eng.run_stats().clone();
    Ok(ArmResult {
        draft_k,
        wall_s,
        tokens,
        completed,
        decode_steps: run.decode_steps,
        spec_steps: m.spec_steps,
        acceptance_rate: m.spec_acceptance_rate(),
        accepted_per_step: m.spec_accepted_per_step(),
        rollback_depth: m.spec_rollback_depth(),
    })
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("SA_QUICK").is_ok();
    let n_requests = if quick { 8 } else { 24 };

    let mut arms = Vec::new();
    for &k in &[0usize, 2, 4, 8] {
        arms.push(run_arm(k, n_requests)?);
    }

    let mut table = Table::new(&[
        "draft_k",
        "tok/s",
        "accept_rate",
        "accepted/step",
        "rollback/step",
        "decode_steps",
    ]);
    for arm in &arms {
        table.row(vec![
            arm.draft_k.to_string(),
            format!("{:.1}", arm.tokens_per_s()),
            format!("{:.3}", arm.acceptance_rate),
            format!("{:.2}", arm.accepted_per_step),
            format!("{:.2}", arm.rollback_depth),
            arm.decode_steps.to_string(),
        ]);
    }
    println!(
        "speculative decode sweep: {n_requests} requests x {MAX_NEW} new tokens \
         (prompt {PROMPT_LEN}, sim://tiny + sim://tiny-draft):"
    );
    table.print();

    // The point of the exercise: every speculative arm must land more than
    // one committed token per engine step, and the baseline arm must not
    // touch the speculation path at all.
    for arm in &arms {
        if arm.draft_k == 0 {
            assert_eq!(arm.spec_steps, 0, "draft_k=0 must run the plain decode path");
        } else {
            assert!(
                arm.accepted_per_step > 1.0,
                "draft_k={} accepted only {:.2} tokens/step",
                arm.draft_k,
                arm.accepted_per_step
            );
        }
    }

    let report = Json::obj(vec![
        ("bench", Json::str("spec_decode_sweep")),
        ("n_requests", Json::num(n_requests as f64)),
        ("prompt_len", Json::num(PROMPT_LEN as f64)),
        ("max_new", Json::num(MAX_NEW as f64)),
        ("arms", Json::Arr(arms.iter().map(|a| a.to_json()).collect())),
    ]);
    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/BENCH_spec.json", report.to_string())?;
    println!("wrote reports/BENCH_spec.json");
    Ok(())
}
