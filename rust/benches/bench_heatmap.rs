//! Figure 2 reproduction: cosine-similarity heatmap (layer × position),
//! averaged over prompts, plus the layer-mean profile and the k-means
//! grouping Algorithm 1 would produce.
//!
//! Output: reports/fig2_heatmap.csv (+ an ASCII rendering on stdout).
//! Expected shape (paper): early layers darker (low cosine = important),
//! second half lighter; first/last layers often special.

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{Engine, Request};
use squeezeattention::squeeze::kmeans_1d;
use squeezeattention::util::bench::Table;
use squeezeattention::workload::{TaskGen, ALL_TASKS};

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        eprintln!("SKIP bench_heatmap: run `make artifacts` first");
        return Ok(());
    }
    let n_prompts: usize =
        std::env::var("SA_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(10);

    let mut eng = Engine::new(ServeConfig::new("artifacts/tiny"))?;
    eng.enable_cosine_collection();
    let mut gen = TaskGen::new(2024);
    for i in 0..n_prompts {
        let task = ALL_TASKS[i % ALL_TASKS.len()];
        let s = gen.sample(task, 180);
        eng.generate_batch(vec![Request::new(i as u64, s.prompt, 2)]);
    }

    let stats = eng.cosine_stats().unwrap().clone();
    let n_layer = stats.n_layer();
    let means = stats.layer_means();

    // CSV: layer, then cosine per position.
    let max_pos = (0..n_layer).map(|l| stats.heatmap_row(l).len()).max().unwrap_or(0);
    let mut headers = vec!["layer".to_string()];
    headers.extend((0..max_pos).map(|p| format!("pos{p}")));
    let mut table = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for l in 0..n_layer {
        let row = stats.heatmap_row(l);
        let mut cells = vec![l.to_string()];
        cells.extend(
            (0..max_pos).map(|p| row.get(p).map(|v| format!("{v:.4}")).unwrap_or_default()),
        );
        table.row(cells);
    }
    table.write_csv("reports/fig2_heatmap.csv")?;
    println!(
        "wrote reports/fig2_heatmap.csv ({n_layer} layers x {max_pos} positions, {n_prompts} prompts)"
    );

    // ASCII heatmap: 1 char per 8 positions, darker = lower cosine.
    println!("\nFig.2 ASCII heatmap (rows=layers, dark=important):");
    let shades = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    for l in 0..n_layer {
        let row = stats.heatmap_row(l);
        let mut line = String::new();
        for chunk in row.chunks(8) {
            let vals: Vec<f64> = chunk.iter().copied().filter(|v| v.is_finite()).collect();
            if vals.is_empty() {
                line.push(' ');
                continue;
            }
            let m = vals.iter().sum::<f64>() / vals.len() as f64;
            // dark for LOW cosine (important layer)
            let idx = (((1.0 - m).clamp(0.0, 1.0)) * (shades.len() - 1) as f64).round() as usize;
            line.push(shades[idx]);
        }
        println!("  layer {l:2}  |{line}|  mean={:.4}", means[l]);
    }

    // k-means grouping (Algorithm 1 line 5).
    let clustering = kmeans_1d(&means, 3, 100);
    println!("\nlayer groups (G1=most important):");
    for g in 0..3 {
        let members = clustering.members(g);
        println!("  G{} ({} layers): {:?}", g + 1, members.len(), members);
    }
    let mut t2 = Table::new(&["layer", "mean_cosine", "group"]);
    for l in 0..n_layer {
        t2.row(vec![
            l.to_string(),
            format!("{:.5}", means[l]),
            (clustering.assignment[l] + 1).to_string(),
        ]);
    }
    t2.write_csv("reports/fig2_layer_means.csv")?;
    println!("wrote reports/fig2_layer_means.csv");
    Ok(())
}
