//! Table 3 + Table 9 reproduction: decode throughput vs batch size, Full
//! Cache vs best baseline vs +SqueezeAttention, including the OOM cells.
//!
//! Two views:
//!   (a) measured on the tiny model: batch sweep through the engine; the KV
//!       pool is capped so Full Cache hits OOM at large batch exactly like
//!       the paper's 40GB HBM wall — and the squeezed run binds a smaller
//!       capacity tier, so it also moves fewer bytes per step.
//!   (b) paper-scale projection (Mistral-7B to batch 224, Llama2-70B to 64).
//! Expected shape: Squeeze >= Full everywhere, diverging with batch;
//! Full/baseline OOM first. SA_QUICK=1 shrinks the sweep.

use squeezeattention::config::{PolicyKind, ServeConfig};
use squeezeattention::coordinator::Engine;
use squeezeattention::simulator::{simulate_decode, KvPolicy, A100_40GB_X8};
use squeezeattention::simulator::zoo::{LLAMA2_70B, MISTRAL_7B};
use squeezeattention::util::bench::Table;
use squeezeattention::workload::{evaluate, EvalSpec, Task};

fn fmt_tps(t: Option<f64>) -> String {
    t.map(|x| format!("{x:.1}")).unwrap_or_else(|| "OOM".into())
}

fn main() -> anyhow::Result<()> {
    // ---------------- (b) paper-scale projection --------------------------
    println!("Table 3 (paper-scale projection, tokens/s on 8xA100-40GB):");
    let mut proj = Table::new(&["model", "batch", "full", "squeeze@20-30%"]);
    for (model, batches, prompt, gen, frac) in [
        (&MISTRAL_7B, vec![1usize, 32, 64, 128, 224], 512usize, 1024usize, 0.2),
        (&LLAMA2_70B, vec![1, 8, 16, 32, 64], 256, 512, 0.3),
    ] {
        let b_init = ((prompt + gen) as f64 * frac) as usize;
        let squeezed = KvPolicy::squeeze(model.n_layer, model.n_layer / 2, b_init, 0.35);
        for b in batches {
            let full = simulate_decode(model, &A100_40GB_X8, &KvPolicy::Full, b, prompt, gen);
            let sq = simulate_decode(model, &A100_40GB_X8, &squeezed, b, prompt, gen);
            proj.row(vec![
                model.name.into(),
                b.to_string(),
                fmt_tps(full.tokens_per_s),
                fmt_tps(sq.tokens_per_s),
            ]);
        }
    }
    proj.print();
    proj.write_csv("reports/table3_projection.csv")?;

    // ---------------- (a) measured on the tiny model ----------------------
    if !std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        eprintln!("SKIP measured half: run `make artifacts` first");
        return Ok(());
    }
    let quick = std::env::var("SA_QUICK").is_ok();
    let batches: Vec<usize> = if quick { vec![4] } else { vec![1, 2, 4, 8] };
    let prompt_len = 128;
    let max_new = if quick { 12 } else { 24 };

    let mut eng = Engine::new(ServeConfig::new("artifacts/tiny"))?;
    // Compile every tier up front so no measured arm pays one-time XLA
    // compilation (the paper's numbers are steady-state too).
    eng.runtime().compile_all()?;
    // Pool sized so Full Cache OOMs at batch 8 (like the paper's HBM wall):
    // full cache needs ~ (128+24)*8slots*8layers*1KiB ≈ 9.7 MB; cap at 6 MB.
    let pool_cap = 6 * 1024 * 1024;
    let mut table = Table::new(&[
        "batch", "full tok/s", "baseline@30% tok/s", "squeeze@20% tok/s", "squeeze vs full",
    ]);
    for &b in &batches {
        let spec = EvalSpec::new(Task::Copy, 2 * b, prompt_len, max_new, 7);
        let mk = |policy: PolicyKind, frac: Option<f64>, squeeze: bool| {
            let mut cfg = ServeConfig::new("artifacts/tiny")
                .with_policy(policy)
                .with_squeeze(squeeze);
            cfg.max_batch = b;
            cfg.kv_pool_bytes = pool_cap;
            if let Some(f) = frac {
                cfg = cfg.with_budget_frac(f);
            }
            cfg
        };
        let full = evaluate(&mut eng, mk(PolicyKind::Full, None, false), &spec)?;
        let base = evaluate(&mut eng, mk(PolicyKind::SlidingWindow, Some(0.3), false), &spec)?;
        let sq = evaluate(&mut eng, mk(PolicyKind::SlidingWindow, Some(0.2), true), &spec)?;
        let cell = |r: &squeezeattention::workload::EvalResult| {
            if r.oom_requests > 0 {
                format!("OOM({}/{})", r.oom_requests, spec.n_requests)
            } else {
                format!("{:.1}", r.tokens_per_s)
            }
        };
        let speedup = if full.oom_requests > 0 {
            "∞ (full OOM)".to_string()
        } else {
            format!("{:.2}x", sq.tokens_per_s / full.tokens_per_s.max(1e-9))
        };
        println!(
            "batch {b}: full {} | baseline {} | squeeze {} | {}",
            cell(&full), cell(&base), cell(&sq), speedup
        );
        table.row(vec![b.to_string(), cell(&full), cell(&base), cell(&sq), speedup]);
    }
    println!("\nTable 3/9 (measured, tiny model, pool capped at {} MiB):", pool_cap >> 20);
    table.print();
    table.write_csv("reports/table3_measured.csv")?;
    Ok(())
}
