//! Telemetry overhead bench (emits `reports/BENCH_trace.json`).
//!
//! The observability contract is that `--trace-level off` costs nothing
//! measurable and even `full` (spans + per-phase timers + layer table)
//! stays under 5% of decode throughput. This bench drains the same closed
//! workload at each trace level, alternating arms A/B/A/B across repeats
//! so drift on a shared CI runner hits both arms equally, scores each arm
//! by its best repeat, and *asserts* `full >= 0.95 × off`.
//!
//! Runs entirely on the simulated backend (`sim://tiny`), deterministic
//! workload. `SA_QUICK=1` shrinks it.

use std::time::Instant;

use squeezeattention::config::ServeConfig;
use squeezeattention::coordinator::{Engine, FinishReason, Request};
use squeezeattention::metrics::TraceLevel;
use squeezeattention::util::bench::Table;
use squeezeattention::util::Json;
use squeezeattention::workload::TraceSpec;

const PROMPT_LEN: usize = 16;
const MAX_NEW: usize = 32;
/// `full` must keep at least this fraction of `off`'s best throughput.
const MAX_OVERHEAD: f64 = 0.05;

fn base_cfg() -> ServeConfig {
    ServeConfig::new("sim://tiny").with_budget(48).with_squeeze(false)
}

/// Drain one closed workload at the given trace level; returns
/// (tokens/s, spans recorded).
fn run_arm(level: TraceLevel, n_requests: usize) -> anyhow::Result<(f64, u64)> {
    let mut cfg = base_cfg();
    cfg.trace_level = level;
    let items = TraceSpec::closed(n_requests, PROMPT_LEN, MAX_NEW, 83).generate();
    let mut eng = Engine::new(cfg)?;
    let t0 = Instant::now();
    for (i, it) in items.iter().enumerate() {
        let req = Request::new(i as u64, it.sample.prompt.clone(), MAX_NEW);
        if let Err(rejected) = eng.submit(req) {
            anyhow::bail!("request {} rejected at submit: {:?}", i, rejected.finish);
        }
    }
    let mut outs = Vec::new();
    while eng.has_work() {
        outs.extend(eng.step()?);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    for o in &outs {
        if !matches!(o.finish, FinishReason::Eos | FinishReason::Length) {
            anyhow::bail!("request {} failed at level {}: {:?}", o.id, level.name(), o.finish);
        }
    }
    let tokens: u64 = outs.iter().map(|o| o.generated.len() as u64).sum();
    Ok((tokens as f64 / wall_s.max(1e-9), eng.recorder().total()))
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("SA_QUICK").is_ok();
    let n_requests = if quick { 8 } else { 32 };
    let repeats = if quick { 3 } else { 5 };
    let levels = [TraceLevel::Off, TraceLevel::Spans, TraceLevel::Full];

    // Warmup (allocator, page pool, branch predictors) — discarded.
    run_arm(TraceLevel::Full, n_requests)?;

    // Alternate arms within each repeat so runner drift is shared.
    let mut runs: Vec<Vec<f64>> = vec![Vec::new(); levels.len()];
    let mut spans: Vec<u64> = vec![0; levels.len()];
    for _ in 0..repeats {
        for (i, level) in levels.iter().enumerate() {
            let (tok_s, n_spans) = run_arm(*level, n_requests)?;
            runs[i].push(tok_s);
            spans[i] = n_spans;
        }
    }
    let best: Vec<f64> = runs.iter().map(|r| r.iter().cloned().fold(0.0, f64::max)).collect();
    let mean: Vec<f64> = runs.iter().map(|r| r.iter().sum::<f64>() / r.len() as f64).collect();

    let mut table = Table::new(&["level", "best tok/s", "mean tok/s", "spans", "vs off"]);
    for (i, level) in levels.iter().enumerate() {
        table.row(vec![
            level.name().to_string(),
            format!("{:.1}", best[i]),
            format!("{:.1}", mean[i]),
            spans[i].to_string(),
            format!("{:.1}%", 100.0 * (1.0 - best[i] / best[0].max(1e-9))),
        ]);
    }
    println!("trace-level overhead ({n_requests} requests, best of {repeats}):");
    table.print();

    // Sanity: `off` records nothing; `full` records spans for every request.
    assert_eq!(spans[0], 0, "trace-level off still recorded spans");
    assert!(spans[2] > 0, "trace-level full recorded no spans");

    let overhead = 1.0 - best[2] / best[0].max(1e-9);
    assert!(
        best[2] >= best[0] * (1.0 - MAX_OVERHEAD),
        "full tracing overhead {:.1}% exceeds the {:.0}% budget \
         (off {:.1} tok/s, full {:.1} tok/s)",
        100.0 * overhead,
        100.0 * MAX_OVERHEAD,
        best[0],
        best[2]
    );
    println!("full-tracing overhead {:.1}% (budget {:.0}%)", 100.0 * overhead.max(0.0), 5.0);

    let arms: Vec<Json> = levels
        .iter()
        .enumerate()
        .map(|(i, level)| {
            Json::obj(vec![
                ("level", Json::str(level.name())),
                ("best_tokens_per_s", Json::num(best[i])),
                ("mean_tokens_per_s", Json::num(mean[i])),
                ("spans_recorded", Json::num(spans[i] as f64)),
                ("runs", Json::Arr(runs[i].iter().map(|&t| Json::num(t)).collect())),
            ])
        })
        .collect();
    let report = Json::obj(vec![
        ("bench", Json::str("trace")),
        ("n_requests", Json::num(n_requests as f64)),
        ("repeats", Json::num(repeats as f64)),
        ("max_overhead_frac", Json::num(MAX_OVERHEAD)),
        ("full_overhead_frac", Json::num(overhead)),
        ("arms", Json::Arr(arms)),
    ]);
    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/BENCH_trace.json", report.to_string())?;
    println!("wrote reports/BENCH_trace.json");
    Ok(())
}
