//! Figure 4 reproduction: per-token decoding memory (KV bytes / token) for
//! Full Cache vs best baseline vs +SqueezeAttention.
//!
//! Two views:
//!   (a) measured on the tiny model through the real engine + KV pool;
//!   (b) paper-scale projection through the A100 cost model for the three
//!       Table-2 settings (Mistral-7B, GPT-NeoX-20B, Llama2-70B).
//! Expected shape: Full > baseline > Squeeze, with 70–80% saving vs Full.

use squeezeattention::config::{PolicyKind, ServeConfig};
use squeezeattention::coordinator::Engine;
use squeezeattention::simulator::{per_token_kv_bytes, KvPolicy};
use squeezeattention::simulator::zoo::{GPT_NEOX_20B, LLAMA2_70B, MISTRAL_7B};
use squeezeattention::util::bench::Table;
use squeezeattention::workload::{best_baseline_for, evaluate, EvalSpec, Task};

fn main() -> anyhow::Result<()> {
    // ---------------- (b) paper-scale projection (always runs) ------------
    // Paper settings from Table 2: budgets that preserved accuracy.
    let settings = [
        (&MISTRAL_7B, "SlidingWindow", 0.20, 0.30),
        (&GPT_NEOX_20B, "H2O", 0.20, 0.60),
        (&LLAMA2_70B, "StreamingLLM", 0.30, 0.40),
    ];
    let seq = 1536usize; // 512 prompt + 1024 gen, the Table-3 shape
    let mut proj = Table::new(&[
        "model", "baseline", "full B/tok", "baseline B/tok", "squeeze B/tok",
        "squeeze vs full", "squeeze vs baseline",
    ]);
    for (model, name, sq_frac, base_frac) in settings {
        let full = per_token_kv_bytes(model, &KvPolicy::Full, seq);
        let base = per_token_kv_bytes(
            model,
            &KvPolicy::Uniform { budget: (seq as f64 * base_frac) as usize },
            seq,
        );
        let sq_policy = KvPolicy::squeeze(
            model.n_layer,
            model.n_layer / 2,
            (seq as f64 * sq_frac) as usize,
            0.35,
        );
        let sq = per_token_kv_bytes(model, &sq_policy, seq);
        proj.row(vec![
            model.name.into(),
            name.into(),
            format!("{full:.0}"),
            format!("{base:.0}"),
            format!("{sq:.0}"),
            format!("-{:.0}%", (1.0 - sq / full) * 100.0),
            format!("-{:.0}%", (1.0 - sq / base) * 100.0),
        ]);
    }
    println!("Fig. 4 (paper-scale projection, per-token KV bytes at seq {seq}):");
    proj.print();
    proj.write_csv("reports/fig4_projection.csv")?;

    // ---------------- (a) measured on the tiny model ----------------------
    if !std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        eprintln!("SKIP measured half: run `make artifacts` first");
        return Ok(());
    }
    let mut eng = Engine::new(ServeConfig::new("artifacts/tiny"))?;
    let task = Task::Lookup;
    let spec = EvalSpec::new(task, 4, 160, 24, 99);
    let mk = |policy, frac: Option<f64>, squeeze| {
        let mut cfg = ServeConfig::new("artifacts/tiny").with_policy(policy).with_squeeze(squeeze);
        if let Some(f) = frac {
            cfg = cfg.with_budget_frac(f);
        }
        cfg
    };
    let arms = [
        ("full", mk(PolicyKind::Full, None, false)),
        ("baseline@30%", mk(best_baseline_for(task), Some(0.3), false)),
        ("squeeze@20%", mk(best_baseline_for(task), Some(0.2), true)),
    ];
    let mut measured = Table::new(&["arm", "peak KV bytes", "mean KV tokens/req", "bytes/gen-token"]);
    let mut rows = Vec::new();
    for (name, cfg) in arms {
        let r = evaluate(&mut eng, cfg, &spec)?;
        rows.push((name, r.peak_kv_bytes));
        measured.row(vec![
            name.into(),
            r.peak_kv_bytes.to_string(),
            format!("{:.0}", r.mean_kv_tokens),
            format!("{:.0}", r.peak_kv_bytes as f64 / r.generated_tokens.max(1) as f64),
        ]);
    }
    println!("\nFig. 4 (measured, tiny model through the engine pool):");
    measured.print();
    measured.write_csv("reports/fig4_measured.csv")?;
    let full = rows[0].1 as f64;
    for (name, b) in &rows[1..] {
        println!("  {name}: {:.0}% of full-cache peak", *b as f64 / full * 100.0);
    }
    Ok(())
}
