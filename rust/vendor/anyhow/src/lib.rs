//! Offline shim of the `anyhow` crate: the API subset this workspace uses,
//! implemented over a plain message string so the build needs no registry
//! access. Swap for the real crate by deleting this path dependency.
//!
//! Covered surface:
//! * `anyhow::Error` — `Display`/`Debug`, `{:#}` prints the context chain,
//!   `From<E: std::error::Error>` so `?` works on std error types.
//! * `anyhow::Result<T>` — alias with the usual default error parameter.
//! * `anyhow!` / `bail!` — format-style constructors.
//! * `Context` — `.context(..)` / `.with_context(..)` on `Result`, for any
//!   error type that implements `Display` (this includes `anyhow::Error`
//!   itself, mirroring the real crate's blanket behaviour).

use std::fmt;

/// A message-carrying error with an optional chain of context strings
/// (outermost first, like the real crate's `{:#}` rendering).
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Self {
        Self { msg: msg.into(), context: Vec::new() }
    }

    fn push_context(mut self, ctx: String) -> Self {
        self.context.push(ctx);
        self
    }

    /// Root-cause message (innermost), mirroring `Error::root_cause`'s role.
    pub fn root_cause_msg(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Outermost context is the headline, like real anyhow.
        match self.context.last() {
            Some(outer) if !f.alternate() => write!(f, "{outer}"),
            _ => {
                for ctx in self.context.iter().rev() {
                    write!(f, "{ctx}: ")?;
                }
                write!(f, "{}", self.msg)
            }
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for ctx in self.context.iter().rev() {
            write!(f, "{ctx}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket `From` below coherent (same trick as real anyhow).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| wrap(e).push_context(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| wrap(e).push_context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

fn wrap<E: fmt::Display>(e: E) -> Error {
    // Alternate form so wrapping an existing `Error` keeps its whole chain.
    Error::msg(format!("{e:#}"))
}

#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(::std::format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::string::ToString::to_string(&$err))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
        Ok(s)
    }

    #[test]
    fn question_mark_on_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("bad {} at {}", "thing", 7);
        assert_eq!(e.to_string(), "bad thing at 7");
        let inline = 42;
        let e2 = anyhow!("value {inline}");
        assert_eq!(e2.to_string(), "value 42");
    }

    #[test]
    fn bail_returns_err() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
    }

    #[test]
    fn context_chains_render_in_alternate() {
        let base: Result<()> = Err(anyhow!("root"));
        let e = base.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
        assert_eq!(format!("{e:?}"), "outer: root");
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| format!("while doing {}", "x")).unwrap_err();
        assert!(format!("{e:#}").starts_with("while doing x: "));
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(5).context("missing").unwrap(), 5);
    }
}
