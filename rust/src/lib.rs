//! # SqueezeAttention
//!
//! A reproduction of *SqueezeAttention: 2D Management of KV-Cache in LLM
//! Inference via Layer-wise Optimal Budget* (ICLR 2025) as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//! * **Layer 3 (this crate)** — the serving coordinator: a step-driven
//!   continuous-batching scheduler, a paged two-tier KV-cache pool
//!   (fixed-size ref-counted pages with copy-on-write prefix sharing,
//!   [`kvcache::PageTable`] / [`kvcache::PagedKvPool`]), sequence-wise
//!   eviction policies (Sliding Window / StreamingLLM / H2O), and the
//!   paper's layer-wise budget allocator driven by the cosine-similarity
//!   importance probe.
//! * **Layer 2** — a JAX transformer AOT-lowered to HLO-text artifacts
//!   (`python/compile/model.py`), executed via PJRT (`runtime`, behind the
//!   `pjrt` feature). The default build runs a deterministic simulated
//!   backend (`sim://tiny`) with the same interface, so the whole stack is
//!   testable without artifacts.
//! * **Layer 1** — Pallas kernels for prefill flash attention, budget-masked
//!   decode attention (which also emits the H2O signal), and the cosine
//!   probe (`python/compile/kernels/`).
//!
//! ## Scheduler architecture (admission → step → retire/suspend/resume)
//!
//! The engine no longer runs closed batches internally; it is driven one
//! decode step at a time by `Engine::step`, over the state machine in
//! [`coordinator::scheduler`] (submit → queue → running → suspended →
//! running):
//!
//! 1. **Submit** — `Engine::submit` enqueues a request (backpressure at
//!    `ServeConfig::queue_depth` produces an immediate `Rejected` output).
//! 2. **Admit** — between decode steps, free decode slots fill from two
//!    sources in priority order. *Suspended* sequences swap back in first:
//!    their bytes migrate host→device and decoding continues from
//!    `next_pos` with no prefill. Then *queued* requests prefill and join,
//!    KV-pool aware twice over: a pre-prefill headroom estimate
//!    (`min(b_init, prompt_len)` tokens per layer) skips wasted prefills
//!    while the pool is saturated, and the post-prefill `BudgetPlan`
//!    predicts the sequence's peak growth — a request that cannot fit
//!    *even alone* fails fast with `Oom`.
//! 3. **Step** — one batched decode over the occupied slots on the smallest
//!    capacity tier that fits; new KV rows are appended, charged to the
//!    pool, then each layer is re-compressed to its own budget (the paper's
//!    2-D management). With speculative decoding enabled (`--spec-k N`),
//!    each step becomes a *draft → verify → rollback* burst instead: a
//!    small draft model proposes up to `N` tokens per sequence against its
//!    own optimistically-appended KV rows, `SequenceCache::truncate` rolls
//!    those rows back, and the target model then verifies the proposals in
//!    batched one-token micro-steps that run the exact non-speculative
//!    commit path — so the output is token-identical to `--spec-k 0` under
//!    every eviction policy, and up to `N + 1` tokens land per engine step
//!    (the accepted prefix plus the verifier's own bonus token).
//! 4. **Lifecycle** — requests may carry an event sink, a cancel token,
//!    and a deadline ([`coordinator::lifecycle`]). The engine publishes a
//!    `RequestEvent` at every transition (admission, each decoded token,
//!    suspend/resume, terminal) and begins every step by retiring
//!    cancelled or deadline-expired requests from the queue, the decode
//!    slots, and the suspended set (`FinishReason::{Cancelled,
//!    DeadlineExceeded}`) — a cancel while swapped out frees the host tier
//!    without a swap-in. The TCP server's `"stream": true` mode forwards
//!    `Token` events as `{"id", "token", "pos"}` wire lines and cancels a
//!    connection's in-flight requests when the client disconnects.
//! 5. **Retire / suspend** — finished sequences (EOS or length) free their
//!    slot immediately, so waiting requests join the running batch on the
//!    next step. If a sequence cannot grow its reservation, the youngest
//!    *other* running sequence is preempted instead of failing anyone: with
//!    `ServeConfig::host_spill_bytes > 0` its post-eviction cache — already
//!    squeezed to each layer's budget, so the spilled bytes are minimal by
//!    construction — is *suspended* to the host tier together with its
//!    budget plan, H2O accumulators, and decode position, and later resumed
//!    token-identically; with the host tier full or disabled it is requeued
//!    for a restart-from-scratch (re-prefill, partial output discarded).
//!    `FinishReason::Oom` is reserved for requests that cannot fit with the
//!    pool otherwise empty, and `preemption = false` reproduces the paper's
//!    hard-OOM table cells.
//!
//! ## Paged KV allocation
//!
//! Both pool tiers are carved into fixed-size pages
//! (`ServeConfig::kv_page_bytes`, `--kv-page-bytes`, clamped up to one
//! token row). Every sequence holds a per-layer [`kvcache::PageTable`]
//! mapping slot ranges to ref-counted page ids; admission, per-step growth
//! and eviction shrink all move in whole-page quanta, so pool accounting
//! is page-quantized and the metrics snapshot exports allocated-vs-used
//! bytes per tier (fragmentation) alongside shared-page and copy-on-write
//! gauges. Suspend/resume is a page-table edit: only private
//! (refcount-1) pages migrate across the PCIe boundary, and a prefix
//! shared between tables via `PageTable::share_prefix` is charged to the
//! pool exactly once until a divergent write privatizes it.
//!
//! ## Decode hot-path data flow (batch-resident scratch)
//!
//! The per-step KV data flow is incremental, not re-built. Each decode tier
//! `(B, M)` owns one persistent scratch `(K, V)` buffer pair — the exact
//! tensors handed to the kernel — with per-slot residency records: which
//! sequence last filled the slot, at which cache generation, and how many
//! rows per layer are already valid. The steady-state step therefore runs
//!
//! ```text
//! SequenceCache ──(new rows only)──► resident scratch ──► Runtime::decode
//!      │ generation / dirty counters      │ per-slot (seq, gen, valid[])
//!      └── destructive op (retain /       └── mismatch ⇒ full refill of
//!          truncate / restore) bumps          just that slot
//!          the dirty watermark
//! ```
//!
//! appending O(rows-grown) bytes per slot instead of re-copying O(cache
//! size) every step. Anything destructive — eviction (`retain`),
//! speculative rollback (`truncate`), suspend/resume (`restore`),
//! preemption, slot reassignment, a tier change — invalidates residency
//! through the `SequenceCache` generation counters, checked at gather time,
//! so the optimization can never serve stale rows (COW page privatization
//! is pure accounting and needs no invalidation). Scratch tiers idle too
//! long are reclaimed; `kv_bytes_copied`, `gather_full_refills`,
//! `gather_incremental_appends`, and `scratch_retained_bytes` export via
//! [`metrics::SchedulerMetrics`], `--no-resident-scratch` forces the
//! always-refill baseline, and the `bench_hotpath` bench gates the win in
//! CI.
//!
//! `Engine::generate_batch` survives as a thin compatibility wrapper
//! (enqueue everything, drain the scheduler, sort by id) and is
//! token-identical to the step-driven path under greedy sampling — the
//! `scheduler_parity` integration test pins that equivalence. The router
//! drives one engine per worker thread step-by-step, so requests arriving
//! over TCP mid-batch are decoded alongside the ones already running (and,
//! with `batch_wait_ms`, near-simultaneous arrivals form one batch from the
//! first step); queue depth, batch occupancy, preemption and swap-out/in
//! counters are exported via [`metrics::SchedulerMetrics`], and the
//! suspend/resume lifecycle makes capped-pool serving cheap instead of
//! merely survivable. Per-request time-to-first-token and
//! inter-token-latency histograms ride along in each worker's snapshot and
//! are exported through `Router::metrics_json` (served over the wire via a
//! `{"metrics": true}` control line).
//!
//! ## Fault tolerance (supervision, bounded retry, load shedding)
//!
//! Serving survives its own failures; each fault is contained at the
//! smallest layer that can handle it, and the contract is uniform: *every
//! request gets exactly one terminal event, and pool bytes return to
//! baseline after drain*.
//!
//! ```text
//!    TCP client ──► server ──► router admission ──► worker ──► engine
//!                                   │                 │           │
//!        {"error":"overloaded",     │ queue depth /   │ thread    │ backend
//!         "retry_after_ms": N} ◄────┘ latency bound   │ death     │ step error
//!                                                     │           │
//!                            supervisor: synthesize   │           │ retry (≤
//!                            WorkerError terminals ◄──┘           │ max_retries)
//!                            for in-flight, re-route              │ or retire
//!                            queued jobs, bounded                 ▼ with
//!                            respawn w/ backoff            WorkerError
//! ```
//!
//! * **Engine level** ([`coordinator::engine`]): a backend error during a
//!   decode step never poisons the engine. Affected sequences are suspended
//!   (or requeued) and retried up to `ServeConfig::max_retries` times; a
//!   request whose budget is spent retires with
//!   `FinishReason::WorkerError`. RAII page-table ownership guarantees the
//!   failed step's reservations are released.
//! * **Worker level** ([`coordinator::supervisor`]): worker threads
//!   heartbeat; a panic trips a liveness guard and the supervisor thread
//!   fails the dead worker's in-flight requests with synthesized
//!   `WorkerError` terminals (no subscriber hangs), re-routes its
//!   queued-but-unstarted jobs, and respawns the engine with exponential
//!   backoff, bounded by `ServeConfig::max_worker_restarts`.
//! * **Router level** ([`coordinator::router`]): admission control sheds
//!   load with `RouteError::Overloaded` (+ a `retry_after_ms` hint derived
//!   from observed queue wait) when `shed_queue_depth` or
//!   `shed_queue_latency_ms` bounds are exceeded — rejected before any
//!   worker resource is consumed.
//!
//! Deterministic fault *injection* drives the chaos suite: `sim://` specs
//! accept a seeded [`config::FaultConfig`] (`--fault-step-error-rate`,
//! `--fault-latency-spike`, `--fault-oom-at`) whose decisions are a pure
//! function of (seed, call index), so every chaos run replays exactly.
//! `worker_restarts`, `worker_errors`, `requests_retried`, `requests_shed`,
//! and `faults_injected` export through [`metrics::SchedulerMetrics`] and
//! `Router::metrics_json`.
//!
//! ## Observability (trace spans, phase timing, squeeze introspection)
//!
//! Telemetry is layered on the same serving stack, gated by
//! `ServeConfig::trace_level` (`--trace-level {off,spans,full}`; `off`
//! costs one enum compare per would-be event):
//!
//! * **Trace spans** ([`metrics::FlightRecorder`]) — every request
//!   lifecycle transition (submit → admit → prefill → squeeze →
//!   first_token → suspend/resume/retry → retire) records a
//!   [`metrics::SpanEvent`] with a monotonic timestamp and the request's
//!   KV bytes at that moment into a bounded per-worker ring. Queryable live via the
//!   `{"trace": <id>}` wire control line (caller ids resolve through the
//!   router's ticket alias table).
//! * **Crash flight recorder** — the ring lives on the worker's shared
//!   state, not the engine, so it survives the engine thread. On a worker
//!   death, a contained `WorkerError`, or retry-budget exhaustion the ring
//!   is dumped as structured JSON (reason + full span history), printed to
//!   stderr and retained for the `{"flight_dump": <worker>}` control line.
//! * **Step-phase timing** (`--trace-level full`) — `Engine::step` is split
//!   into admission / gather / model / verify / evict / commit phases
//!   ([`metrics::StepPhase`]), each accumulated per step into reservoir
//!   histograms ([`metrics::PhaseTimers`]) answering "where does a decode
//!   millisecond go".
//! * **Per-layer squeeze introspection** ([`metrics::LayerTable`]) — each
//!   admitted sequence's resolved `BudgetPlan` (per-layer budgets, group
//!   assignment, cosine layer means) plus cumulative per-layer evicted
//!   rows/KV-bytes form a layer-indexed table: the live-server
//!   reconstruction of the paper's Figure-1 budget heatmap.
//! * **Prometheus exposition** ([`metrics::PromWriter`]) — the
//!   `{"metrics_prom": true}` control line renders every scheduler counter,
//!   latency/phase summary, per-layer series, and throughput window as
//!   text-format 0.0.4, wrapped in one JSON wire line.
//!
//! Quickstart (runs on the simulated backend — no artifacts needed):
//! ```
//! use squeezeattention::config::ServeConfig;
//! use squeezeattention::coordinator::{Engine, Request};
//!
//! let cfg = ServeConfig::new("sim://tiny");
//! let mut engine = Engine::new(cfg).unwrap();
//! let out = engine.generate_batch(vec![Request::new(0, vec![256, 5, 257], 16)]);
//! assert!(!out[0].generated.is_empty());
//! ```

pub mod config;
pub mod coordinator;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod simulator;
pub mod squeeze;
pub mod util;
pub mod workload;
