//! # SqueezeAttention
//!
//! A reproduction of *SqueezeAttention: 2D Management of KV-Cache in LLM
//! Inference via Layer-wise Optimal Budget* (ICLR 2025) as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//! * **Layer 3 (this crate)** — the serving coordinator: continuous
//!   batching, KV-cache pool, sequence-wise eviction policies (Sliding
//!   Window / StreamingLLM / H2O), and the paper's layer-wise budget
//!   allocator driven by the cosine-similarity importance probe.
//! * **Layer 2** — a JAX transformer AOT-lowered to HLO-text artifacts
//!   (`python/compile/model.py`), executed via PJRT (`runtime`).
//! * **Layer 1** — Pallas kernels for prefill flash attention, budget-masked
//!   decode attention (which also emits the H2O signal), and the cosine
//!   probe (`python/compile/kernels/`).
//!
//! Quickstart:
//! ```no_run
//! use squeezeattention::config::ServeConfig;
//! use squeezeattention::coordinator::{Engine, Request};
//!
//! let cfg = ServeConfig::new("artifacts/tiny");
//! let mut engine = Engine::new(cfg).unwrap();
//! let out = engine.generate_batch(vec![Request::new(0, vec![256, 5, 257], 16)]);
//! println!("{:?}", out[0].generated);
//! ```

pub mod config;
pub mod coordinator;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod simulator;
pub mod squeeze;
pub mod util;
pub mod workload;
