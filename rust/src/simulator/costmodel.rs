//! Analytic A100-scale cost model — the substitution for the paper's
//! 8×A100-40GB testbed (DESIGN.md §4).
//!
//! Decode on large models at batch is **memory-bandwidth bound**: each step
//! streams the (active) weights once plus every live KV byte. That is the
//! regime SqueezeAttention exploits (its savings are KV bytes), so a
//! bandwidth-roofline model preserves exactly the effect the paper measures:
//!
//!   t_step = (active_weights + Σ_seq kv_bytes(seq) + overhead) / (BW × eff)
//!   throughput = batch / t_step          (tokens/s)
//!   OOM ⇔ weights + peak KV > HBM
//!
//! It deliberately ignores compute (MLP flops at batch ≤ 224 stay under the
//! bandwidth roofline on A100) and prefill (amortized across the 512–1024
//! generated tokens in the paper's tables).


use super::zoo::ModelSpec;

/// Hardware description (defaults = the paper's p4d.24xlarge).
#[derive(Debug, Clone)]
pub struct Cluster {
    pub name: &'static str,
    pub n_gpus: usize,
    /// HBM bytes per GPU.
    pub hbm_bytes: f64,
    /// HBM bandwidth per GPU (bytes/s).
    pub hbm_bw: f64,
    /// Achievable fraction of peak bandwidth (measured A100 decode kernels
    /// typically reach 60–80%).
    pub efficiency: f64,
    /// Host-link (PCIe) bandwidth per GPU (bytes/s) for device↔host KV
    /// swaps. A100 PCIe 4.0 x16 peaks at 32 GB/s; ~25 GB/s is a realistic
    /// achieved rate. Swaps of a suspended sequence traverse one GPU's
    /// link, so this is deliberately *not* scaled by `n_gpus`.
    pub pcie_bw: f64,
}

pub const A100_40GB_X8: Cluster = Cluster {
    name: "8xA100-40GB",
    n_gpus: 8,
    hbm_bytes: 40e9,
    hbm_bw: 1.555e12,
    efficiency: 0.7,
    pcie_bw: 25e9,
};

pub const A100_40GB_X1: Cluster = Cluster {
    name: "1xA100-40GB",
    n_gpus: 1,
    hbm_bytes: 40e9,
    hbm_bw: 1.555e12,
    efficiency: 0.7,
    pcie_bw: 25e9,
};

impl Cluster {
    pub fn total_hbm(&self) -> f64 {
        self.hbm_bytes * self.n_gpus as f64
    }

    pub fn total_bw(&self) -> f64 {
        self.hbm_bw * self.n_gpus as f64 * self.efficiency
    }

    /// Seconds the host link needs to move `bytes` of KV between device
    /// and host memory (one direction; a full swap-out + swap-in cycle is
    /// two transfers — pass the summed traffic). This is the cost the
    /// two-tier pool's `migrated_into` counters meter, so swap-vs-restart
    /// projections stop treating suspension as free.
    pub fn swap_transfer_s(&self, bytes: f64) -> f64 {
        if self.pcie_bw <= 0.0 {
            0.0
        } else {
            bytes / self.pcie_bw
        }
    }
}

/// Per-layer KV budgets in tokens, after (or without) Squeeze reallocation.
#[derive(Debug, Clone)]
pub enum KvPolicy {
    /// Cache every token of every layer.
    Full,
    /// Every layer capped at the same budget (the sequence-wise baselines).
    Uniform { budget: usize },
    /// Explicit per-layer budgets (SqueezeAttention output).
    PerLayer { budgets: Vec<usize> },
}

impl KvPolicy {
    /// Mean cached tokens per layer when the sequence holds `tokens` tokens.
    pub fn cached_tokens_per_layer(&self, tokens: usize, n_layer: usize) -> f64 {
        match self {
            KvPolicy::Full => tokens as f64,
            KvPolicy::Uniform { budget } => tokens.min(*budget) as f64,
            KvPolicy::PerLayer { budgets } => {
                assert_eq!(budgets.len(), n_layer);
                budgets.iter().map(|&b| tokens.min(b) as f64).sum::<f64>() / n_layer as f64
            }
        }
    }

    /// KV bytes a sequence holding `tokens` tokens charges a *paged*
    /// allocator: each layer's cached tokens are rounded up to whole pages
    /// of `page_bytes` (clamped to at least one `token_bytes` row, like the
    /// engine does). The gap against the byte-exact
    /// `cached_tokens_per_layer` product is tail-page fragmentation — the
    /// quantity the serving engine's `kv_alloc_bytes` vs `kv_used_bytes`
    /// gauges expose.
    pub fn paged_kv_bytes(
        &self,
        tokens: usize,
        n_layer: usize,
        token_bytes: usize,
        page_bytes: usize,
    ) -> f64 {
        let pb = page_bytes.max(token_bytes.max(1));
        let spp = (pb / token_bytes.max(1)).max(1);
        let layer_bytes = |cached: usize| cached.div_ceil(spp) * pb;
        match self {
            KvPolicy::Full => (n_layer * layer_bytes(tokens)) as f64,
            KvPolicy::Uniform { budget } => (n_layer * layer_bytes(tokens.min(*budget))) as f64,
            KvPolicy::PerLayer { budgets } => {
                assert_eq!(budgets.len(), n_layer);
                let mut total = 0usize;
                for &b in budgets {
                    total += layer_bytes(tokens.min(b));
                }
                total as f64
            }
        }
    }

    /// Paper-style Squeeze budgets: `n_layer` layers, `unimportant` of them
    /// squeezed to `p × b_init`, the rest boosted so the total is conserved.
    pub fn squeeze(n_layer: usize, unimportant: usize, b_init: usize, p: f64) -> Self {
        assert!(unimportant < n_layer);
        let keep = n_layer - unimportant;
        let g3 = (b_init as f64 * p).round() as usize;
        let freed = n_layer * b_init - unimportant * g3;
        let boosted = freed / keep;
        let mut budgets = vec![boosted; keep];
        budgets.extend(std::iter::repeat(g3).take(unimportant));
        KvPolicy::PerLayer { budgets }
    }
}

/// Result of simulating one (model, batch, policy) point.
#[derive(Debug, Clone)]
pub struct SimPoint {
    pub batch: usize,
    /// tokens/s across the batch; None = OOM (the paper's table cells).
    pub tokens_per_s: Option<f64>,
    /// Peak KV bytes across the run.
    pub peak_kv_bytes: f64,
    /// Peak total HBM use (weights + KV).
    pub peak_hbm_bytes: f64,
}

/// Simulate steady-state decode of `batch` sequences generating `gen_len`
/// tokens after a `prompt_len` prompt.
pub fn simulate_decode(
    model: &ModelSpec,
    cluster: &Cluster,
    policy: &KvPolicy,
    batch: usize,
    prompt_len: usize,
    gen_len: usize,
) -> SimPoint {
    let per_layer_bytes = model.kv_bytes_per_token_layer();
    let n_layer = model.n_layer;

    // Peak KV: every sequence at its final length.
    let final_tokens = prompt_len + gen_len;
    let peak_per_seq =
        policy.cached_tokens_per_layer(final_tokens, n_layer) * n_layer as f64 * per_layer_bytes;
    let peak_kv = peak_per_seq * batch as f64;
    let peak_hbm = model.weight_bytes() + peak_kv;
    if peak_hbm > cluster.total_hbm() {
        return SimPoint {
            batch,
            tokens_per_s: None,
            peak_kv_bytes: peak_kv,
            peak_hbm_bytes: peak_hbm,
        };
    }

    // Integrate step time over the generation (KV grows until budgets clamp).
    let bw = cluster.total_bw();
    let mut total_time = 0.0f64;
    for step in 0..gen_len {
        let tokens = prompt_len + step;
        let kv_per_seq =
            policy.cached_tokens_per_layer(tokens, n_layer) * n_layer as f64 * per_layer_bytes;
        let bytes = model.active_weight_bytes() + kv_per_seq * batch as f64;
        total_time += bytes / bw;
    }
    let toks = (batch * gen_len) as f64;
    SimPoint {
        batch,
        tokens_per_s: Some(toks / total_time),
        peak_kv_bytes: peak_kv,
        peak_hbm_bytes: peak_hbm,
    }
}

/// Per-token decode memory (Fig. 4's metric): KV bytes actually held per
/// generated token at steady state, excluding weights.
pub fn per_token_kv_bytes(model: &ModelSpec, policy: &KvPolicy, seq_tokens: usize) -> f64 {
    policy.cached_tokens_per_layer(seq_tokens, model.n_layer) * model.n_layer as f64
        * model.kv_bytes_per_token_layer()
        / seq_tokens as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::zoo::{LLAMA2_70B, MISTRAL_7B};

    #[test]
    fn full_cache_ooms_before_squeeze() {
        // Mirror of Table 3: Mistral-7B, 512+1024, batch 224.
        let full = simulate_decode(&MISTRAL_7B, &A100_40GB_X8, &KvPolicy::Full, 224, 512, 1024);
        let squeezed = KvPolicy::squeeze(32, 16, (1536_f64 * 0.2) as usize, 0.35);
        let sq = simulate_decode(&MISTRAL_7B, &A100_40GB_X8, &squeezed, 224, 512, 1024);
        assert!(sq.tokens_per_s.is_some());
        assert!(sq.peak_kv_bytes < full.peak_kv_bytes * 0.5);
    }

    #[test]
    fn throughput_monotone_in_batch_until_oom() {
        let mut last = 0.0;
        for batch in [1usize, 8, 16, 32] {
            let p = simulate_decode(&LLAMA2_70B, &A100_40GB_X8, &KvPolicy::Full, batch, 256, 512);
            if let Some(t) = p.tokens_per_s {
                assert!(t > last, "batch {batch}: {t} <= {last}");
                last = t;
            }
        }
    }

    #[test]
    fn uniform_budget_caps_memory() {
        let uncapped = per_token_kv_bytes(&MISTRAL_7B, &KvPolicy::Full, 1536);
        let capped = per_token_kv_bytes(&MISTRAL_7B, &KvPolicy::Uniform { budget: 307 }, 1536);
        assert!(capped < uncapped * 0.25);
    }

    #[test]
    fn squeeze_policy_conserves_total() {
        let KvPolicy::PerLayer { budgets } = KvPolicy::squeeze(32, 14, 1000, 0.3) else {
            panic!()
        };
        let total: usize = budgets.iter().sum();
        // Conserved up to integer rounding (floor on boosted).
        assert!((total as i64 - 32_000).abs() < 32, "{total}");
        // Appendix A.2: unimportant 300, important ~1544.
        assert_eq!(budgets[31], 300);
        assert!(budgets[0] == 1544 || budgets[0] == 1545);
    }

    #[test]
    fn paged_bytes_round_up_to_whole_pages() {
        let token = 1024; // sim://tiny row: 128 elems × 2 tensors × 4 bytes
        let page = 16 * 1024; // 16 slots per page
        let p = KvPolicy::Uniform { budget: 48 };
        // 48 cached tokens -> exactly 3 pages per layer.
        assert_eq!(p.paged_kv_bytes(100, 8, token, page), (8 * 3 * page) as f64);
        // 17 cached tokens -> 2 pages per layer (one slot into the second).
        assert_eq!(p.paged_kv_bytes(17, 1, token, page), (2 * page) as f64);
        // Byte-exact accounting is a lower bound (fragmentation is the gap).
        let exact = p.cached_tokens_per_layer(17, 1) * token as f64;
        assert!(p.paged_kv_bytes(17, 1, token, page) >= exact);
        // Per-layer budgets quantize layer by layer, not on the mean.
        let pl = KvPolicy::PerLayer { budgets: vec![1, 31] };
        assert_eq!(pl.paged_kv_bytes(100, 2, token, page), (3 * page) as f64);
        // Degenerate page sizes clamp up to one token row.
        assert_eq!(pl.paged_kv_bytes(1, 2, token, 8), (2 * token) as f64);
    }

    #[test]
    fn swap_transfer_priced_by_pcie_bw() {
        // 1 GiB over a 25 GB/s link ≈ 43 ms — far from free next to a
        // decode step, which is the point of pricing it.
        let t = A100_40GB_X1.swap_transfer_s(1024.0 * 1024.0 * 1024.0);
        assert!((t - 1073741824.0 / 25e9).abs() < 1e-12);
        assert!(t > 0.04 && t < 0.05, "{t}");
        // Multi-GPU clusters do not parallelize a single sequence's swap.
        assert_eq!(
            A100_40GB_X8.swap_transfer_s(1e9),
            A100_40GB_X1.swap_transfer_s(1e9)
        );
        // Degenerate link: free (models the accounting-only sim default).
        let free = Cluster { pcie_bw: 0.0, ..A100_40GB_X1 };
        assert_eq!(free.swap_transfer_s(1e12), 0.0);
    }

    #[test]
    fn full_cache_oom_at_large_batch_llama70b() {
        // Table 3: Llama2-70B full cache OOMs at batch 64 (256+512).
        let p = simulate_decode(&LLAMA2_70B, &A100_40GB_X8, &KvPolicy::Full, 64, 256, 512);
        // 70B weights ~140GB; KV at 64x768 tokens... paper observed OOM.
        // Our model may or may not cross 320GB exactly; assert the weaker
        // property that the squeezed variant fits with margin.
        let squeezed = KvPolicy::squeeze(80, 48, 230, 0.35);
        let sq = simulate_decode(&LLAMA2_70B, &A100_40GB_X8, &squeezed, 64, 256, 512);
        assert!(sq.tokens_per_s.is_some());
        assert!(sq.peak_hbm_bytes <= p.peak_hbm_bytes);
    }
}
