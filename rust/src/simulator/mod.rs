//! Paper-scale substrate: an analytic A100 memory/bandwidth model over the
//! paper's model zoo. Used by the benches to project Tables 2/3/9 and
//! Fig. 4 at the scales the paper ran (we have no A100s here); the *measured*
//! counterparts run on the tiny model through the real engine.

pub mod costmodel;
pub mod zoo;

pub use costmodel::{per_token_kv_bytes, simulate_decode, Cluster, KvPolicy, SimPoint,
                    A100_40GB_X1, A100_40GB_X8};
pub use zoo::{by_name, ModelSpec, ZOO};
