//! The paper's model zoo as shape specs for the analytic cost model.
//!
//! Sources: model cards / config.json of each checkpoint. `kv_dim` is the
//! *per-layer* K (or V) width actually cached: `n_kv_heads × head_dim` —
//! GQA/MQA models cache far less than d_model.


#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: &'static str,
    pub n_layer: usize,
    pub d_model: usize,
    /// n_kv_heads * head_dim (per-layer cached width for K or V).
    pub kv_dim: usize,
    /// Total parameters (for weight-traffic and HBM residency).
    pub n_params: f64,
    /// Parameters touched per token (≠ n_params for MoE).
    pub active_params: f64,
    /// Cache/weight dtype bytes (paper: FP16).
    pub dtype_bytes: usize,
}

impl ModelSpec {
    /// KV-cache bytes per cached token across all layers (K+V).
    pub fn kv_bytes_per_token(&self) -> f64 {
        (2 * self.kv_dim * self.dtype_bytes * self.n_layer) as f64
    }

    /// Per-layer KV bytes per token (K+V).
    pub fn kv_bytes_per_token_layer(&self) -> f64 {
        (2 * self.kv_dim * self.dtype_bytes) as f64
    }

    pub fn weight_bytes(&self) -> f64 {
        self.n_params * self.dtype_bytes as f64
    }

    pub fn active_weight_bytes(&self) -> f64 {
        self.active_params * self.dtype_bytes as f64
    }
}

pub const MISTRAL_7B: ModelSpec = ModelSpec {
    name: "Mistral-7B",
    n_layer: 32,
    d_model: 4096,
    kv_dim: 1024, // 8 kv heads x 128
    n_params: 7.24e9,
    active_params: 7.24e9,
    dtype_bytes: 2,
};

pub const LLAMA2_7B: ModelSpec = ModelSpec {
    name: "Llama2-7B",
    n_layer: 32,
    d_model: 4096,
    kv_dim: 4096, // MHA
    n_params: 6.74e9,
    active_params: 6.74e9,
    dtype_bytes: 2,
};

pub const LLAMA2_70B: ModelSpec = ModelSpec {
    name: "Llama2-70B",
    n_layer: 80,
    d_model: 8192,
    kv_dim: 1024, // 8 kv heads x 128 (GQA)
    n_params: 6.9e10,
    active_params: 6.9e10,
    dtype_bytes: 2,
};

pub const FALCON_7B: ModelSpec = ModelSpec {
    name: "Falcon-7B",
    n_layer: 32,
    d_model: 4544,
    kv_dim: 64, // MQA: 1 kv head x 64
    n_params: 7.22e9,
    active_params: 7.22e9,
    dtype_bytes: 2,
};

pub const OPT_6_7B: ModelSpec = ModelSpec {
    name: "OPT-6.7B",
    n_layer: 32,
    d_model: 4096,
    kv_dim: 4096, // MHA
    n_params: 6.7e9,
    active_params: 6.7e9,
    dtype_bytes: 2,
};

pub const GPT_NEOX_20B: ModelSpec = ModelSpec {
    name: "GPT-NeoX-20B",
    n_layer: 44,
    d_model: 6144,
    kv_dim: 6144, // MHA
    n_params: 2.05e10,
    active_params: 2.05e10,
    dtype_bytes: 2,
};

pub const MIXTRAL_8X7B: ModelSpec = ModelSpec {
    name: "Mixtral-8x7B",
    n_layer: 32,
    d_model: 4096,
    kv_dim: 1024,
    n_params: 4.67e10,
    active_params: 1.29e10, // 2-of-8 experts
    dtype_bytes: 2,
};

pub const ZOO: [&ModelSpec; 7] = [
    &MISTRAL_7B,
    &LLAMA2_7B,
    &LLAMA2_70B,
    &FALCON_7B,
    &OPT_6_7B,
    &GPT_NEOX_20B,
    &MIXTRAL_8X7B,
];

pub fn by_name(name: &str) -> Option<&'static ModelSpec> {
    ZOO.iter().copied().find(|m| m.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama2_7b_paper_number() {
        // Paper §2.1: Llama-2-7B FP16 KV ≈ 0.5 MB per token.
        let b = LLAMA2_7B.kv_bytes_per_token();
        assert!((b - 524_288.0).abs() < 1.0, "{b}");
    }

    #[test]
    fn gqa_models_cache_less() {
        assert!(MISTRAL_7B.kv_bytes_per_token() < LLAMA2_7B.kv_bytes_per_token() / 3.0);
        assert!(FALCON_7B.kv_bytes_per_token() < MISTRAL_7B.kv_bytes_per_token());
    }

    #[test]
    fn zoo_lookup() {
        assert!(by_name("mistral-7b").is_some());
        assert!(by_name("gpt-neox-20b").is_some());
        assert!(by_name("nope").is_none());
    }
}
