//! Synthetic evaluation workloads — rust mirror of `python/compile/tasks.py`.
//!
//! Five tasks stand in for the paper's five datasets (DESIGN.md §4). Each
//! sample carries its exact expected answer, so generation quality is a
//! deterministic exact-match rate rather than ROUGE. The token-level formats
//! are identical to the python generators the model was trained on; only the
//! RNG streams differ (the two sides need to agree on distribution, not on
//! draws).

use crate::model::tokenizer::*;
use crate::util::Rng;

/// The five evaluation tasks (≈ the paper's five datasets).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    /// Repeat the payload after SEP (≈ SAMSUM few-shot; recency+induction).
    Copy,
    /// key=value store, answer one queried key (≈ TriviaQA/NarrativeQA).
    Lookup,
    /// Repeat only MARK-ed tokens (≈ summarization heavy-hitters).
    Selective,
    /// Repeat the first FIRST_K payload tokens (sink-token stress).
    First,
    /// Deterministic 2nd-order recurrence with noise (≈ local-structure LM).
    Lm,
}

pub const ALL_TASKS: [Task; 5] =
    [Task::Copy, Task::Lookup, Task::Selective, Task::First, Task::Lm];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::Copy => "copy",
            Task::Lookup => "lookup",
            Task::Selective => "selective",
            Task::First => "first",
            Task::Lm => "lm",
        }
    }

    pub fn parse(s: &str) -> Option<Task> {
        ALL_TASKS.iter().copied().find(|t| t.name() == s)
    }
}

/// One evaluation sample: a prompt and the exact expected continuation.
#[derive(Debug, Clone)]
pub struct Sample {
    pub task: Task,
    pub prompt: Vec<i32>,
    pub answer: Vec<i32>,
}

/// Deterministic component of the `lm` task (mirror of tasks.py::lm_next).
pub fn lm_next(a: i32, b: i32) -> i32 {
    ((a * 31 + b * 17 + 7) % LM_MOD) + 1
}

/// Deterministic workload generator.
pub struct TaskGen {
    rng: Rng,
}

impl TaskGen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::seed_from_u64(seed) }
    }

    fn word(&mut self) -> i32 {
        self.rng.range_i32(WORD_LO, WORD_HI)
    }

    pub fn gen_copy(&mut self, payload_len: usize) -> Sample {
        let words: Vec<i32> = (0..payload_len).map(|_| self.word()).collect();
        let mut prompt = vec![BOS];
        prompt.extend(&words);
        prompt.push(SEP);
        let mut answer = words;
        answer.push(EOS);
        Sample { task: Task::Copy, prompt, answer }
    }

    pub fn gen_lookup(&mut self, n_pairs: usize) -> Sample {
        let n_pairs = n_pairs.min((KEY_HI - KEY_LO + 1) as usize);
        // distinct keys via partial shuffle
        let mut keys: Vec<i32> = (KEY_LO..=KEY_HI).collect();
        for i in 0..n_pairs {
            let j = self.rng.range(i, keys.len());
            keys.swap(i, j);
        }
        keys.truncate(n_pairs);
        let vals: Vec<i32> =
            (0..n_pairs).map(|_| self.rng.range_i32(VAL_LO, VAL_HI)).collect();
        let mut prompt = vec![BOS];
        for (k, v) in keys.iter().zip(&vals) {
            prompt.extend([*k, EQUALS, *v, COMMA]);
        }
        let qi = self.rng.below(n_pairs);
        prompt.extend([QUERY, keys[qi], ANSWER]);
        Sample { task: Task::Lookup, prompt, answer: vec![vals[qi], EOS] }
    }

    pub fn gen_selective(&mut self, payload_len: usize, n_marks: usize) -> Sample {
        let n_marks = n_marks.min(payload_len);
        // choose n_marks distinct positions
        let mut pos: Vec<usize> = (0..payload_len).collect();
        for i in 0..n_marks {
            let j = self.rng.range(i, pos.len());
            pos.swap(i, j);
        }
        let mut marked_pos = pos[..n_marks].to_vec();
        marked_pos.sort_unstable();
        let words: Vec<i32> = (0..payload_len).map(|_| self.word()).collect();
        let mut prompt = vec![BOS];
        let mut answer = Vec::new();
        let mut mi = 0usize;
        for (i, &w) in words.iter().enumerate() {
            if mi < marked_pos.len() && marked_pos[mi] == i {
                prompt.push(MARK);
                answer.push(w);
                mi += 1;
            }
            prompt.push(w);
        }
        prompt.push(SEP);
        answer.push(EOS);
        Sample { task: Task::Selective, prompt, answer }
    }

    pub fn gen_first(&mut self, payload_len: usize) -> Sample {
        let words: Vec<i32> = (0..payload_len).map(|_| self.word()).collect();
        let mut prompt = vec![BOS];
        prompt.extend(&words);
        prompt.push(QUERY);
        let mut answer: Vec<i32> = words[..FIRST_K.min(words.len())].to_vec();
        answer.push(EOS);
        Sample { task: Task::First, prompt, answer }
    }

    /// `lm` sample: prompt is a generated chain; the expected continuation is
    /// the deterministic recurrence (answer_len tokens, no EOS).
    pub fn gen_lm(&mut self, prompt_len: usize, answer_len: usize) -> Sample {
        let mut seq = vec![
            self.rng.range_i32(1, LM_MOD),
            self.rng.range_i32(1, LM_MOD),
        ];
        while seq.len() < prompt_len - 1 {
            if self.rng.bool(0.1) {
                seq.push(self.rng.range_i32(1, LM_MOD));
            } else {
                let n = lm_next(seq[seq.len() - 1], seq[seq.len() - 2]);
                seq.push(n);
            }
        }
        // expected continuation = pure deterministic recurrence
        let mut answer = Vec::with_capacity(answer_len);
        let (mut a, mut b) = (seq[seq.len() - 1], seq[seq.len() - 2]);
        for _ in 0..answer_len {
            let n = lm_next(a, b);
            answer.push(n);
            b = a;
            a = n;
        }
        let mut prompt = vec![BOS];
        prompt.extend(seq);
        Sample { task: Task::Lm, prompt, answer }
    }

    /// Sample a task instance sized to roughly `approx_prompt_len` tokens
    /// (mirror of tasks.py::sample).
    pub fn sample(&mut self, task: Task, approx_prompt_len: usize) -> Sample {
        let n = approx_prompt_len.max(8);
        match task {
            Task::Copy => self.gen_copy(n.saturating_sub(2).max(4)),
            Task::Lookup => self.gen_lookup(((n - 4) / 4).max(2)),
            Task::Selective => {
                let pl = ((n as f64 - 2.0) / 1.25) as usize;
                let pl = pl.max(8);
                self.gen_selective(pl, (pl / 8).max(2))
            }
            Task::First => self.gen_first(n - 2),
            Task::Lm => self.gen_lm(n - 1, 16),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_layout() {
        let mut g = TaskGen::new(0);
        let s = g.gen_copy(5);
        assert_eq!(s.prompt.len(), 7);
        assert_eq!(s.prompt[0], BOS);
        assert_eq!(s.prompt[6], SEP);
        assert_eq!(&s.answer[..5], &s.prompt[1..6]);
        assert_eq!(*s.answer.last().unwrap(), EOS);
    }

    #[test]
    fn lookup_answer_is_queried_value() {
        let mut g = TaskGen::new(1);
        for _ in 0..20 {
            let s = g.gen_lookup(8);
            let q = s.prompt[s.prompt.len() - 2];
            // find q's value in the body
            let mut val = None;
            let mut i = 1;
            while s.prompt[i] != QUERY {
                if s.prompt[i] == q && s.prompt[i + 1] == EQUALS {
                    val = Some(s.prompt[i + 2]);
                }
                i += 4;
            }
            assert_eq!(s.answer[0], val.expect("query key present"));
            assert_eq!(s.answer[1], EOS);
        }
    }

    #[test]
    fn lookup_keys_distinct() {
        let mut g = TaskGen::new(2);
        let s = g.gen_lookup(48);
        let mut keys: Vec<i32> = s.prompt[1..]
            .chunks(4)
            .take_while(|c| c.len() == 4 && c[1] == EQUALS)
            .map(|c| c[0])
            .collect();
        let n = keys.len();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), n);
    }

    #[test]
    fn selective_answer_matches_marks() {
        let mut g = TaskGen::new(3);
        let s = g.gen_selective(20, 4);
        let mut expect = Vec::new();
        for (i, &t) in s.prompt.iter().enumerate() {
            if t == MARK {
                expect.push(s.prompt[i + 1]);
            }
        }
        expect.push(EOS);
        assert_eq!(s.answer, expect);
        assert_eq!(expect.len(), 5);
    }

    #[test]
    fn first_answer_prefix() {
        let mut g = TaskGen::new(4);
        let s = g.gen_first(30);
        assert_eq!(&s.answer[..FIRST_K], &s.prompt[1..1 + FIRST_K]);
    }

    #[test]
    fn lm_recurrence_consistency() {
        assert_eq!(lm_next(1, 1), ((31 + 17 + 7) % 96) + 1);
        let mut g = TaskGen::new(5);
        let s = g.gen_lm(64, 8);
        // continuation must follow the recurrence seeded by prompt tail
        let n = s.prompt.len();
        let (a, b) = (s.prompt[n - 1], s.prompt[n - 2]);
        assert_eq!(s.answer[0], lm_next(a, b));
        assert_eq!(s.answer[1], lm_next(s.answer[0], a));
    }

    #[test]
    fn sample_sizes_roughly_match() {
        let mut g = TaskGen::new(6);
        for task in ALL_TASKS {
            let s = g.sample(task, 100);
            assert!(
                (s.prompt.len() as i64 - 100).abs() < 40,
                "{}: prompt len {}",
                task.name(),
                s.prompt.len()
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = TaskGen::new(42).gen_copy(10);
        let b = TaskGen::new(42).gen_copy(10);
        assert_eq!(a.prompt, b.prompt);
    }
}
