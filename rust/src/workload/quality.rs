//! Exact-match answer scoring — the deterministic stand-in for ROUGE/F1.

use crate::model::tokenizer::EOS;

use super::tasks::Sample;

/// Fraction of expected answer tokens the generation got right, position by
/// position, stopping at the expected answer's end. An early EOS truncates
/// credit; extra tokens after the expected answer are not penalized (the
/// paper's metrics are recall-flavored too).
pub fn answer_accuracy(sample: &Sample, generated: &[i32]) -> f64 {
    if sample.answer.is_empty() {
        return f64::NAN;
    }
    let mut hit = 0usize;
    for (i, &want) in sample.answer.iter().enumerate() {
        match generated.get(i) {
            Some(&got) if got == want => hit += 1,
            _ => {}
        }
    }
    hit as f64 / sample.answer.len() as f64
}

/// Strict exact match of the full answer (including EOS position).
pub fn exact_match(sample: &Sample, generated: &[i32]) -> bool {
    generated.len() >= sample.answer.len()
        && generated[..sample.answer.len()] == sample.answer[..]
}

/// Mean accuracy over (sample, generation) pairs, NaN-skipping.
pub fn mean_accuracy(pairs: &[(Sample, Vec<i32>)]) -> f64 {
    let scores: Vec<f64> = pairs
        .iter()
        .map(|(s, g)| answer_accuracy(s, g))
        .filter(|a| a.is_finite())
        .collect();
    if scores.is_empty() {
        return f64::NAN;
    }
    scores.iter().sum::<f64>() / scores.len() as f64
}

/// Trim generation at (and including) the first EOS for display.
pub fn trim_at_eos(generated: &[i32]) -> &[i32] {
    match generated.iter().position(|&t| t == EOS) {
        Some(i) => &generated[..=i],
        None => generated,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::tasks::{Task, TaskGen};

    fn sample_with_answer(answer: Vec<i32>) -> Sample {
        Sample { task: Task::Copy, prompt: vec![], answer }
    }

    #[test]
    fn perfect_match() {
        let s = sample_with_answer(vec![1, 2, 3, EOS]);
        assert_eq!(answer_accuracy(&s, &[1, 2, 3, EOS, 9, 9]), 1.0);
        assert!(exact_match(&s, &[1, 2, 3, EOS]));
    }

    #[test]
    fn partial_match() {
        let s = sample_with_answer(vec![1, 2, 3, 4]);
        assert_eq!(answer_accuracy(&s, &[1, 9, 3, 9]), 0.5);
        assert!(!exact_match(&s, &[1, 9, 3, 9]));
    }

    #[test]
    fn short_generation() {
        let s = sample_with_answer(vec![1, 2, 3, 4]);
        assert_eq!(answer_accuracy(&s, &[1]), 0.25);
    }

    #[test]
    fn trim() {
        assert_eq!(trim_at_eos(&[1, 2, EOS, 7]), &[1, 2, EOS]);
        assert_eq!(trim_at_eos(&[1, 2]), &[1, 2]);
    }

    #[test]
    fn mean_over_tasks() {
        let mut g = TaskGen::new(0);
        let s1 = g.gen_copy(3);
        let perfect = s1.answer.clone();
        let s2 = g.gen_copy(3);
        let wrong = vec![0; 4];
        let m = mean_accuracy(&[(s1, perfect), (s2, wrong)]);
        assert!((m - 0.5).abs() < 1e-9);
    }
}
