//! Request-arrival trace generation for serving experiments.
//!
//! The paper's throughput tables use closed batches (all requests present at
//! t=0); its serving context also motivates open-loop arrivals. Both are
//! supported: `TraceSpec::closed` replays a fixed batch, `TraceSpec::poisson`
//! draws exponential inter-arrival gaps.

use crate::util::Rng;

use super::tasks::{Sample, Task, TaskGen, ALL_TASKS};

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct TraceItem {
    /// Arrival time offset from trace start (seconds).
    pub arrival_s: f64,
    pub sample: Sample,
    pub max_new_tokens: usize,
}

#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub n_requests: usize,
    /// Tasks to draw from (round-robin); empty = all five.
    pub tasks: Vec<Task>,
    pub approx_prompt_len: usize,
    pub max_new_tokens: usize,
    /// Requests per second; 0 = closed (all arrive at t=0).
    pub arrival_rate: f64,
    pub seed: u64,
}

impl TraceSpec {
    pub fn closed(n: usize, prompt_len: usize, max_new: usize, seed: u64) -> Self {
        Self {
            n_requests: n,
            tasks: vec![],
            approx_prompt_len: prompt_len,
            max_new_tokens: max_new,
            arrival_rate: 0.0,
            seed,
        }
    }

    pub fn poisson(mut self, rate: f64) -> Self {
        self.arrival_rate = rate;
        self
    }

    pub fn with_tasks(mut self, tasks: &[Task]) -> Self {
        self.tasks = tasks.to_vec();
        self
    }

    pub fn generate(&self) -> Vec<TraceItem> {
        let mut gen = TaskGen::new(self.seed);
        let mut rng = Rng::seed_from_u64(self.seed ^ 0x9e3779b97f4a7c15);
        let tasks = if self.tasks.is_empty() { ALL_TASKS.to_vec() } else { self.tasks.clone() };
        let mut t = 0.0f64;
        (0..self.n_requests)
            .map(|i| {
                let task = tasks[i % tasks.len()];
                let sample = gen.sample(task, self.approx_prompt_len);
                if self.arrival_rate > 0.0 {
                    t += rng.exp(self.arrival_rate);
                }
                TraceItem { arrival_s: t, sample, max_new_tokens: self.max_new_tokens }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_trace_all_at_zero() {
        let items = TraceSpec::closed(6, 64, 16, 0).generate();
        assert_eq!(items.len(), 6);
        assert!(items.iter().all(|i| i.arrival_s == 0.0));
        // round-robin over all 5 tasks
        assert_eq!(items[0].sample.task, ALL_TASKS[0]);
        assert_eq!(items[4].sample.task, ALL_TASKS[4]);
        assert_eq!(items[5].sample.task, ALL_TASKS[0]);
    }

    #[test]
    fn poisson_monotone_arrivals() {
        let items = TraceSpec::closed(20, 64, 16, 1).poisson(5.0).generate();
        for w in items.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        assert!(items.last().unwrap().arrival_s > 0.0);
    }

    #[test]
    fn deterministic() {
        let a = TraceSpec::closed(5, 64, 16, 7).generate();
        let b = TraceSpec::closed(5, 64, 16, 7).generate();
        assert_eq!(a[3].sample.prompt, b[3].sample.prompt);
    }

    #[test]
    fn task_filter() {
        let items = TraceSpec::closed(4, 64, 16, 0)
            .with_tasks(&[Task::Lookup])
            .generate();
        assert!(items.iter().all(|i| i.sample.task == Task::Lookup));
    }
}
