//! Synthetic workloads: the five evaluation tasks and arrival traces.

pub mod eval;
pub mod quality;
pub mod tasks;
pub mod trace;

pub use eval::{best_baseline_for, evaluate, EvalResult, EvalSpec};
pub use quality::{answer_accuracy, exact_match, mean_accuracy, trim_at_eos};
pub use tasks::{lm_next, Sample, Task, TaskGen, ALL_TASKS};
pub use trace::{TraceItem, TraceSpec};
