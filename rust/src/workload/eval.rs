//! Evaluation harness shared by the paper-reproduction benches and examples:
//! run a task workload through an engine configuration and report accuracy +
//! serving metrics.

use crate::config::ServeConfig;
use crate::coordinator::{Engine, FinishReason, Request};
use crate::metrics::Histogram;

use super::quality::answer_accuracy;
use super::tasks::Task;
use super::trace::TraceSpec;

/// One evaluation workload.
#[derive(Debug, Clone)]
pub struct EvalSpec {
    pub tasks: Vec<Task>,
    pub n_requests: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    pub seed: u64,
}

impl EvalSpec {
    pub fn new(task: Task, n: usize, prompt_len: usize, max_new: usize, seed: u64) -> Self {
        Self { tasks: vec![task], n_requests: n, prompt_len, max_new, seed }
    }

    pub fn mixed(n: usize, prompt_len: usize, max_new: usize, seed: u64) -> Self {
        Self { tasks: vec![], n_requests: n, prompt_len, max_new, seed }
    }
}

/// Aggregate result of one evaluation run.
#[derive(Debug, Clone)]
pub struct EvalResult {
    /// Mean answer accuracy over scoreable requests.
    pub accuracy: f64,
    /// Generated tokens per wall-second.
    pub tokens_per_s: f64,
    pub decode_steps: u64,
    pub generated_tokens: u64,
    pub evictions: u64,
    /// Peak bytes held in the KV pool.
    pub peak_kv_bytes: usize,
    /// Mean per-request total KV tokens at finish (2-D cache size).
    pub mean_kv_tokens: f64,
    pub wall_s: f64,
    pub oom_requests: usize,
    pub rejected_requests: usize,
    /// Requests killed by a runtime fault (FinishReason::Failed).
    pub failed_requests: usize,
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    /// Fraction of requests whose plan actually reallocated budget.
    pub reallocated_frac: f64,
}

/// Run `spec` against `engine` after applying `cfg` (reconfigure keeps the
/// PJRT client alive across sweep points).
pub fn evaluate(engine: &mut Engine, cfg: ServeConfig, spec: &EvalSpec) -> anyhow::Result<EvalResult> {
    engine.reconfigure(cfg)?;
    let mut trace = TraceSpec::closed(spec.n_requests, spec.prompt_len, spec.max_new, spec.seed);
    if !spec.tasks.is_empty() {
        trace = trace.with_tasks(&spec.tasks);
    }
    let items = trace.generate();
    let reqs: Vec<Request> = items
        .iter()
        .enumerate()
        .map(|(i, it)| Request::new(i as u64, it.sample.prompt.clone(), it.max_new_tokens))
        .collect();
    let outs = engine.generate_batch(reqs);

    let mut acc_sum = 0.0;
    let mut acc_n = 0usize;
    let mut kv_tokens = 0usize;
    let mut oom = 0usize;
    let mut rejected = 0usize;
    let mut failed = 0usize;
    let mut lat = Histogram::new();
    let mut reallocated = 0usize;
    for (it, out) in items.iter().zip(&outs) {
        match out.finish {
            FinishReason::Oom => oom += 1,
            FinishReason::Rejected => rejected += 1,
            FinishReason::Failed => failed += 1,
            _ => {
                let a = answer_accuracy(&it.sample, &out.generated);
                if a.is_finite() {
                    acc_sum += a;
                    acc_n += 1;
                }
            }
        }
        kv_tokens += out.final_kv_tokens;
        lat.record(out.timing.total_s);
        reallocated += out.plan.reallocated as usize;
    }
    let run = &engine.last_run;
    Ok(EvalResult {
        accuracy: if acc_n == 0 { f64::NAN } else { acc_sum / acc_n as f64 },
        tokens_per_s: run.generated_tokens as f64 / run.wall_s.max(1e-9),
        decode_steps: run.decode_steps,
        generated_tokens: run.generated_tokens,
        evictions: run.evictions,
        peak_kv_bytes: run.peak_pool_bytes,
        mean_kv_tokens: kv_tokens as f64 / outs.len().max(1) as f64,
        wall_s: run.wall_s,
        oom_requests: oom,
        rejected_requests: rejected,
        failed_requests: failed,
        latency_p50_s: lat.p50(),
        latency_p95_s: lat.p95(),
        reallocated_frac: reallocated as f64 / outs.len().max(1) as f64,
    })
}

/// The paper pairs each dataset with its best sequence-wise baseline (Fig. 3
/// picks the best case). Our tasks map naturally: recency-structured tasks →
/// Sliding Window, sink-structured → StreamingLLM, importance-structured →
/// H2O.
pub fn best_baseline_for(task: Task) -> crate::config::PolicyKind {
    use crate::config::PolicyKind::*;
    match task {
        Task::Copy | Task::Lm => SlidingWindow,
        Task::First => StreamingLlm,
        Task::Lookup | Task::Selective => H2o,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_constructors() {
        let s = EvalSpec::new(Task::Copy, 4, 100, 16, 0);
        assert_eq!(s.tasks, vec![Task::Copy]);
        let m = EvalSpec::mixed(4, 100, 16, 0);
        assert!(m.tasks.is_empty());
    }

    #[test]
    fn baseline_mapping_total() {
        use crate::workload::ALL_TASKS;
        for t in ALL_TASKS {
            let _ = best_baseline_for(t); // all tasks covered (no panic)
        }
    }
}
