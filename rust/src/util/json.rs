//! Minimal JSON — parser + serializer built from scratch (no serde in the
//! offline dependency set; see Cargo.toml note).
//!
//! Covers the full JSON grammar we produce/consume: the AOT manifest written
//! by `python/compile/aot.py`, the TCP wire protocol, and bench report files.
//! Numbers are f64 (all our integers are well below 2^53).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------- accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get`, but an error mentioning the key when missing.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    // ---------- constructors ---------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---------- parse ------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected '{}' got '{}' at byte {}", b as char, got as char, self.pos - 1);
        }
        Ok(())
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected '{}' at byte {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(out)),
                c => bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        // Surrogate pairs
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            if self.bump()? != b'\\' || self.bump()? != b'u' {
                                bail!("unpaired surrogate");
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump()? as char;
                                low = low * 16
                                    + c.to_digit(16).ok_or_else(|| anyhow!("bad \\u escape"))?;
                            }
                            0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                        } else {
                            code
                        };
                        s.push(char::from_u32(ch).ok_or_else(|| anyhow!("bad codepoint"))?);
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x20 => bail!("control char in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let extra = match c {
                            0xC0..=0xDF => 1,
                            0xE0..=0xEF => 2,
                            0xF0..=0xF7 => 3,
                            _ => bail!("invalid utf-8 lead byte"),
                        };
                        let start = self.pos - 1;
                        for _ in 0..extra {
                            self.bump()?;
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| anyhow!("invalid utf-8"))?;
                        s.push_str(chunk);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number '{text}': {e}"))?))
    }
}

// ---------- serialize -----------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let j = Json::parse(
            r#"{"model": {"n_layer": 8, "rope_theta": 10000.0},
                "trained": false,
                "artifacts": [{"file": "a.hlo.txt", "len": 64}, {"batch": 4}]}"#,
        )
        .unwrap();
        assert_eq!(j.get("model").unwrap().get("n_layer").unwrap().as_usize(), Some(8));
        assert_eq!(j.get("trained").unwrap().as_bool(), Some(false));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[0].get("file").unwrap().as_str(), Some("a.hlo.txt"));
    }

    #[test]
    fn roundtrip() {
        let src = Json::obj(vec![
            ("a", Json::num(1.5)),
            ("b", Json::arr([Json::Null, Json::Bool(true), Json::str("x\"y\n")])),
            ("c", Json::num(42.0)),
        ]);
        let text = src.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(src, back);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.25e2").unwrap().as_f64(), Some(-325.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
        assert_eq!(Json::parse("9").unwrap().as_i64(), Some(9));
        assert!(Json::parse("--1").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé 😀""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé 😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let j = Json::parse("\"héllo 世界\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{ }").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn integer_display_exact() {
        assert_eq!(Json::num(8.0).to_string(), "8");
        assert_eq!(Json::num(1.5).to_string(), "1.5");
    }
}
