//! From-scratch substrates for the offline build: JSON, PRNG, CLI parsing,
//! bench harness, and property testing (see the Cargo.toml note — only the
//! `xla` crate closure is available offline).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

pub use cli::Args;
pub use json::Json;
pub use rng::Rng;
