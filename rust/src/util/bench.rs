//! Bench harness (no criterion in the offline dependency set): warmup +
//! timed iterations with mean/p50/min stats, plus aligned table printing and
//! CSV report emission for the paper-reproduction benches.

use std::time::Instant;

/// Timing summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    pub fn per_iter_display(&self) -> String {
        format!(
            "{:<40} {:>10} iters  mean {:>12}  p50 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_duration(self.mean_s),
            fmt_duration(self.p50_s),
            fmt_duration(self.min_s)
        )
    }
}

pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Run `f` for `warmup + iters` iterations, timing the last `iters`.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_s: samples[samples.len() / 2],
        min_s: samples[0],
        max_s: *samples.last().unwrap(),
    };
    println!("{}", stats.per_iter_display());
    stats
}

/// Measure one shot (for end-to-end cases where iteration is too slow).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Aligned table printer for paper-style rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
                .collect();
            println!("| {} |", cols.join(" | "));
        };
        line(&self.headers);
        println!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            line(row);
        }
    }

    /// Write as CSV (for plotting Fig. 2/3/4 data).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = self.headers.join(",") + "\n";
        for row in &self.rows {
            out += &(row.join(",") + "\n");
        }
        std::fs::write(path, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let s = bench("noop", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.iters, 5);
        assert!(s.min_s <= s.mean_s && s.mean_s <= s.max_s + 1e-12);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(2.5e-9).contains("ns"));
        assert!(fmt_duration(2.5e-5).contains("µs"));
        assert!(fmt_duration(2.5e-2).contains("ms"));
        assert!(fmt_duration(2.5).contains(" s"));
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["batch", "tok/s"]);
        t.row(vec!["8".into(), "123.4".into()]);
        let dir = std::env::temp_dir().join(format!("sa_table_{}", std::process::id()));
        let path = dir.join("t.csv");
        t.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "batch,tok/s\n8,123.4\n");
        std::fs::remove_dir_all(dir).ok();
    }
}
