//! Deterministic PRNG — xoshiro256** seeded via SplitMix64 (no `rand` crate
//! in the offline dependency set). Used by workload generation, sampling,
//! and the property-test harness; everything that randomizes takes a seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion (Vigna's recommended seeding).
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] (inclusive). Panics if lo > hi.
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(lo <= hi);
        let span = (hi as i64 - lo as i64 + 1) as u64;
        lo + (self.next_u64() % span) as i32
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform usize in [lo, hi) (half-open).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// First `k` elements of a Fisher–Yates partial shuffle.
    pub fn choose_k<T: Copy>(&mut self, items: &[T], k: usize) -> Vec<T> {
        let mut pool: Vec<T> = items.to_vec();
        let k = k.min(pool.len());
        for i in 0..k {
            let j = self.range(i, pool.len());
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }

    /// Exponential variate with rate `lambda`.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        let u = self.f64().max(1e-12);
        -u.ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range_i32(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::seed_from_u64(11);
        let items: Vec<i32> = (0..50).collect();
        let picked = r.choose_k(&items, 20);
        assert_eq!(picked.len(), 20);
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn mean_roughly_half() {
        let mut r = Rng::seed_from_u64(13);
        let n = 10_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        assert!((sum / n as f64 - 0.5).abs() < 0.02);
    }
}
