//! Minimal CLI argument parser (no clap in the offline dependency set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! subcommands, defaults, and auto-generated `--help`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    bools: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse argv (without the program name). `bool_flags` lists flags that
    /// take no value.
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Args> {
        let mut values = BTreeMap::new();
        let mut bools = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    values.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&name) {
                    bools.push(name.to_string());
                } else {
                    i += 1;
                    let v = argv
                        .get(i)
                        .ok_or_else(|| anyhow!("flag --{name} expects a value"))?;
                    values.insert(name.to_string(), v.clone());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { values, bools, positional })
    }

    pub fn from_env(bool_flags: &[&str]) -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv, bool_flags)
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.values.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, name: &str) -> Option<String> {
        self.values.get(name).cloned()
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.values.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.values.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.values.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad float '{v}'")),
        }
    }

    pub fn opt_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.values.get(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| anyhow!("--{name}: bad float '{v}'"))?)),
        }
    }

    /// Comma-separated usizes.
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.values.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim().parse::<usize>().map_err(|_| anyhow!("--{name}: bad list '{v}'"))
                })
                .collect(),
        }
    }

    /// Error out on unknown flags (typo guard) given the known set.
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.values.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known.join(", "));
            }
        }
        for k in &self.bools {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn values_and_bools() {
        let a = Args::parse(
            &argv(&["generate", "--budget", "64", "--p=0.3", "--verbose"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional(0), Some("generate"));
        assert_eq!(a.usize("budget", 0).unwrap(), 64);
        assert!((a.f64("p", 1.0).unwrap() - 0.3).abs() < 1e-12);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv(&[]), &[]).unwrap();
        assert_eq!(a.str("policy", "sliding_window"), "sliding_window");
        assert_eq!(a.usize("n", 8).unwrap(), 8);
        assert!(a.opt_str("task").is_none());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv(&["--budget"]), &[]).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&argv(&["--n", "abc"]), &[]).unwrap();
        assert!(a.usize("n", 0).is_err());
    }

    #[test]
    fn lists() {
        let a = Args::parse(&argv(&["--batches", "1, 8,16"]), &[]).unwrap();
        assert_eq!(a.usize_list("batches", &[]).unwrap(), vec![1, 8, 16]);
        assert_eq!(a.usize_list("other", &[2, 3]).unwrap(), vec![2, 3]);
    }

    #[test]
    fn unknown_flag_guard() {
        let a = Args::parse(&argv(&["--budgte", "64"]), &[]).unwrap();
        assert!(a.check_known(&["budget"]).is_err());
        assert!(a.check_known(&["budgte"]).is_ok());
    }
}
