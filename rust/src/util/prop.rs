//! Property-test harness (no proptest in the offline dependency set):
//! runs a property over many seeded random cases and reports the failing
//! seed so a failure is reproducible with `check_with_seed`.

use super::rng::Rng;

/// Run `prop` on `cases` random inputs drawn via the provided RNG. Panics
/// (with the offending case seed) on the first failure.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, cases: usize, mut prop: F) {
    for case in 0..cases {
        let seed = 0x5EED_0000_u64 + case as u64;
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_with_seed<F: FnMut(&mut Rng) -> Result<(), String>>(
    name: &str,
    seed: u64,
    mut prop: F,
) {
    let mut rng = Rng::seed_from_u64(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed (seed {seed:#x}): {msg}");
    }
}

/// Assertion helpers usable inside properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T, ctx: &str) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{ctx}: {a:?} != {b:?}"))
    }
}

pub fn ensure_le<T: PartialOrd + std::fmt::Debug>(a: T, b: T, ctx: &str) -> Result<(), String> {
    if a <= b {
        Ok(())
    } else {
        Err(format!("{ctx}: {a:?} > {b:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |rng| ensure(rng.f64() < -1.0, "impossible"));
    }

    #[test]
    fn ensure_eq_messages() {
        assert!(ensure_eq(1, 1, "x").is_ok());
        let e = ensure_eq(1, 2, "budgets").unwrap_err();
        assert!(e.contains("budgets"));
    }

    #[test]
    fn ensure_le_messages() {
        assert!(ensure_le(1, 1, "x").is_ok());
        assert!(ensure_le(1, 2, "x").is_ok());
        let e = ensure_le(3, 2, "cap").unwrap_err();
        assert!(e.contains("cap"));
    }
}
