//! JSON-lines TCP front-end over the router (std::net — no tokio in the
//! offline dependency set; one reader + one writer thread per connection).
//!
//! Wire protocol (one JSON object per line):
//!   -> {"id": 1, "prompt": [256, 5, 6, 257], "max_new_tokens": 32}
//!   <- {"id": 1, "generated": [...], "finish": "eos", "total_s": 0.42}
//!
//! Every parsed line is submitted to the router *immediately* (not after the
//! previous response), so pipelined requests stream into a worker's
//! scheduler queue and join its running batch mid-flight. Responses are
//! written back in request order per connection; malformed lines produce an
//! in-order `{"error": ...}` object and the connection stays usable.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::Result;

use crate::util::Json;

use super::request::{FinishReason, Request, RequestOutput};
use super::router::Router;

fn finish_str(f: FinishReason) -> &'static str {
    match f {
        FinishReason::Eos => "eos",
        FinishReason::Length => "length",
        FinishReason::Oom => "oom",
        FinishReason::Rejected => "rejected",
        FinishReason::Failed => "failed",
    }
}

/// Parse one wire request line.
pub fn parse_wire_request(line: &str) -> Result<Request> {
    let j = Json::parse(line)?;
    let id = j.req("id")?.as_i64().ok_or_else(|| anyhow::anyhow!("bad id"))? as u64;
    let prompt: Vec<i32> = j
        .req("prompt")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("prompt must be an array"))?
        .iter()
        .filter_map(|v| v.as_i64().map(|x| x as i32))
        .collect();
    let max_new = j.get("max_new_tokens").and_then(|v| v.as_usize()).unwrap_or(64);
    Ok(Request::new(id, prompt, max_new))
}

/// Encode one wire response line.
pub fn encode_wire_response(out: &RequestOutput) -> String {
    Json::obj(vec![
        ("id", Json::num(out.id as f64)),
        ("generated", Json::arr(out.generated.iter().map(|&t| Json::num(t as f64)))),
        ("finish", Json::str(finish_str(out.finish))),
        ("total_s", Json::num(out.timing.total_s)),
        ("first_token_s", Json::num(out.timing.first_token_s)),
    ])
    .to_string()
}

/// Serve until the listener errors. Each connection may pipeline requests.
pub fn serve(listener: TcpListener, router: Arc<Router>) -> Result<()> {
    loop {
        let (stream, _) = listener.accept()?;
        let router = router.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle(stream, router) {
                eprintln!("connection error: {e:#}");
            }
        });
    }
}

/// One in-order response slot for the writer thread: either a pending engine
/// output or an immediate protocol error.
enum PendingLine {
    Output(mpsc::Receiver<RequestOutput>),
    Error(String),
}

fn handle(stream: TcpStream, router: Arc<Router>) -> Result<()> {
    let writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let (tx, rx) = mpsc::channel::<PendingLine>();
    let responder = std::thread::spawn(move || write_loop(writer, rx));
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let item = match parse_wire_request(&line) {
            Ok(req) => match router.submit_async(req) {
                Ok(rx_out) => PendingLine::Output(rx_out),
                Err(e) => PendingLine::Error(e.to_string()),
            },
            Err(e) => PendingLine::Error(e.to_string()),
        };
        if tx.send(item).is_err() {
            break; // writer gone (client hung up mid-stream)
        }
    }
    drop(tx);
    let _ = responder.join();
    Ok(())
}

fn write_loop(mut writer: TcpStream, rx: mpsc::Receiver<PendingLine>) {
    for item in rx {
        let line = match item {
            PendingLine::Output(rx_out) => match rx_out.recv() {
                Ok(out) => encode_wire_response(&out),
                Err(_) => Json::obj(vec![("error", Json::str("request dropped"))]).to_string(),
            },
            PendingLine::Error(e) => Json::obj(vec![("error", Json::str(e))]).to_string(),
        };
        if writeln!(writer, "{line}").is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{RequestOutput, RequestTiming};
    use crate::squeeze::BudgetPlan;

    #[test]
    fn wire_request_parse() {
        let r = parse_wire_request(r#"{"id": 3, "prompt": [256, 5], "max_new_tokens": 9}"#)
            .unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt, vec![256, 5]);
        assert_eq!(r.max_new_tokens, 9);
        // default max_new
        let r2 = parse_wire_request(r#"{"id": 1, "prompt": []}"#).unwrap();
        assert_eq!(r2.max_new_tokens, 64);
        assert!(parse_wire_request("{notjson").is_err());
    }

    #[test]
    fn wire_response_encode_roundtrip() {
        let out = RequestOutput {
            id: 7,
            generated: vec![1, 2, 260],
            finish: FinishReason::Eos,
            timing: RequestTiming { total_s: 0.5, first_token_s: 0.1, ..Default::default() },
            plan: BudgetPlan::uniform(2, 8),
            peak_kv_bytes: 0,
            final_kv_tokens: 0,
        };
        let line = encode_wire_response(&out);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("finish").unwrap().as_str(), Some("eos"));
        assert_eq!(j.get("generated").unwrap().as_arr().unwrap().len(), 3);
    }
}
