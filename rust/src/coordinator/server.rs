//! JSON-lines TCP front-end over the router (std::net — no tokio in the
//! offline dependency set; one reader + one writer thread per connection).
//!
//! Wire protocol (one JSON object per line):
//!   -> {"id": 1, "prompt": [256, 5, 6, 257], "max_new_tokens": 32}
//!   <- {"id": 1, "generated": [...], "finish": "eos", "total_s": 0.42, ...}
//!
//! Optional request fields:
//!   "stream": true      emit one {"id", "token", "pos"} line per decoded
//!                       token as it is sampled, before the summary line.
//!                       `pos` is the 0-based generation index and is
//!                       authoritative: a restart-from-scratch preemption
//!                       re-emits from pos 0 (suspend/resume never does).
//!   "deadline_ms": N    wall-clock budget from submission; an expired
//!                       request finishes with "finish": "deadline" at the
//!                       next step boundary, keeping its partial output.
//!
//! Control lines:
//!   -> {"metrics": true}
//!   <- {"workers": [{scheduler, queue_latency_s, ttft_s, itl_s, phases,
//!                    squeeze, throughput, healthy, state, restarts}, ...],
//!       ...}
//!   -> {"metrics_prom": true}
//!   <- {"content_type": "text/plain; version=0.0.4", "body": "..."}
//!      Prometheus text exposition wrapped in one JSON line — the newlines
//!      ride escaped inside the "body" string, so the payload stays one
//!      line on the socket and `body` unescapes to scrapeable text.
//!   -> {"trace": <request id>}
//!   <- {"id": N, "found": bool, "spans": [{"id", "kind", "t_ms",
//!       "kv_bytes"}, ...]} — the request's lifecycle span history (submit
//!      → admit → prefill → squeeze → first_token → ... → retire) from the
//!      worker flight recorders, resolved through the id alias table.
//!   -> {"flight_dump": <worker index>}
//!   <- the worker's most recent crash report ({"flight_recorder": true,
//!      "reason", "spans", ...}), or {"flight_dump": N, "found": false}
//!      when that worker never faulted.
//!
//! Load shedding: when the router's admission control rejects a request
//! (`RouteError::Overloaded`), the connection gets a structured in-order
//! line — {"id": N, "error": "overloaded", "retry_after_ms": M} — instead
//! of a generic error, so clients can back off and retry. A request that
//! dies with its worker (retry budget spent) is answered with a normal
//! summary line carrying "finish": "worker_error".
//!
//! Every parsed line is submitted to the router *immediately* (not after the
//! previous response), so pipelined requests stream into a worker's
//! scheduler queue and join its running batch mid-flight. Responses are
//! written back in request order per connection — a streamed request's token
//! lines all precede its summary line, and the summary precedes the next
//! request's first line. Malformed lines (bad JSON, or a prompt containing
//! a non-integer entry) produce an in-order `{"error": ...}` object and the
//! connection stays usable.
//!
//! Client disconnect (a failed write) cancels every request still in flight
//! on that connection via its lifecycle `CancelToken`, so abandoned
//! generations release their device/host KV reservations at the next step
//! boundary instead of decoding to `max_new_tokens`. Detection is
//! write-driven by design: read-side EOF must NOT cancel, because a
//! pipelining client may legally shut down its write half and keep reading
//! responses (`printf ... | nc`). Streamed requests therefore notice a dead
//! client within one token; a non-streamed request only notices at its
//! summary write and may decode to completion first — clients wanting eager
//! reclamation should set `"stream": true` or a `"deadline_ms"`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::util::Json;

use super::lifecycle::{RequestEvent, RequestHandle};
use super::request::{FinishReason, Request, RequestOutput};
use super::router::Router;
use super::supervisor::RouteError;

fn finish_str(f: FinishReason) -> &'static str {
    match f {
        FinishReason::Eos => "eos",
        FinishReason::Length => "length",
        FinishReason::Oom => "oom",
        FinishReason::Rejected => "rejected",
        FinishReason::Failed => "failed",
        FinishReason::Cancelled => "cancelled",
        FinishReason::WorkerError => "worker_error",
        FinishReason::DeadlineExceeded => "deadline",
    }
}

/// One parsed wire request: the engine request plus wire-only options.
#[derive(Debug)]
pub struct WireRequest {
    pub request: Request,
    /// Emit per-token lines ahead of the summary line.
    pub stream: bool,
}

/// Parse one wire request line. Every prompt entry must be an integer token
/// id — a non-integer entry rejects the whole line (previously it was
/// silently dropped, shifting the prompt).
pub fn parse_wire_request(line: &str) -> Result<WireRequest> {
    let j = Json::parse(line)?;
    let id = j.req("id")?.as_i64().ok_or_else(|| anyhow::anyhow!("bad id"))? as u64;
    let arr = j
        .req("prompt")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("prompt must be an array"))?;
    let mut prompt = Vec::with_capacity(arr.len());
    for (i, v) in arr.iter().enumerate() {
        let tok = v
            .as_i64()
            .ok_or_else(|| anyhow::anyhow!("prompt[{i}] is not an integer token id"))?;
        let tok = i32::try_from(tok)
            .map_err(|_| anyhow::anyhow!("prompt[{i}] is out of token-id range"))?;
        prompt.push(tok);
    }
    let max_new = j.get("max_new_tokens").and_then(|v| v.as_usize()).unwrap_or(64);
    let mut request = Request::new(id, prompt, max_new);
    if let Some(ms) = j.get("deadline_ms").and_then(|v| v.as_usize()) {
        request.deadline = Some(Duration::from_millis(ms as u64));
    }
    let stream = j.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
    Ok(WireRequest { request, stream })
}

/// Encode one summary (terminal) response line.
pub fn encode_wire_response(out: &RequestOutput) -> String {
    Json::obj(vec![
        ("id", Json::num(out.id as f64)),
        ("generated", Json::arr(out.generated.iter().map(|&t| Json::num(t as f64)))),
        ("finish", Json::str(finish_str(out.finish))),
        ("total_s", Json::num(out.timing.total_s)),
        ("first_token_s", Json::num(out.timing.first_token_s)),
    ])
    .to_string()
}

/// Encode one streamed-token line.
pub fn encode_token_line(id: u64, token: i32, pos: usize) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("token", Json::num(token as f64)),
        ("pos", Json::num(pos as f64)),
    ])
    .to_string()
}

/// Encode a routing-layer rejection. Shedding gets a structured line with a
/// Retry-After hint (`{"id", "error": "overloaded", "retry_after_ms": N}`)
/// so well-behaved clients back off instead of hammering a saturated
/// router; other routing errors carry their display string.
pub fn encode_route_error(id: u64, e: RouteError) -> String {
    let mut fields = vec![("id", Json::num(id as f64))];
    match e {
        RouteError::Overloaded { retry_after_ms } => {
            fields.push(("error", Json::str("overloaded")));
            fields.push(("retry_after_ms", Json::num(retry_after_ms as f64)));
        }
        other => fields.push(("error", Json::str(other.to_string()))),
    }
    Json::obj(fields).to_string()
}

/// Serve until the listener errors. Each connection may pipeline requests.
pub fn serve(listener: TcpListener, router: Arc<Router>) -> Result<()> {
    loop {
        let (stream, _) = listener.accept()?;
        let router = router.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle(stream, router) {
                eprintln!("connection error: {e:#}");
            }
        });
    }
}

/// One in-order response slot for the writer thread.
enum PendingLine {
    /// A submitted request: its lifecycle handle plus whether to emit
    /// per-token lines.
    Request { handle: RequestHandle, stream: bool },
    /// An immediate protocol error.
    Error(String),
    /// A pre-rendered control response (metrics snapshot).
    Control(String),
}

fn handle(stream: TcpStream, router: Arc<Router>) -> Result<()> {
    let writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let (tx, rx) = mpsc::channel::<PendingLine>();
    let responder = std::thread::spawn(move || write_loop(writer, rx));
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        if let Some(c) = parse_control_line(&line) {
            if tx.send(PendingLine::Control(control_response(c, &router))).is_err() {
                break;
            }
            continue;
        }
        let item = match parse_wire_request(&line) {
            Ok(wire) => {
                let id = wire.request.id;
                match router.submit_stream(wire.request) {
                    Ok(handle) => PendingLine::Request { handle, stream: wire.stream },
                    Err(e) => PendingLine::Control(encode_route_error(id, e)),
                }
            }
            Err(e) => PendingLine::Error(e.to_string()),
        };
        if tx.send(item).is_err() {
            break; // writer gone (client hung up mid-stream)
        }
    }
    drop(tx);
    let _ = responder.join();
    Ok(())
}

/// A recognized observability control line (see the module doc).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ControlLine {
    /// `{"metrics": true}` — JSON metrics snapshot.
    Metrics,
    /// `{"metrics_prom": true}` — Prometheus text exposition.
    MetricsProm,
    /// `{"trace": <id>}` — span history for one request id.
    Trace(u64),
    /// `{"flight_dump": <worker>}` — that worker's last crash report.
    FlightDump(usize),
}

/// Recognize a control line. `None` means the line is a normal request (or
/// malformed — the request parser reports that in order).
fn parse_control_line(line: &str) -> Option<ControlLine> {
    let j = Json::parse(line).ok()?;
    if j.get("metrics").and_then(|v| v.as_bool()) == Some(true) {
        return Some(ControlLine::Metrics);
    }
    if j.get("metrics_prom").and_then(|v| v.as_bool()) == Some(true) {
        return Some(ControlLine::MetricsProm);
    }
    if let Some(id) = j.get("trace").and_then(|v| v.as_usize()) {
        return Some(ControlLine::Trace(id as u64));
    }
    if let Some(i) = j.get("flight_dump").and_then(|v| v.as_usize()) {
        return Some(ControlLine::FlightDump(i));
    }
    None
}

/// Render the in-order response line for a control query.
fn control_response(c: ControlLine, router: &Router) -> String {
    match c {
        ControlLine::Metrics => router.metrics_json().to_string(),
        ControlLine::MetricsProm => prom_wire_line(&router.metrics_prom()),
        ControlLine::Trace(id) => router.trace_json(id).to_string(),
        ControlLine::FlightDump(i) => router
            .last_flight_dump(i)
            .unwrap_or_else(|| {
                Json::obj(vec![
                    ("flight_dump", Json::num(i as f64)),
                    ("found", Json::Bool(false)),
                ])
            })
            .to_string(),
    }
}

/// Wrap the (multi-line) Prometheus exposition as one JSON wire line: the
/// JSON string escapes the newlines, keeping the JSON-lines protocol intact.
pub fn prom_wire_line(body: &str) -> String {
    Json::obj(vec![
        ("content_type", Json::str("text/plain; version=0.0.4")),
        ("body", Json::str(body)),
    ])
    .to_string()
}

/// Writer thread: answer pending lines in order. Once a write fails the
/// client is gone — every remaining in-flight request is cancelled (its KV
/// reservations are released at the engine's next step boundary) and the
/// rest of the queue is drained without writing.
fn write_loop(mut writer: TcpStream, rx: mpsc::Receiver<PendingLine>) {
    let mut client_gone = false;
    for item in rx {
        match item {
            PendingLine::Request { handle, stream } => {
                if client_gone || !forward_request(&mut writer, &handle, stream) {
                    client_gone = true;
                    handle.cancel();
                }
            }
            PendingLine::Error(e) if !client_gone => {
                let line = Json::obj(vec![("error", Json::str(e))]).to_string();
                client_gone = writeln!(writer, "{line}").is_err();
            }
            PendingLine::Control(line) if !client_gone => {
                client_gone = writeln!(writer, "{line}").is_err();
            }
            PendingLine::Error(_) | PendingLine::Control(_) => {}
        }
    }
}

/// Forward one request's lifecycle to the socket: token lines while
/// streaming, then the terminal summary. Returns false when the client
/// disconnected (a write failed) — the caller cancels the request.
fn forward_request(writer: &mut TcpStream, handle: &RequestHandle, stream: bool) -> bool {
    loop {
        match handle.recv() {
            Ok(RequestEvent::Token { id, token, pos }) if stream => {
                if writeln!(writer, "{}", encode_token_line(id, token, pos)).is_err() {
                    return false;
                }
            }
            Ok(ev) if ev.is_terminal() => {
                return match ev.into_output() {
                    Some(out) => writeln!(writer, "{}", encode_wire_response(&out)).is_ok(),
                    // Defensive: a terminal event always carries its output
                    // today. If that invariant ever breaks, answer the
                    // connection with an error line instead of panicking the
                    // writer thread (which would strand every request queued
                    // behind this one on the connection).
                    None => {
                        let line =
                            Json::obj(vec![("error", Json::str("terminal event without output"))])
                                .to_string();
                        writeln!(writer, "{line}").is_ok()
                    }
                };
            }
            Ok(_) => {} // Started / Suspended / Resumed / unstreamed Token
            Err(_) => {
                // Stream closed without a terminal event (worker teardown).
                let line = Json::obj(vec![("error", Json::str("request dropped"))]).to_string();
                return writeln!(writer, "{line}").is_ok();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{RequestOutput, RequestTiming};
    use crate::squeeze::BudgetPlan;

    #[test]
    fn wire_request_parse() {
        let w = parse_wire_request(r#"{"id": 3, "prompt": [256, 5], "max_new_tokens": 9}"#)
            .unwrap();
        assert_eq!(w.request.id, 3);
        assert_eq!(w.request.prompt, vec![256, 5]);
        assert_eq!(w.request.max_new_tokens, 9);
        assert!(!w.stream);
        assert!(w.request.deadline.is_none());
        // default max_new
        let w2 = parse_wire_request(r#"{"id": 1, "prompt": []}"#).unwrap();
        assert_eq!(w2.request.max_new_tokens, 64);
        assert!(parse_wire_request("{notjson").is_err());
    }

    #[test]
    fn wire_request_stream_and_deadline() {
        let w = parse_wire_request(
            r#"{"id": 4, "prompt": [256], "stream": true, "deadline_ms": 250}"#,
        )
        .unwrap();
        assert!(w.stream);
        assert_eq!(w.request.deadline, Some(Duration::from_millis(250)));
    }

    #[test]
    fn wire_request_rejects_non_integer_prompt_entries() {
        // A string entry must reject the line, not silently shift the prompt.
        let err = parse_wire_request(r#"{"id": 1, "prompt": [256, "x", 5]}"#).unwrap_err();
        assert!(err.to_string().contains("prompt[1]"), "{err}");
        // Fractional token ids are not integers either.
        assert!(parse_wire_request(r#"{"id": 1, "prompt": [1.5]}"#).is_err());
        // null likewise.
        assert!(parse_wire_request(r#"{"id": 1, "prompt": [null]}"#).is_err());
        // Integers outside i32 range must be rejected, not wrapped.
        let err = parse_wire_request(r#"{"id": 1, "prompt": [3000000000]}"#).unwrap_err();
        assert!(err.to_string().contains("range"), "{err}");
        assert!(parse_wire_request(r#"{"id": 1, "prompt": [-3000000000]}"#).is_err());
    }

    #[test]
    fn wire_response_encode_roundtrip() {
        let out = RequestOutput {
            id: 7,
            generated: vec![1, 2, 260],
            finish: FinishReason::Eos,
            timing: RequestTiming { total_s: 0.5, first_token_s: 0.1, ..Default::default() },
            plan: BudgetPlan::uniform(2, 8),
            peak_kv_bytes: 0,
            final_kv_tokens: 0,
        };
        let line = encode_wire_response(&out);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("finish").unwrap().as_str(), Some("eos"));
        assert_eq!(j.get("generated").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn token_line_encodes_id_token_pos() {
        let j = Json::parse(&encode_token_line(9, 260, 3)).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(9));
        assert_eq!(j.get("token").unwrap().as_i64(), Some(260));
        assert_eq!(j.get("pos").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn finish_strings_cover_lifecycle_reasons() {
        assert_eq!(finish_str(FinishReason::Cancelled), "cancelled");
        assert_eq!(finish_str(FinishReason::DeadlineExceeded), "deadline");
        assert_eq!(finish_str(FinishReason::WorkerError), "worker_error");
    }

    #[test]
    fn route_error_lines_encode_structured_overload() {
        let line = encode_route_error(9, RouteError::Overloaded { retry_after_ms: 250 });
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(9));
        assert_eq!(j.get("error").unwrap().as_str(), Some("overloaded"));
        assert_eq!(j.get("retry_after_ms").unwrap().as_usize(), Some(250));

        let line = encode_route_error(3, RouteError::NoHealthyWorker);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("no healthy worker"));
        assert!(j.get("retry_after_ms").is_none());
    }

    #[test]
    fn control_line_detection() {
        assert_eq!(parse_control_line(r#"{"metrics": true}"#), Some(ControlLine::Metrics));
        assert_eq!(parse_control_line(r#"{"metrics": false}"#), None);
        assert_eq!(
            parse_control_line(r#"{"metrics_prom": true}"#),
            Some(ControlLine::MetricsProm)
        );
        assert_eq!(parse_control_line(r#"{"trace": 7}"#), Some(ControlLine::Trace(7)));
        assert_eq!(parse_control_line(r#"{"flight_dump": 0}"#), Some(ControlLine::FlightDump(0)));
        assert_eq!(parse_control_line(r#"{"id": 1, "prompt": []}"#), None);
        assert_eq!(parse_control_line("{garbage"), None);
    }

    #[test]
    fn prom_wire_line_stays_single_line() {
        let body = "# TYPE sa_up gauge\nsa_up 1\n";
        let line = prom_wire_line(body);
        assert!(!line.contains('\n'));
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("body").unwrap().as_str(), Some(body));
        assert!(j.get("content_type").unwrap().as_str().unwrap().contains("0.0.4"));
    }
}
