//! Batch-resident scratch KV: per-tier decode buffers whose slot contents
//! persist across steps so the steady-state gather copies only the rows
//! appended since the last step, not the whole cache.
//!
//! Ownership and contract:
//!
//! * The engine owns one [`ScratchTier`] per decode tier `(B, M)`. The
//!   tensors inside are the exact buffers handed to `Runtime::decode`; the
//!   kernel masks by `cache_lens`, so rows past a slot's length are
//!   don't-care garbage and a shrinking slot never needs zeroing.
//! * Each slot records which sequence (`seq` ordinal — unique for the
//!   lifetime of the scheduler, so slot reassignment can never alias) last
//!   filled it, at which cache [`generation`](SequenceCache::generation),
//!   and how many rows per layer were synced.
//! * On gather, the slot is eligible for an *incremental append* iff the
//!   same sequence is still in the slot and the cache's
//!   [`dirty_generation`](SequenceCache::dirty_generation) has not passed
//!   the synced generation — i.e. every mutation since the last sync was a
//!   pure append (or metadata-only score fold). Anything destructive —
//!   eviction/compaction (`retain`), speculative rollback (`truncate`),
//!   suspend/resume (`restore`), preemption, slot reassignment — bumps the
//!   dirty generation or changes the slot's `seq`, forcing a full refill of
//!   just that slot. A tier-capacity change lands in a different
//!   `ScratchTier` whose slot entry is validated the same way, so tier
//!   switches are safe by construction, and COW page privatization never
//!   rewrites payload rows (page tables are pure accounting), so it needs
//!   no invalidation at all.
//!
//! The checks are enforced here, not assumed: a breached contract (e.g. a
//! synced prefix longer than the live cache) falls back to a full refill or
//! surfaces as a hard error from the copy layer, never as silently stale
//! rows.

use anyhow::Result;

use crate::kvcache::SequenceCache;
use crate::runtime::Tensor;

/// Cumulative gather-path counters, exported through `SchedulerMetrics`.
#[derive(Debug, Clone, Copy, Default)]
pub struct GatherStats {
    /// Payload bytes copied into scratch (K+V, f32).
    pub kv_bytes_copied: u64,
    /// Slot gathers that had to rewrite the slot from row 0.
    pub full_refills: u64,
    /// Slot gathers that appended only rows new since the last sync.
    pub incremental_appends: u64,
}

/// What one slot of one tier currently holds.
#[derive(Debug, Clone)]
struct SlotResidency {
    /// Scheduler-wide unique sequence ordinal that filled this slot.
    seq: u64,
    /// Cache generation at the time of the last sync.
    synced_gen: u64,
    /// Rows valid in the buffer, per layer.
    valid: Vec<usize>,
}

/// One decode tier's scratch buffers plus per-slot residency state.
#[derive(Debug, Clone)]
pub struct ScratchTier {
    pub k: Tensor,
    pub v: Tensor,
    resident: Vec<Option<SlotResidency>>,
    /// Engine decode-step clock at last use, for idle-tier eviction.
    pub last_used_step: u64,
    /// Scratch zero-offset vector reused by full refills (avoids a per-call
    /// allocation on the hot path).
    zeros: Vec<usize>,
}

impl ScratchTier {
    /// Allocate buffers of shape `[n_layer, b, m, h, d]` with empty
    /// residency.
    pub fn new(n_layer: usize, b: usize, m: usize, h: usize, d: usize) -> Self {
        Self {
            k: Tensor::zeros(&[n_layer, b, m, h, d]),
            v: Tensor::zeros(&[n_layer, b, m, h, d]),
            resident: vec![None; b],
            last_used_step: 0,
            zeros: vec![0; n_layer],
        }
    }

    /// Bytes held by the K and V buffers.
    pub fn bytes(&self) -> usize {
        (self.k.data.len() + self.v.data.len()) * 4
    }

    /// Forget everything resident (e.g. after reconfigure).
    #[cfg(test)]
    pub fn invalidate_all(&mut self) {
        for r in &mut self.resident {
            *r = None;
        }
    }

    /// Sync `cache` (owned by sequence `seq`) into slot `b`, refreshing
    /// `lens` for every layer. Copies only the rows appended since the last
    /// sync when the residency contract allows; otherwise performs a full
    /// refill of the slot. `allow_incremental = false` forces the refill
    /// path (the parity baseline). On error the slot's residency is cleared
    /// — a partial write must never masquerade as a valid prefix.
    pub fn gather(
        &mut self,
        cache: &SequenceCache,
        seq: u64,
        b: usize,
        lens: &mut [i32],
        allow_incremental: bool,
        stats: &mut GatherStats,
    ) -> Result<()> {
        let n_layer = cache.n_layer();
        let incremental = allow_incremental
            && self.resident.get(b).and_then(|r| r.as_ref()).is_some_and(|r| {
                r.seq == seq
                    && cache.dirty_generation() <= r.synced_gen
                    && r.valid.len() == n_layer
                    && (0..n_layer).all(|l| r.valid[l] <= cache.layer_len(l))
            });
        let from: &[usize] = if incremental {
            &self.resident[b].as_ref().expect("checked above").valid
        } else {
            &self.zeros
        };
        let copied = match cache.write_rows_into_batch(&mut self.k, &mut self.v, lens, b, from) {
            Ok(n) => n,
            Err(e) => {
                if let Some(r) = self.resident.get_mut(b) {
                    *r = None;
                }
                return Err(e);
            }
        };
        let valid = (0..n_layer).map(|l| cache.layer_len(l)).collect();
        self.resident[b] = Some(SlotResidency { seq, synced_gen: cache.generation(), valid });
        stats.kv_bytes_copied += copied as u64 * SequenceCache::token_bytes(cache.row_elems) as u64;
        if incremental {
            stats.incremental_appends += 1;
        } else {
            stats.full_refills += 1;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure, ensure_eq};
    use crate::util::rng::Rng;

    const N_LAYER: usize = 3;
    const ROW: usize = 4; // h=2, d=2
    const B: usize = 2;
    const M: usize = 24;

    fn tier() -> ScratchTier {
        ScratchTier::new(N_LAYER, B, M, 2, 2)
    }

    fn filled_cache(rng: &mut Rng, rows: usize) -> (SequenceCache, u32) {
        let mut c = SequenceCache::new(N_LAYER, ROW);
        let mut pos = 0u32;
        for _ in 0..rows {
            append_row(rng, &mut c, &mut pos);
        }
        (c, pos)
    }

    fn append_row(rng: &mut Rng, c: &mut SequenceCache, pos: &mut u32) {
        for l in 0..N_LAYER {
            let k: Vec<f32> = (0..ROW).map(|_| rng.f64() as f32).collect();
            let v: Vec<f32> = (0..ROW).map(|_| rng.f64() as f32).collect();
            c.append(l, &k, &v, *pos).unwrap();
        }
        *pos += 1;
    }

    /// Compare the buffer's slot-`b` contents against the cache row by row.
    fn slot_matches(st: &ScratchTier, c: &SequenceCache, b: usize) -> Result<(), String> {
        for l in 0..N_LAYER {
            let len = c.layer_len(l);
            let base = (l * B + b) * M * ROW;
            ensure(
                st.k.data[base..base + len * ROW] == c.layers[l].k[..],
                format!("layer {l}: K rows diverge from cache"),
            )?;
            ensure(
                st.v.data[base..base + len * ROW] == c.layers[l].v[..],
                format!("layer {l}: V rows diverge from cache"),
            )?;
        }
        Ok(())
    }

    #[test]
    fn steady_state_appends_copy_only_new_rows() {
        let mut rng = Rng::seed_from_u64(1);
        let (mut c, mut pos) = filled_cache(&mut rng, 5);
        let mut st = tier();
        let mut lens = vec![0i32; N_LAYER * B];
        let mut stats = GatherStats::default();
        st.gather(&c, 7, 0, &mut lens, true, &mut stats).unwrap();
        assert_eq!(stats.full_refills, 1);
        let after_refill = stats.kv_bytes_copied;
        assert_eq!(after_refill, (5 * N_LAYER * SequenceCache::token_bytes(ROW)) as u64);
        for _ in 0..3 {
            append_row(&mut rng, &mut c, &mut pos);
            st.gather(&c, 7, 0, &mut lens, true, &mut stats).unwrap();
        }
        assert_eq!(stats.incremental_appends, 3);
        assert_eq!(
            stats.kv_bytes_copied - after_refill,
            (3 * N_LAYER * SequenceCache::token_bytes(ROW)) as u64,
            "each steady-state step copies exactly the appended rows"
        );
        slot_matches(&st, &c, 0).unwrap();
        assert_eq!(lens[0], 8);
    }

    #[test]
    fn destructive_ops_force_refill_and_seq_change_isolates_slots() {
        let mut rng = Rng::seed_from_u64(2);
        let (mut c, mut pos) = filled_cache(&mut rng, 6);
        let mut st = tier();
        let mut lens = vec![0i32; N_LAYER * B];
        let mut stats = GatherStats::default();
        st.gather(&c, 1, 0, &mut lens, true, &mut stats).unwrap();
        // Eviction: keep 4 of 6 rows in layer 0.
        c.retain(0, &[0, 2, 3, 5]).unwrap();
        st.gather(&c, 1, 0, &mut lens, true, &mut stats).unwrap();
        assert_eq!(stats.full_refills, 2, "retain must invalidate residency");
        slot_matches(&st, &c, 0).unwrap();
        // Pure append after the refill is incremental again.
        append_row(&mut rng, &mut c, &mut pos);
        st.gather(&c, 1, 0, &mut lens, true, &mut stats).unwrap();
        assert_eq!(stats.incremental_appends, 1);
        // A different sequence taking the slot refills even if its cache
        // generations happen to line up.
        let (other, _) = filled_cache(&mut rng, 3);
        st.gather(&other, 2, 0, &mut lens, true, &mut stats).unwrap();
        assert_eq!(stats.full_refills, 3, "slot reassignment must refill");
        slot_matches(&st, &other, 0).unwrap();
    }

    #[test]
    fn gather_error_clears_residency() {
        let mut rng = Rng::seed_from_u64(3);
        let (c, _) = filled_cache(&mut rng, 4);
        let mut st = tier();
        let mut lens = vec![0i32; N_LAYER * B];
        let mut stats = GatherStats::default();
        st.gather(&c, 1, 0, &mut lens, true, &mut stats).unwrap();
        // Overfull cache (len == M) makes the copy layer error out; the
        // slot must not keep claiming residency afterwards.
        let (big, _) = filled_cache(&mut rng, M);
        assert!(st.gather(&big, 1, 0, &mut lens, true, &mut stats).is_err());
        assert!(st.resident[0].is_none());
    }

    /// Random interleavings of append / retain / truncate / suspend-resume /
    /// slot reassignment / skipped steps: after every gather the scratch
    /// slot must match the cache byte-exactly (i.e. equal a freshly
    /// gathered shadow buffer), whether the gather took the incremental or
    /// the refill path.
    #[test]
    fn prop_random_interleavings_stay_byte_exact() {
        check("residency_byte_exact", 60, |rng| {
            let mut st = tier();
            let mut lens = vec![0i32; N_LAYER * B];
            let mut stats = GatherStats::default();
            // One live cache per slot.
            let mut caches: Vec<(SequenceCache, u32, u64)> = Vec::new();
            let mut next_seq = 0u64;
            for _ in 0..B {
                let rows = rng.range(1, 8);
                let (c, pos) = filled_cache(rng, rows);
                caches.push((c, pos, next_seq));
                next_seq += 1;
            }
            for _ in 0..40 {
                let b = rng.below(B);
                let (cache, pos, seq) = &mut caches[b];
                match rng.below(6) {
                    // Append 1-3 rows (plain decode or a spec burst).
                    0 | 1 => {
                        for _ in 0..rng.range(1, 4) {
                            if cache.max_layer_len() + 1 < M {
                                append_row(rng, cache, pos);
                            }
                        }
                    }
                    // Evict: keep a random subset of one layer.
                    2 => {
                        let l = rng.below(N_LAYER);
                        let n = cache.layer_len(l);
                        if n > 1 {
                            let mut keep = rng.choose_k(&(0..n).collect::<Vec<_>>(), n - 1);
                            keep.sort_unstable();
                            cache.retain(l, &keep).map_err(|e| e.to_string())?;
                        }
                    }
                    // Speculative rollback: drop the positional tail.
                    3 => {
                        if *pos > 1 {
                            let cut = rng.range(1, *pos as usize) as u32;
                            cache.truncate(cut as usize);
                            *pos = cut;
                        }
                    }
                    // Suspend/resume round-trip.
                    4 => {
                        let snap = cache.clone().snapshot();
                        *cache = snap.restore();
                    }
                    // Slot reassigned to a brand-new sequence.
                    5 => {
                        let rows = rng.range(1, 6);
                        let (c, p) = filled_cache(rng, rows);
                        *cache = c;
                        *pos = p;
                        *seq = next_seq;
                        next_seq += 1;
                    }
                    _ => unreachable!(),
                }
                // Some steps skip the gather (slot not in this step's
                // inputs); residency must tolerate syncing later.
                if rng.bool(0.75) {
                    let (cache, _, seq) = &caches[b];
                    st.gather(cache, *seq, b, &mut lens, true, &mut stats)
                        .map_err(|e| e.to_string())?;
                    slot_matches(&st, cache, b)?;
                    for l in 0..N_LAYER {
                        ensure_eq(
                            lens[l * B + b],
                            cache.layer_len(l) as i32,
                            "cache_lens refreshed",
                        )?;
                    }
                }
            }
            ensure(
                stats.incremental_appends > 0 || stats.full_refills > 0,
                "property exercised the gather path",
            )
        });
    }

    #[test]
    fn disallow_incremental_always_refills() {
        let mut rng = Rng::seed_from_u64(5);
        let (mut c, mut pos) = filled_cache(&mut rng, 4);
        let mut st = tier();
        let mut lens = vec![0i32; N_LAYER * B];
        let mut stats = GatherStats::default();
        for _ in 0..3 {
            st.gather(&c, 9, 1, &mut lens, false, &mut stats).unwrap();
            append_row(&mut rng, &mut c, &mut pos);
        }
        assert_eq!(stats.incremental_appends, 0);
        assert_eq!(stats.full_refills, 3);
        st.invalidate_all();
        assert!(st.resident[1].is_none());
    }
}
