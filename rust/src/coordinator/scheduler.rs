//! Continuous-batching scheduler state: the admission queue, the running
//! batch (decode slots), the suspended set (sequences swapped out to the
//! host tier), and the metrics that describe them.
//!
//! The scheduler is a passive state machine driven by `Engine::step`; each
//! step moves requests through
//!
//! ```text
//!   submit ──> queue ──admit──> running ──retire──> finished output
//!                ^  │              │ ^         │
//!      requeue   │  │     swap-out │ │ swap-in │ (resume at queue-front
//!   (host full:  │  │   (preempted │ │         │  priority: device reserve →
//!     restart)   │  │  on pool OOM)v │         │  restore → decode from
//!                └──│────────── suspended      │  next_pos)
//!                   │              │           │
//!                   └── cancel / deadline ─────┴──> Cancelled /
//!                     (every state; releases        DeadlineExceeded output
//!                      device or host bytes,
//!                      no swap-in needed)
//! ```
//!
//! * **Admission** fills free slots between decode steps from two sources,
//!   in strict priority order: (1) *suspended* sequences swap back in —
//!   their post-eviction KV snapshot migrates host→device and decoding
//!   continues from `next_pos` with no prefill; (2) *queued* requests
//!   prefill and join, gated by a KV-pool headroom estimate (see
//!   `Engine::estimate_admit_bytes`) so a full pool does not trigger
//!   wasted prefills.
//! * **Retirement** frees a slot the moment its sequence finishes (EOS /
//!   length / OOM), so the very next step can admit from the queue —
//!   requests join and leave a running batch mid-flight.
//! * **Preemption**: when a sequence cannot grow its KV reservation, the
//!   youngest running sequence (possibly the failing one itself — it then
//!   yields to older work) is *suspended*: its squeezed per-layer cache,
//!   budget plan, H2O accumulators, and decode position are snapshotted and
//!   the bytes migrate to the host-spill tier. Restart-from-scratch (the
//!   pre-suspend semantics: requeue the bare request, re-prefill later,
//!   discard partial output) survives only as the fallback when the host
//!   tier is full or disabled. The oldest sequence is never preempted,
//!   which guarantees forward progress; a sequence only fails with
//!   `FinishReason::Oom` if it cannot fit with the pool otherwise empty.
//! * **Cancellation / deadlines** (`Engine::lifecycle_phase`): at every
//!   step boundary, requests whose `CancelToken` fired or whose deadline
//!   lapsed leave whichever state they are in — the queue, a decode slot,
//!   or the suspended set — with `FinishReason::Cancelled` /
//!   `DeadlineExceeded`. Dropping the state releases its reservation
//!   (RAII), so a cancel while swapped out frees the host tier without a
//!   swap-in.
//! * **Speculative bursts** (`ServeConfig::spec`): a decode step may commit
//!   up to `draft_k + 1` tokens per slot via draft → verify → rollback
//!   (see `Engine`). The scheduler is burst-agnostic — each burst charges
//!   its `draft_k + 1`-row worst case up front through the same
//!   grow-with-preempt path a plain step uses, bursts are registered
//!   oldest-first so a preemption victim (always the youngest) is never a
//!   sequence already mid-burst, and every snapshot a suspend takes remains
//!   step-boundary consistent: drafted rows are truncated before any
//!   suspend can observe them. Acceptance statistics land in
//!   `SchedulerMetrics::{spec_steps, spec_drafted, spec_accepted,
//!   spec_rollback_tokens}`.
//! * **Fault containment** (`Engine::contain_step_error`): a backend error
//!   during the decode phase re-enters this state machine instead of
//!   escaping it — every occupied slot is suspended (or requeued, along
//!   the restart path above) while its per-request retry budget
//!   (`ServeConfig::max_retries`) lasts, and retires with
//!   `FinishReason::WorkerError` once it is spent. The queue and the
//!   suspended set are untouched, so one faulted batch never poisons
//!   waiting work; `SchedulerMetrics::{worker_errors, requests_retried,
//!   faults_injected}` count the damage.
//!
//! The scheduler owns no model state; `Active` carries everything a running
//! sequence needs (its per-sequence cache, budget plan, and RAII page
//! table, so dropping an `Active` always releases its pages), and
//! `Suspended` carries the same state frozen into a `SequenceSnapshot` plus
//! the page table — migrated to the host tier — that accounts for it while
//! it waits. Suspend/resume moves page-table entries, never byte blobs.

use std::collections::VecDeque;
use std::time::Instant;

use crate::kvcache::{CacheSnapshot, PageTable, SequenceCache};
use crate::metrics::SchedulerMetrics;
use crate::squeeze::BudgetPlan;

use super::request::{Request, RequestTiming};

/// A request waiting for admission, with its original submission time so
/// queue latency (and latency across preemptions) is accounted end-to-end.
pub(crate) struct Queued {
    pub req: Request,
    pub t_submit: Instant,
    /// True when this entry is a restart-from-scratch requeue of a request
    /// that already completed an admission (and so already delivered its
    /// first token): its re-admission must not record a second
    /// time-to-first-token sample.
    pub restarted: bool,
}

/// One sequence occupying a decode slot.
pub(crate) struct Active {
    pub req: Request,
    pub cache: SequenceCache,
    pub plan: BudgetPlan,
    /// Page-granular accounting for `cache`: every layer's slots mapped
    /// onto ref-counted pages of the engine's `PagedKvPool` (RAII — drop
    /// releases the pages).
    pub table: PageTable,
    pub generated: Vec<i32>,
    /// Absolute position of the *next* token to decode.
    pub next_pos: usize,
    pub last_token: i32,
    pub effective_max_new: usize,
    /// Admission ordinal — larger = younger (preemption picks the max).
    /// Preserved across suspend/resume so a resumed sequence keeps its age.
    pub seq: u64,
    pub t_submit: Instant,
    pub t_admit: Instant,
    /// When this sequence's most recent token was emitted (admission counts
    /// as the first token) — the anchor for inter-token-latency samples.
    pub t_last_token: Instant,
    pub timing: RequestTiming,
    pub peak_bytes: usize,
}

/// Everything a preempted sequence needs to continue decoding exactly where
/// it stopped: the squeezed per-layer KV (with H2O score accumulators inside
/// the slot metadata), the layer-budget plan, the emitted tokens, and the
/// decode position. Restoring this state and re-running the next decode step
/// is token-identical to never having been preempted — the decode output is
/// a pure function of (cache, last_token, next_pos).
pub(crate) struct SequenceSnapshot {
    pub cache: CacheSnapshot,
    pub plan: BudgetPlan,
    pub generated: Vec<i32>,
    pub next_pos: usize,
    pub last_token: i32,
    pub effective_max_new: usize,
    pub t_admit: Instant,
    /// Carried across the swap so resume's first inter-token-latency sample
    /// honestly includes the suspended gap.
    pub t_last_token: Instant,
    pub timing: RequestTiming,
    pub peak_bytes: usize,
}

/// A sequence swapped out of the device pool: its snapshot plus its page
/// table, already migrated to the host tier, accounting for the spilled
/// pages (RAII — dropping a `Suspended`, e.g. on a fatal engine fault,
/// releases the host pages).
pub(crate) struct Suspended {
    pub req: Request,
    pub snapshot: SequenceSnapshot,
    pub table: PageTable,
    pub seq: u64,
    pub t_submit: Instant,
    pub t_suspend: Instant,
}

impl Suspended {
    /// Freeze a preempted `Active` whose page table has already been
    /// migrated to the host tier. Inverse of [`Suspended::into_active`].
    pub(crate) fn from_active(a: Active) -> Self {
        let Active {
            req,
            cache,
            plan,
            table,
            generated,
            next_pos,
            last_token,
            effective_max_new,
            seq,
            t_submit,
            t_admit,
            t_last_token,
            timing,
            peak_bytes,
        } = a;
        Suspended {
            req,
            snapshot: SequenceSnapshot {
                cache: cache.snapshot(),
                plan,
                generated,
                next_pos,
                last_token,
                effective_max_new,
                t_admit,
                t_last_token,
                timing,
                peak_bytes,
            },
            table,
            seq,
            t_submit,
            t_suspend: Instant::now(),
        }
    }

    /// Thaw back into a running `Active` whose page table has already been
    /// migrated to the device tier, folding the time spent suspended into
    /// the request's timing. The preserved `seq` keeps the sequence's age —
    /// a resumed sequence is not "young" again for victim selection.
    pub(crate) fn into_active(self) -> Active {
        let Suspended { req, snapshot, table, seq, t_submit, t_suspend } = self;
        let SequenceSnapshot {
            cache,
            plan,
            generated,
            next_pos,
            last_token,
            effective_max_new,
            t_admit,
            t_last_token,
            mut timing,
            peak_bytes,
        } = snapshot;
        timing.suspended_s += t_suspend.elapsed().as_secs_f64();
        Active {
            req,
            cache: cache.restore(),
            plan,
            table,
            generated,
            next_pos,
            last_token,
            effective_max_new,
            seq,
            t_submit,
            t_admit,
            t_last_token,
            timing,
            peak_bytes,
        }
    }
}

/// Queue + running batch + suspended set + counters. Created sized to the
/// engine's decode slot count; `Default` builds an empty zero-slot scheduler
/// (used only to move the real one out of the engine during a step).
pub struct Scheduler {
    pub(crate) queue: VecDeque<Queued>,
    pub(crate) slots: Vec<Option<Active>>,
    /// Swapped-out sequences, ordered oldest-work-first (LIFO over
    /// suspension order: preemption picks the youngest, so the last
    /// sequence suspended is the oldest of the suspended set and resumes
    /// first).
    pub(crate) suspended: VecDeque<Suspended>,
    pub(crate) metrics: SchedulerMetrics,
    pub(crate) next_seq: u64,
    /// Queue backpressure threshold (0 = unbounded).
    pub(crate) max_queue: usize,
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::new(0, 0)
    }
}

impl Scheduler {
    pub(crate) fn new(slots: usize, max_queue: usize) -> Self {
        Self {
            queue: VecDeque::new(),
            slots: (0..slots).map(|_| None).collect(),
            suspended: VecDeque::new(),
            metrics: SchedulerMetrics { slots, ..Default::default() },
            next_seq: 0,
            max_queue,
        }
    }

    pub fn running(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn suspended_len(&self) -> usize {
        self.suspended.len()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty()
            && self.suspended.is_empty()
            && self.slots.iter().all(|s| s.is_none())
    }

    pub fn metrics(&self) -> &SchedulerMetrics {
        &self.metrics
    }

    pub(crate) fn has_free_slot(&self) -> bool {
        self.slots.iter().any(|s| s.is_none())
    }

    /// Enqueue at the back; `Err` returns the item when backpressure applies
    /// (cap enforced only for `enforce_cap`, i.e. open-loop submission).
    /// Every call counts as one submission (`metrics.submitted`), accepted
    /// or rejected — `requeue_front` re-queues are deliberately not counted,
    /// which is what keeps the conservation identity on `SchedulerMetrics`
    /// exact across preemptions and retries.
    pub(crate) fn enqueue(&mut self, q: Queued, enforce_cap: bool) -> Result<(), Queued> {
        self.metrics.submitted += 1;
        if enforce_cap && self.max_queue > 0 && self.queue.len() >= self.max_queue {
            self.metrics.rejected += 1;
            return Err(q);
        }
        self.queue.push_back(q);
        self.note_queue();
        Ok(())
    }

    /// Requeue at the front (restart-from-scratch preemption / transient
    /// admission failure) — never subject to the backpressure cap.
    pub(crate) fn requeue_front(&mut self, q: Queued) {
        self.queue.push_front(q);
        self.note_queue();
    }

    pub(crate) fn pop_queue(&mut self) -> Option<Queued> {
        let q = self.queue.pop_front();
        self.metrics.queue_depth = self.queue.len();
        q
    }

    /// Park a swapped-out sequence. Pushed to the *front*: preemption always
    /// picks the youngest running sequence, so the most recently suspended
    /// entry is the oldest work in the suspended set and must resume first
    /// (oldest-first resume is what keeps the age order, and thus forward
    /// progress, intact across swap cycles).
    pub(crate) fn suspend(&mut self, s: Suspended) {
        self.suspended.push_front(s);
        self.metrics.suspended = self.suspended.len();
    }

    pub(crate) fn peek_suspended(&self) -> Option<&Suspended> {
        self.suspended.front()
    }

    pub(crate) fn pop_suspended(&mut self) -> Option<Suspended> {
        let s = self.suspended.pop_front();
        self.metrics.suspended = self.suspended.len();
        s
    }

    fn note_queue(&mut self) {
        self.metrics.queue_depth = self.queue.len();
        self.metrics.queue_peak = self.metrics.queue_peak.max(self.queue.len());
    }

    /// Place a newly admitted sequence into the first free slot.
    pub(crate) fn place(&mut self, active: Active) {
        let slot = self
            .slots
            .iter_mut()
            .find(|s| s.is_none())
            .expect("place() requires a free slot");
        *slot = Some(active);
        self.metrics.admitted += 1;
        let running = self.running();
        self.metrics.running = running;
        self.metrics.peak_occupancy = self.metrics.peak_occupancy.max(running);
    }

    /// Index of the youngest running sequence (largest admission ordinal) —
    /// the preemption victim. LIFO preemption keeps the oldest work moving,
    /// which is what guarantees forward progress under a capped pool.
    pub(crate) fn youngest_running(&self) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .max_by_key(|(_, s)| s.as_ref().map(|a| a.seq))
            .map(|(i, _)| i)
    }

    /// Refresh the gauges after a step.
    pub(crate) fn note_step(&mut self, batch_occupancy: usize) {
        self.metrics.steps += 1;
        self.metrics.occupancy_sum += batch_occupancy as u64;
        self.refresh_gauges();
    }

    /// Refresh the occupancy/queue/suspended gauges (used by retirements and
    /// fault paths that bypass `note_step`, so an idle engine never reports
    /// a phantom running sequence).
    pub(crate) fn refresh_gauges(&mut self) {
        self.metrics.running = self.running();
        self.metrics.queue_depth = self.queue.len();
        self.metrics.suspended = self.suspended.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{KvPool, PagedKvPool, Tier};

    /// 64-byte pages over an unlimited pool; token_bytes below is 32, so
    /// two slots fit one page.
    fn paged() -> PagedKvPool {
        PagedKvPool::new(KvPool::unlimited(), 64)
    }

    fn dummy_active(seq: u64, pool: &PagedKvPool) -> Active {
        Active {
            req: Request::new(seq, vec![1, 2, 3], 4),
            cache: SequenceCache::new(1, 4),
            plan: BudgetPlan::uniform(1, 8),
            table: PageTable::new(pool, Tier::Device, 1, 32),
            generated: vec![],
            next_pos: 3,
            last_token: 1,
            effective_max_new: 4,
            seq,
            t_submit: Instant::now(),
            t_admit: Instant::now(),
            t_last_token: Instant::now(),
            timing: RequestTiming::default(),
            peak_bytes: 0,
        }
    }

    fn dummy_suspended(seq: u64, pool: &PagedKvPool) -> Suspended {
        let now = Instant::now();
        // One host page charged, as a real swapped-out sequence would hold.
        let mut table = PageTable::new(pool, Tier::Host, 1, 32);
        table.grow(&[0], &[1]).unwrap();
        Suspended {
            req: Request::new(seq, vec![1, 2, 3], 4),
            snapshot: SequenceSnapshot {
                cache: SequenceCache::new(1, 4).snapshot(),
                plan: BudgetPlan::uniform(1, 8),
                generated: vec![7],
                next_pos: 3,
                last_token: 7,
                effective_max_new: 4,
                t_admit: now,
                t_last_token: now,
                timing: RequestTiming::default(),
                peak_bytes: 0,
            },
            table,
            seq,
            t_submit: now,
            t_suspend: now,
        }
    }

    #[test]
    fn queue_cap_and_requeue_bypass() {
        let mut s = Scheduler::new(2, 2);
        let q = |id| Queued {
            req: Request::new(id, vec![1], 1),
            t_submit: Instant::now(),
            restarted: false,
        };
        assert!(s.enqueue(q(0), true).is_ok());
        assert!(s.enqueue(q(1), true).is_ok());
        assert!(s.enqueue(q(2), true).is_err());
        assert_eq!(s.metrics().rejected, 1);
        // requeue ignores the cap and goes to the front
        s.requeue_front(q(9));
        assert_eq!(s.queue_len(), 3);
        assert_eq!(s.pop_queue().unwrap().req.id, 9);
        assert_eq!(s.metrics().queue_peak, 3);
    }

    #[test]
    fn place_and_youngest_selection() {
        let pool = paged();
        let mut s = Scheduler::new(3, 0);
        s.place(dummy_active(10, &pool));
        s.place(dummy_active(11, &pool));
        s.place(dummy_active(12, &pool));
        assert_eq!(s.running(), 3);
        assert_eq!(s.metrics().peak_occupancy, 3);
        // youngest overall is slot 2 (seq 12)
        assert_eq!(s.youngest_running(), Some(2));
        s.slots[2] = None;
        assert_eq!(s.youngest_running(), Some(1));
        s.slots[1] = None;
        assert_eq!(s.youngest_running(), Some(0));
        s.slots[0] = None;
        assert_eq!(s.youngest_running(), None);
        assert!(s.is_idle());
    }

    #[test]
    fn suspended_resume_order_is_oldest_first() {
        let pool = paged();
        let mut s = Scheduler::new(2, 0);
        // Preemption order: youngest first — seq 12 suspended before seq 11.
        s.suspend(dummy_suspended(12, &pool));
        s.suspend(dummy_suspended(11, &pool));
        assert_eq!(s.suspended_len(), 2);
        assert_eq!(s.metrics().suspended, 2);
        assert!(!s.is_idle(), "suspended sequences are live work");
        // Oldest work (seq 11, suspended last) resumes first.
        assert_eq!(s.peek_suspended().unwrap().seq, 11);
        assert_eq!(s.pop_suspended().unwrap().seq, 11);
        assert_eq!(s.pop_suspended().unwrap().seq, 12);
        assert_eq!(s.metrics().suspended, 0);
        assert!(s.is_idle());
        // Host pages released when the Suspended entries dropped.
        assert_eq!(pool.pool().in_use_of(Tier::Host), 0);
    }

    #[test]
    fn step_gauges() {
        let mut s = Scheduler::new(4, 0);
        s.note_step(3);
        s.note_step(1);
        assert_eq!(s.metrics().steps, 2);
        assert!((s.metrics().mean_occupancy() - 2.0).abs() < 1e-12);
    }
}
