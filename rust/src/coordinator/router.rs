//! Multi-worker router: spreads requests across engine workers.
//!
//! Each worker owns an `Engine` on a dedicated thread (the engine is
//! synchronous; PJRT-CPU execution is compute-bound) and pulls work from its
//! own channel. The router assigns each incoming request to the worker with
//! the least outstanding work (least-loaded, falling back to round-robin on
//! ties) — the same shape as vLLM's router in front of engine replicas.
//! Plain std threading: the offline dependency set has no tokio.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::Result;

use crate::config::ServeConfig;

use super::engine::Engine;
use super::request::{Request, RequestOutput};

struct WorkerHandle {
    tx: mpsc::Sender<Job>,
    inflight: Arc<AtomicUsize>,
}

struct Job {
    request: Request,
    reply: mpsc::Sender<RequestOutput>,
}

/// Routing discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
}

pub struct Router {
    workers: Vec<WorkerHandle>,
    next: AtomicUsize,
    policy: RoutePolicy,
}

impl Router {
    /// Spawn `n_workers` engines (each compiles its own executables).
    ///
    /// The PJRT client is not `Send` (it holds `Rc` internals), so each
    /// engine is constructed *inside* its worker thread; construction errors
    /// are reported back over a readiness channel before `spawn` returns.
    pub fn spawn(cfg: ServeConfig, n_workers: usize, policy: RoutePolicy) -> Result<Self> {
        let mut workers = Vec::new();
        for w in 0..n_workers.max(1) {
            let (tx, rx) = mpsc::channel::<Job>();
            let inflight = Arc::new(AtomicUsize::new(0));
            let inflight2 = inflight.clone();
            let cfg = cfg.clone();
            let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
            std::thread::spawn(move || match Engine::new(cfg) {
                Ok(engine) => {
                    let _ = ready_tx.send(Ok(()));
                    worker_loop(engine, rx, inflight2);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                }
            });
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("worker {w} died during startup"))?
                .map_err(|e| anyhow::anyhow!("worker {w} failed to start: {e}"))?;
            workers.push(WorkerHandle { tx, inflight });
        }
        Ok(Self { workers, next: AtomicUsize::new(0), policy })
    }

    fn pick(&self) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                self.next.fetch_add(1, Ordering::Relaxed) % self.workers.len()
            }
            RoutePolicy::LeastLoaded => self
                .workers
                .iter()
                .enumerate()
                .min_by_key(|(i, w)| (w.inflight.load(Ordering::Relaxed), *i))
                .map(|(i, _)| i)
                .unwrap_or(0),
        }
    }

    /// Route one request; blocks until its worker finishes it.
    pub fn submit(&self, request: Request) -> Result<RequestOutput> {
        Ok(self.submit_async(request)?.recv()?)
    }

    /// Route one request; returns a receiver for the eventual output (lets a
    /// caller pipeline many requests before collecting).
    pub fn submit_async(&self, request: Request) -> Result<mpsc::Receiver<RequestOutput>> {
        let w = &self.workers[self.pick()];
        w.inflight.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        w.tx
            .send(Job { request, reply })
            .map_err(|_| anyhow::anyhow!("worker channel closed"))?;
        Ok(rx)
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn inflight(&self) -> usize {
        self.workers.iter().map(|w| w.inflight.load(Ordering::Relaxed)).sum()
    }
}

/// Worker loop: micro-batches whatever is queued (up to the engine's slot
/// count) into one `generate_batch` call — the dynamic batching the paper's
/// throughput tables rely on.
fn worker_loop(mut engine: Engine, rx: mpsc::Receiver<Job>, inflight: Arc<AtomicUsize>) {
    while let Ok(first) = rx.recv() {
        let mut jobs = vec![first];
        while jobs.len() < engine.slot_count() {
            match rx.try_recv() {
                Ok(j) => jobs.push(j),
                Err(_) => break,
            }
        }
        let requests: Vec<Request> = jobs.iter().map(|j| j.request.clone()).collect();
        let mut outputs = engine.generate_batch(requests);
        // generate_batch returns outputs sorted by id; match them back.
        for job in jobs {
            let idx = outputs.iter().position(|o| o.id == job.request.id);
            if let Some(i) = idx {
                let _ = job.reply.send(outputs.swap_remove(i));
            }
            inflight.fetch_sub(1, Ordering::Relaxed);
        }
    }
}
