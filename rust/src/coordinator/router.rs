//! Multi-worker router: spreads requests across engine workers.
//!
//! Each worker owns an `Engine` on a dedicated thread (the engine is
//! synchronous; PJRT-CPU execution is compute-bound) and pulls work from its
//! own channel. The router assigns each incoming request to the worker with
//! the least outstanding work (least-loaded, falling back to round-robin on
//! ties) — the same shape as vLLM's router in front of engine replicas.
//! Plain std threading: the offline dependency set has no tokio.
//!
//! The worker loop is step-driven: it drains its channel into the engine's
//! scheduler queue between decode steps, so a request submitted while a
//! batch is running joins that batch at the next step instead of waiting
//! for the whole batch to finish (continuous batching across the network
//! path). When `ServeConfig::batch_wait_ms > 0`, a worker forming a fresh
//! batch from idle waits up to that long for more arrivals before its first
//! step, so near-simultaneous requests decode together from step one
//! (occupancy vs first-token-latency tradeoff). Request ids are rewritten
//! to a worker-local ticket while in flight, so concurrent connections may
//! reuse ids safely.
//!
//! `submit_stream` is the lifecycle-aware entry point: it attaches a
//! `RequestHandle` (event stream + cancel token) to the request before
//! routing, so token/suspend/terminal events flow from the worker's engine
//! to the subscriber as they happen — the router forwards events rather
//! than waiting on completed outputs, and the sink rewrites worker-local
//! ticket ids back to the caller's. `metrics_json` exports per-worker
//! scheduler counters and queue/TTFT/ITL latency summaries.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::ServeConfig;
use crate::metrics::{HistogramSummary, SchedulerMetrics};
use crate::util::Json;

use super::engine::Engine;
use super::lifecycle::RequestHandle;
use super::request::{Request, RequestOutput};

/// Per-worker observability snapshot, refreshed after every decode step:
/// the scheduler counters plus the engine's latency histograms (queue wait,
/// time-to-first-token, inter-token latency) summarized for export.
#[derive(Debug, Clone, Default)]
pub struct WorkerSnapshot {
    pub sched: SchedulerMetrics,
    pub queue_latency: HistogramSummary,
    pub ttft: HistogramSummary,
    pub itl: HistogramSummary,
}

impl WorkerSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scheduler", self.sched.to_json()),
            ("queue_latency_s", self.queue_latency.to_json()),
            ("ttft_s", self.ttft.to_json()),
            ("itl_s", self.itl.to_json()),
        ])
    }
}

struct WorkerHandle {
    tx: mpsc::Sender<Job>,
    inflight: Arc<AtomicUsize>,
    /// Snapshot of the worker's scheduler metrics + latency summaries,
    /// refreshed after every step (engines live on their worker threads;
    /// this is the only window into their counters).
    metrics: Arc<Mutex<WorkerSnapshot>>,
}

struct Job {
    request: Request,
    reply: mpsc::Sender<RequestOutput>,
}

/// Routing discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
}

pub struct Router {
    workers: Vec<WorkerHandle>,
    next: AtomicUsize,
    policy: RoutePolicy,
}

impl Router {
    /// Spawn `n_workers` engines (each compiles its own executables).
    ///
    /// The PJRT client is not `Send` (it holds `Rc` internals), so each
    /// engine is constructed *inside* its worker thread; construction errors
    /// are reported back over a readiness channel before `spawn` returns.
    pub fn spawn(cfg: ServeConfig, n_workers: usize, policy: RoutePolicy) -> Result<Self> {
        let mut workers = Vec::new();
        for w in 0..n_workers.max(1) {
            let (tx, rx) = mpsc::channel::<Job>();
            let inflight = Arc::new(AtomicUsize::new(0));
            let inflight2 = inflight.clone();
            let metrics = Arc::new(Mutex::new(WorkerSnapshot::default()));
            let metrics2 = metrics.clone();
            let cfg = cfg.clone();
            let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
            std::thread::spawn(move || match Engine::new(cfg) {
                Ok(engine) => {
                    let _ = ready_tx.send(Ok(()));
                    worker_loop(engine, rx, inflight2, metrics2);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                }
            });
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("worker {w} died during startup"))?
                .map_err(|e| anyhow::anyhow!("worker {w} failed to start: {e}"))?;
            workers.push(WorkerHandle { tx, inflight, metrics });
        }
        Ok(Self { workers, next: AtomicUsize::new(0), policy })
    }

    fn pick(&self) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                self.next.fetch_add(1, Ordering::Relaxed) % self.workers.len()
            }
            RoutePolicy::LeastLoaded => self
                .workers
                .iter()
                .enumerate()
                .min_by_key(|(i, w)| (w.inflight.load(Ordering::Relaxed), *i))
                .map(|(i, _)| i)
                .unwrap_or(0),
        }
    }

    /// Route one request; blocks until its worker finishes it.
    pub fn submit(&self, request: Request) -> Result<RequestOutput> {
        Ok(self.submit_async(request)?.recv()?)
    }

    /// Route one request; returns a receiver for the eventual output. The
    /// request enters its worker's scheduler queue immediately and joins the
    /// running batch at that worker's next decode step — callers pipeline
    /// many requests and collect later.
    pub fn submit_async(&self, request: Request) -> Result<mpsc::Receiver<RequestOutput>> {
        let w = &self.workers[self.pick()];
        w.inflight.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        w.tx
            .send(Job { request, reply })
            .map_err(|_| anyhow::anyhow!("worker channel closed"))?;
        Ok(rx)
    }

    /// Route one request and subscribe to its lifecycle: the returned
    /// handle carries the per-request event stream (Started, one Token per
    /// decoded token, Suspended/Resumed, and a terminal Done/Cancelled/
    /// Error with the final output) plus `cancel()`. Events are forwarded
    /// out of the worker as its engine decodes — a streaming consumer
    /// never waits for completion, and events carry the id the caller
    /// submitted with (worker-local ticket rewriting is invisible).
    pub fn submit_stream(&self, mut request: Request) -> Result<RequestHandle> {
        let handle = RequestHandle::attach(&mut request);
        let w = &self.workers[self.pick()];
        w.inflight.fetch_add(1, Ordering::Relaxed);
        // The worker's reply path still runs for inflight bookkeeping; the
        // subscriber consumes the event stream instead, so the receiver is
        // dropped here and the eventual reply send is a silent no-op.
        let (reply, _unused) = mpsc::channel();
        w.tx
            .send(Job { request, reply })
            .map_err(|_| anyhow::anyhow!("worker channel closed"))?;
        Ok(handle)
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn inflight(&self) -> usize {
        self.workers.iter().map(|w| w.inflight.load(Ordering::Relaxed)).sum()
    }

    /// Per-worker scheduler-metrics snapshots (refreshed after each decode
    /// step), for observability across the thread boundary: queue depth,
    /// occupancy, preemptions, swap-outs/ins.
    pub fn sched_metrics(&self) -> Vec<SchedulerMetrics> {
        self.snapshots().into_iter().map(|s| s.sched).collect()
    }

    /// Per-worker full snapshots: scheduler counters plus queue/TTFT/ITL
    /// latency summaries.
    pub fn snapshots(&self) -> Vec<WorkerSnapshot> {
        self.workers
            .iter()
            .map(|w| w.metrics.lock().map(|m| (*m).clone()).unwrap_or_default())
            .collect()
    }

    /// JSON metrics export: one object per worker (scheduler counters,
    /// queue-latency / time-to-first-token / inter-token-latency summaries)
    /// plus router-level gauges. Served over the wire protocol via a
    /// `{"metrics": true}` control line.
    pub fn metrics_json(&self) -> Json {
        Json::obj(vec![
            ("workers", Json::arr(self.snapshots().iter().map(|s| s.to_json()))),
            ("inflight", Json::num(self.inflight() as f64)),
            ("n_workers", Json::num(self.n_workers() as f64)),
        ])
    }
}

/// In-flight bookkeeping for one submitted job: where to send the output and
/// the caller's original request id (ids are rewritten to worker-local
/// tickets while inside the engine).
struct Pending {
    reply: mpsc::Sender<RequestOutput>,
    original_id: u64,
}

/// Worker loop: continuous batching. Jobs are pulled into the engine's
/// scheduler queue whenever the loop is between decode steps — non-blocking
/// while the engine has work (so new arrivals join the running batch), and a
/// blocking `recv` only when idle.
fn worker_loop(
    mut engine: Engine,
    rx: mpsc::Receiver<Job>,
    inflight: Arc<AtomicUsize>,
    metrics: Arc<Mutex<WorkerSnapshot>>,
) {
    let mut pending: HashMap<u64, Pending> = HashMap::new();
    let mut ticket: u64 = 0;
    loop {
        // Ingest: block only when idle; otherwise take whatever is queued.
        let was_idle = !engine.has_work();
        if was_idle && pending.is_empty() {
            match rx.recv() {
                Ok(job) => ingest(&mut engine, job, &mut pending, &mut ticket, &inflight),
                Err(_) => return, // router dropped — shut down
            }
        }
        while let Ok(job) = rx.try_recv() {
            ingest(&mut engine, job, &mut pending, &mut ticket, &inflight);
        }

        // Batch forming: when work just arrived at an idle engine and the
        // batch is still smaller than the slot count, wait up to
        // `batch_wait_ms` for more arrivals so they decode together from
        // the first step instead of joining mid-flight.
        let wait_ms = engine.config().batch_wait_ms;
        if was_idle && wait_ms > 0 {
            let deadline = Instant::now() + Duration::from_millis(wait_ms);
            while engine.queued_len() + engine.running_len() + engine.suspended_len()
                < engine.slot_count()
            {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(job) => ingest(&mut engine, job, &mut pending, &mut ticket, &inflight),
                    Err(_) => break, // timeout or disconnect: step what we have
                }
            }
        }

        // One decode step; completed requests are answered immediately.
        // (step() resolves decode faults internally by failing requests in
        // place — the Err arm is defensive, for future fatal error sources.)
        let outputs = match engine.step() {
            Ok(outs) => outs,
            Err(e) => {
                eprintln!("worker step failed: {e:#}");
                engine.drain()
            }
        };
        // Snapshot counters + latency summaries for the router. Summary
        // re-sorts a histogram only when it gained samples since the last
        // call, and samples are capped engine-side, so this stays cheap
        // relative to a decode step.
        {
            let sched = engine.sched_metrics().clone();
            let queue_latency = engine.queue_latency().summary();
            let ttft = engine.ttft_latency().summary();
            let itl = engine.itl_latency().summary();
            if let Ok(mut m) = metrics.lock() {
                *m = WorkerSnapshot { sched, queue_latency, ttft, itl };
            }
        }
        for mut out in outputs {
            if let Some(p) = pending.remove(&out.id) {
                out.id = p.original_id;
                let _ = p.reply.send(out);
                inflight.fetch_sub(1, Ordering::Relaxed);
            }
        }

        // Defensive: an idle engine with pending entries means outputs were
        // lost (engine invariant violated). Drop the reply senders so the
        // callers error out instead of hanging, and avoid a busy spin here.
        if !engine.has_work() && !pending.is_empty() {
            eprintln!("worker: {} request(s) vanished without output", pending.len());
            for _ in pending.drain() {
                inflight.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }
}

fn ingest(
    engine: &mut Engine,
    job: Job,
    pending: &mut HashMap<u64, Pending>,
    ticket: &mut u64,
    inflight: &Arc<AtomicUsize>,
) {
    let Job { mut request, reply } = job;
    let original_id = request.id;
    let id = *ticket;
    *ticket += 1;
    request.id = id;
    match engine.submit(request) {
        Ok(()) => {
            pending.insert(id, Pending { reply, original_id });
        }
        Err(mut out) => {
            // Queue backpressure: answer the rejection immediately.
            out.id = original_id;
            let _ = reply.send(out);
            inflight.fetch_sub(1, Ordering::Relaxed);
        }
    }
}
