//! Multi-worker router: spreads requests across supervised engine workers.
//!
//! Each worker owns an `Engine` on a dedicated thread (the engine is
//! synchronous; PJRT-CPU execution is compute-bound) and pulls work from its
//! own crash-surviving inbox (`supervisor::WorkerQueue`). The router assigns
//! each incoming request to the worker with the least outstanding work
//! (least-loaded, falling back to round-robin on ties) — the same shape as
//! vLLM's router in front of engine replicas. Plain std threading: the
//! offline dependency set has no tokio.
//!
//! ```text
//!   submit / submit_async / submit_stream
//!        |
//!        v
//!   admission -- shed? --> Err(RouteError::Overloaded{retry_after_ms})
//!        |                 (queue depth / projected queue latency bounds)
//!        v
//!   pick: least-loaded HEALTHY worker (Draining as fallback,
//!        |                             Dead skipped entirely)
//!        v
//!   WorkerQueue -> worker thread -> Engine  (heartbeat every loop)
//!                        ^
//!                        |   supervisor thread (10ms tick): stale beat ->
//!                        |   Draining; dead thread -> fail in-flight with
//!                        +-- WorkerError, re-route queued jobs, bounded
//!                            respawn with backoff (see supervisor.rs)
//! ```
//!
//! The worker loop is step-driven: it drains its inbox into the engine's
//! scheduler queue between decode steps, so a request submitted while a
//! batch is running joins that batch at the next step instead of waiting
//! for the whole batch to finish (continuous batching across the network
//! path). When `ServeConfig::batch_wait_ms > 0`, a worker forming a fresh
//! batch from idle waits up to that long for more arrivals before its first
//! step, so near-simultaneous requests decode together from step one
//! (occupancy vs first-token-latency tradeoff). Request ids are rewritten
//! to a worker-local ticket while in flight, so concurrent connections may
//! reuse ids safely.
//!
//! `submit_stream` is the lifecycle-aware entry point: it attaches a
//! `RequestHandle` (event stream + cancel token) to the request before
//! routing, so token/suspend/terminal events flow from the worker's engine
//! to the subscriber as they happen — the router forwards events rather
//! than waiting on completed outputs, and the sink rewrites worker-local
//! ticket ids back to the caller's. `submit_async` returns a `ReplyHandle`
//! whose drop cancels the request, so abandoned callers release their KV
//! reservations instead of decoding to `max_new_tokens`. `metrics_json`
//! exports per-worker scheduler counters, health, and queue/TTFT/ITL latency
//! summaries plus router-level shed/restart totals; `metrics_prom` renders
//! the same data as Prometheus text exposition, `trace_json` answers
//! per-request span queries against each worker's flight recorder, and
//! `last_flight_dump` surfaces the crash report a dead worker left behind.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::ServeConfig;
use crate::metrics::{HistogramSummary, PromWriter, SchedulerMetrics};
use crate::util::Json;

use super::engine::Engine;
use super::lifecycle::{CancelToken, RequestHandle};
use super::request::{Request, RequestOutput};
use super::supervisor::{
    self, Health, Job, PendingJob, Pop, ReplyHandle, RouteError, SupervisorCtx, WorkerShared,
};

/// How long an idle worker blocks on its inbox before publishing another
/// heartbeat. Bounds supervisor staleness detection for idle workers.
const HEARTBEAT: Duration = Duration::from_millis(50);

/// Per-worker observability snapshot: the scheduler counters plus the
/// engine's latency histograms (queue wait, time-to-first-token, inter-token
/// latency) summarized for export, the telemetry payloads (step-phase
/// timing, per-layer squeeze table, throughput window — `Json::Null` when
/// tracing is off), refreshed after every decode step, and the supervisor's
/// view (health state, restart count) stamped by `Router::snapshots`.
#[derive(Debug, Clone)]
pub struct WorkerSnapshot {
    pub sched: SchedulerMetrics,
    pub queue_latency: HistogramSummary,
    pub ttft: HistogramSummary,
    pub itl: HistogramSummary,
    /// Step-phase timing summaries (`Engine::phase_json`): seconds per step
    /// spent in admission/gather/model/verify/evict/commit. Populated at
    /// `--trace-level full`, `Json::Null` otherwise.
    pub phases: Json,
    /// Per-layer squeeze table (`Engine::squeeze_table_json`): cumulative
    /// evicted rows/bytes per layer plus each active sequence's resolved
    /// `BudgetPlan` (budgets, groups, cosine layer means).
    pub squeeze: Json,
    /// Throughput counters + current-window rates
    /// (`Engine::throughput_json`).
    pub throughput: Json,
    /// False when the worker is draining/dead or its metrics mutex is
    /// poisoned (it died mid-publish).
    pub healthy: bool,
    /// `"healthy"`, `"draining"`, or `"dead"`.
    pub state: String,
    /// Respawn attempts consumed for this worker slot.
    pub restarts: u64,
}

impl Default for WorkerSnapshot {
    fn default() -> Self {
        Self {
            sched: SchedulerMetrics::default(),
            queue_latency: HistogramSummary::default(),
            ttft: HistogramSummary::default(),
            itl: HistogramSummary::default(),
            phases: Json::Null,
            squeeze: Json::Null,
            throughput: Json::Null,
            healthy: false,
            state: String::new(),
            restarts: 0,
        }
    }
}

impl WorkerSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scheduler", self.sched.to_json()),
            ("queue_latency_s", self.queue_latency.to_json()),
            ("ttft_s", self.ttft.to_json()),
            ("itl_s", self.itl.to_json()),
            ("phases", self.phases.clone()),
            ("squeeze", self.squeeze.clone()),
            ("throughput", self.throughput.clone()),
            ("healthy", Json::Bool(self.healthy)),
            ("state", Json::str(self.state.clone())),
            ("restarts", Json::num(self.restarts as f64)),
        ])
    }
}

/// Routing discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
}

pub struct Router {
    workers: Vec<Arc<WorkerShared>>,
    next: AtomicUsize,
    policy: RoutePolicy,
    cfg: ServeConfig,
    shutdown: Arc<AtomicBool>,
    supervisor: Option<JoinHandle<()>>,
    requests_shed: AtomicU64,
}

impl Router {
    /// Spawn `n_workers` engines (each compiles its own executables) plus
    /// the supervisor thread watching them.
    ///
    /// The PJRT client is not `Send` (it holds `Rc` internals), so each
    /// engine is constructed *inside* its worker thread; construction errors
    /// are reported back over a readiness channel before `spawn` returns.
    /// On a partial failure (worker `k` fails to start) the `0..k` workers
    /// already running are shut down and joined before the error — naming
    /// worker `k` — is returned: `spawn` never leaks threads.
    pub fn spawn(cfg: ServeConfig, n_workers: usize, policy: RoutePolicy) -> Result<Self> {
        let start = Instant::now();
        let mut workers: Vec<Arc<WorkerShared>> = Vec::new();
        for idx in 0..n_workers.max(1) {
            let shared = Arc::new(WorkerShared::new(start, cfg.trace_level));
            if let Err(e) = supervisor::spawn_worker(idx, shared.clone(), cfg.clone(), start) {
                for prev in &workers {
                    prev.queue.close();
                    if let Some(h) = prev.thread_take() {
                        let _ = h.join();
                    }
                }
                return Err(e);
            }
            workers.push(shared);
        }
        let shutdown = Arc::new(AtomicBool::new(false));
        let ctx = SupervisorCtx {
            workers: workers.clone(),
            cfg: cfg.clone(),
            start,
            shutdown: shutdown.clone(),
        };
        let supervisor = std::thread::Builder::new()
            .name("sa-supervisor".into())
            .spawn(move || supervisor::supervise(ctx))
            .map_err(|e| anyhow::anyhow!("supervisor thread spawn failed: {e}"))?;
        Ok(Self {
            workers,
            next: AtomicUsize::new(0),
            policy,
            cfg,
            shutdown,
            supervisor: Some(supervisor),
            requests_shed: AtomicU64::new(0),
        })
    }

    /// Pick a worker and pass admission control. Dead workers are skipped;
    /// Draining ones serve only when nothing is Healthy.
    fn pick(&self) -> std::result::Result<usize, RouteError> {
        let by_health = |h: Health| -> Vec<usize> {
            self.workers
                .iter()
                .enumerate()
                .filter(|(_, w)| w.health() == h)
                .map(|(i, _)| i)
                .collect()
        };
        let mut cands = by_health(Health::Healthy);
        if cands.is_empty() {
            cands = by_health(Health::Draining);
        }
        if cands.is_empty() {
            return Err(RouteError::NoHealthyWorker);
        }
        let i = match self.policy {
            RoutePolicy::RoundRobin => {
                cands[self.next.fetch_add(1, Ordering::Relaxed) % cands.len()]
            }
            RoutePolicy::LeastLoaded => cands
                .into_iter()
                .min_by_key(|&i| (self.workers[i].inflight.load(Ordering::Relaxed), i))
                .expect("non-empty"),
        };
        self.admit(i)?;
        Ok(i)
    }

    /// Load shedding: reject before the request consumes worker resources
    /// when the picked (least-loaded) worker is already over the configured
    /// queue-depth or projected queue-latency bound. A bound of 0 disables
    /// that check.
    fn admit(&self, i: usize) -> std::result::Result<(), RouteError> {
        let w = &self.workers[i];
        let depth = self.cfg.shed_queue_depth;
        if depth > 0 && w.inflight.load(Ordering::Relaxed) >= depth {
            return Err(self.shed(w));
        }
        let bound_ms = self.cfg.shed_queue_latency_ms;
        if bound_ms > 0 {
            let p95_s = w.metrics.lock().map(|m| m.queue_latency.p95).unwrap_or(0.0);
            if p95_s.is_finite() && p95_s * 1000.0 >= bound_ms as f64 {
                return Err(self.shed(w));
            }
        }
        Ok(())
    }

    fn shed(&self, w: &WorkerShared) -> RouteError {
        self.requests_shed.fetch_add(1, Ordering::Relaxed);
        // Retry-After hint: the worker's median queue wait is the best
        // single predictor of when capacity frees up; clamp to a sane range.
        let p50_s = w.metrics.lock().map(|m| m.queue_latency.p50).unwrap_or(0.0);
        let hint = if p50_s.is_finite() && p50_s > 0.0 { (p50_s * 1000.0) as u64 } else { 100 };
        RouteError::Overloaded { retry_after_ms: hint.clamp(50, 5000) }
    }

    /// Route one request; blocks until its worker finishes it.
    pub fn submit(&self, request: Request) -> std::result::Result<RequestOutput, RouteError> {
        self.submit_async(request)?.recv().map_err(|_| RouteError::WorkerClosed)
    }

    /// Route one request; returns a handle for the eventual output. The
    /// request enters its worker's scheduler queue immediately and joins the
    /// running batch at that worker's next decode step — callers pipeline
    /// many requests and collect later. Dropping the handle without
    /// receiving cancels the request (see [`ReplyHandle`]).
    pub fn submit_async(
        &self,
        mut request: Request,
    ) -> std::result::Result<ReplyHandle, RouteError> {
        let cancel = match &request.cancel {
            Some(c) => c.clone(),
            None => {
                let c = Arc::new(CancelToken::new());
                request.cancel = Some(c.clone());
                c
            }
        };
        let i = self.pick()?;
        let w = &self.workers[i];
        w.inflight.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        if w.queue.push(Job::Run { request, reply }).is_err() {
            w.inflight.fetch_sub(1, Ordering::Relaxed);
            return Err(RouteError::WorkerClosed);
        }
        Ok(ReplyHandle::new(rx, cancel))
    }

    /// Route one request and subscribe to its lifecycle: the returned
    /// handle carries the per-request event stream (Started, one Token per
    /// decoded token, Suspended/Resumed, and a terminal Done/Cancelled/
    /// Error with the final output) plus `cancel()`. Events are forwarded
    /// out of the worker as its engine decodes — a streaming consumer
    /// never waits for completion, and events carry the id the caller
    /// submitted with (worker-local ticket rewriting is invisible). A
    /// worker death mid-request resolves the stream with a synthesized
    /// `WorkerError` terminal — subscribers never hang.
    pub fn submit_stream(
        &self,
        mut request: Request,
    ) -> std::result::Result<RequestHandle, RouteError> {
        let handle = RequestHandle::attach(&mut request);
        let i = self.pick()?;
        let w = &self.workers[i];
        w.inflight.fetch_add(1, Ordering::Relaxed);
        // The worker's reply path still runs for inflight bookkeeping; the
        // subscriber consumes the event stream instead, so the receiver is
        // dropped here and the eventual reply send is a silent no-op.
        let (reply, _unused) = mpsc::channel();
        if w.queue.push(Job::Run { request, reply }).is_err() {
            w.inflight.fetch_sub(1, Ordering::Relaxed);
            return Err(RouteError::WorkerClosed);
        }
        Ok(handle)
    }

    /// Chaos hook: make worker `i`'s thread panic while holding its metrics
    /// lock — the closest std-thread analog of a hard crash (dead thread +
    /// poisoned mutex). The supervisor notices via the liveness guard and
    /// runs the full death protocol (fail in-flight, re-route, respawn).
    /// Returns false for an out-of-range index or a closed queue.
    pub fn kill_worker(&self, i: usize) -> bool {
        self.workers.get(i).is_some_and(|w| w.queue.push(Job::Poison).is_ok())
    }

    /// Health of worker `i` as a string (`"healthy"` / `"draining"` /
    /// `"dead"`), or `None` when out of range.
    pub fn worker_state(&self, i: usize) -> Option<&'static str> {
        self.workers.get(i).map(|w| w.health().name())
    }

    /// Total respawn attempts across all worker slots.
    pub fn worker_restarts(&self) -> u64 {
        self.workers.iter().map(|w| w.restarts.load(Ordering::Relaxed)).sum()
    }

    /// Requests rejected by admission control since spawn.
    pub fn requests_shed(&self) -> u64 {
        self.requests_shed.load(Ordering::Relaxed)
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn inflight(&self) -> usize {
        self.workers.iter().map(|w| w.inflight.load(Ordering::Relaxed)).sum()
    }

    /// Per-worker scheduler-metrics snapshots (refreshed after each decode
    /// step), for observability across the thread boundary: queue depth,
    /// occupancy, preemptions, swap-outs/ins.
    pub fn sched_metrics(&self) -> Vec<SchedulerMetrics> {
        self.snapshots().into_iter().map(|s| s.sched).collect()
    }

    /// Per-worker full snapshots: scheduler counters plus queue/TTFT/ITL
    /// latency summaries and supervision state. A worker whose metrics
    /// mutex is poisoned (it died mid-publish, or a poison job killed it
    /// while holding the lock) is reported with default counters and
    /// `healthy: false` / `state: "dead"` rather than silently defaulted.
    pub fn snapshots(&self) -> Vec<WorkerSnapshot> {
        let shed_total = self.requests_shed();
        self.workers
            .iter()
            .map(|w| {
                let (mut snap, poisoned) = match w.metrics.lock() {
                    Ok(m) => ((*m).clone(), false),
                    Err(_) => (WorkerSnapshot::default(), true),
                };
                let health = w.health();
                snap.healthy = health == Health::Healthy && !poisoned;
                snap.state =
                    if poisoned { Health::Dead.name().into() } else { health.name().into() };
                snap.restarts = w.restarts.load(Ordering::Relaxed);
                // Router-level counters mirrored into the scheduler snapshot
                // so one metrics object tells the whole fault story:
                // restarts are per-worker, the shed total is router-global.
                snap.sched.worker_restarts = snap.restarts;
                snap.sched.requests_shed = shed_total;
                snap
            })
            .collect()
    }

    /// JSON metrics export: one object per worker (scheduler counters,
    /// queue-latency / time-to-first-token / inter-token-latency summaries,
    /// phase timing, squeeze table, throughput, health state, restarts) plus
    /// router-level gauges and fault totals. Served over the wire protocol
    /// via a `{"metrics": true}` control line.
    pub fn metrics_json(&self) -> Json {
        Json::obj(vec![
            ("workers", Json::arr(self.snapshots().iter().map(|s| s.to_json()))),
            ("inflight", Json::num(self.inflight() as f64)),
            ("n_workers", Json::num(self.n_workers() as f64)),
            ("requests_shed", Json::num(self.requests_shed() as f64)),
            ("worker_restarts", Json::num(self.worker_restarts() as f64)),
        ])
    }

    /// Span history for one request id, served via `{"trace": <id>}`. Every
    /// worker's recorder is scanned — the id the caller submitted with
    /// resolves through the per-worker alias table (ids are rewritten to
    /// worker-local tickets in flight), so both public ids and raw tickets
    /// answer. Returns `{"id", "found": false, "spans": []}` when no worker
    /// retains spans for the id (never recorded, or rotated out of the ring).
    pub fn trace_json(&self, id: u64) -> Json {
        for w in &self.workers {
            let j = w.trace.trace_json(id);
            if j.get("found").and_then(|v| v.as_bool()) == Some(true) {
                return j;
            }
        }
        Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("found", Json::Bool(false)),
            ("spans", Json::Arr(Vec::new())),
        ])
    }

    /// The most recent crash flight-recorder dump from worker `i`, if that
    /// slot ever died (or its engine contained a step fault). `None` for an
    /// out-of-range index or a worker with no recorded fault.
    pub fn last_flight_dump(&self, i: usize) -> Option<Json> {
        self.workers.get(i).and_then(|w| w.trace.last_dump())
    }

    /// Prometheus text-format exposition (version 0.0.4): every scheduler
    /// counter per worker, the latency and step-phase histogram summaries,
    /// per-layer eviction/budget series, throughput rates, and router-level
    /// totals. Served via a `{"metrics_prom": true}` control line.
    pub fn metrics_prom(&self) -> String {
        let mut pw = PromWriter::new();
        for (i, s) in self.snapshots().iter().enumerate() {
            let wid = i.to_string();
            let labels: &[(&str, &str)] = &[("worker", &wid)];
            pw.json_fields("sa_sched", "gauge", labels, &s.sched.to_json());
            pw.write("sa_worker_up", "gauge", labels, if s.healthy { 1.0 } else { 0.0 });
            pw.write("sa_worker_restarted", "counter", labels, s.restarts as f64);
            pw.summary("sa_queue_latency_s", labels, &s.queue_latency);
            pw.summary("sa_ttft_s", labels, &s.ttft);
            pw.summary("sa_itl_s", labels, &s.itl);
            // Step-phase timing: one series per phase, phase as a label.
            if let Json::Obj(phases) = &s.phases {
                for (name, summary) in phases {
                    let labels: &[(&str, &str)] = &[("worker", &wid), ("phase", name)];
                    pw.json_fields("sa_step_phase_s", "gauge", labels, summary);
                }
            }
            // Per-layer squeeze series: cumulative eviction work, plus the
            // live budget heatmap row (budgets summed over active
            // sequences) — the serving-side view of the paper's Figure 1.
            if let Some(layers) = s.squeeze.get("layers").and_then(|v| v.as_arr()) {
                for row in layers {
                    let Some(layer) = row.get("layer").and_then(|v| v.as_usize()) else {
                        continue;
                    };
                    let lid = layer.to_string();
                    let labels: &[(&str, &str)] = &[("worker", &wid), ("layer", &lid)];
                    let rows = row.get("evicted_rows").and_then(|v| v.as_f64()).unwrap_or(0.0);
                    let bytes = row.get("evicted_bytes").and_then(|v| v.as_f64());
                    pw.write("sa_layer_evicted_rows", "counter", labels, rows);
                    pw.write("sa_layer_evicted_bytes", "counter", labels, bytes.unwrap_or(0.0));
                }
            }
            if let Some(seqs) = s.squeeze.get("sequences").and_then(|v| v.as_arr()) {
                pw.write("sa_active_sequences", "gauge", labels, seqs.len() as f64);
                let mut budgets: Vec<f64> = Vec::new();
                for sq in seqs {
                    let Some(bs) = sq.get("budgets").and_then(|v| v.as_arr()) else { continue };
                    if budgets.len() < bs.len() {
                        budgets.resize(bs.len(), 0.0);
                    }
                    for (l, b) in bs.iter().enumerate() {
                        if let Some(x) = b.as_f64() {
                            budgets[l] += x;
                        }
                    }
                }
                for (l, b) in budgets.iter().enumerate() {
                    let lid = l.to_string();
                    let labels: &[(&str, &str)] = &[("worker", &wid), ("layer", &lid)];
                    pw.write("sa_layer_budget_rows", "gauge", labels, *b);
                }
            }
            pw.json_fields("sa_throughput", "gauge", labels, &s.throughput);
            if let Some(wd) = s.throughput.get("window") {
                pw.json_fields("sa_throughput_window", "gauge", labels, wd);
            }
        }
        pw.write("sa_inflight", "gauge", &[], self.inflight() as f64);
        pw.write("sa_workers", "gauge", &[], self.n_workers() as f64);
        pw.write("sa_requests_shed", "counter", &[], self.requests_shed() as f64);
        pw.write("sa_worker_restarts", "counter", &[], self.worker_restarts() as f64);
        pw.finish()
    }
}

impl Drop for Router {
    /// Orderly shutdown: stop the supervisor first (so nothing respawns
    /// under us), then close every inbox and join the worker threads. A
    /// worker finishes its in-flight engine work — answering every reply —
    /// before it observes the closed queue and exits.
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
        for w in &self.workers {
            w.queue.close();
        }
        for w in &self.workers {
            if let Some(h) = w.thread_take() {
                let _ = h.join();
            }
        }
    }
}

/// Worker loop: continuous batching with liveness. Jobs are pulled into the
/// engine's scheduler queue whenever the loop is between decode steps —
/// bounded-blocking while idle (so the heartbeat keeps publishing) and
/// non-blocking while the engine has work (so new arrivals join the running
/// batch). Returns when the inbox is closed and drained (router shutdown);
/// a panic anywhere in here trips the `LivenessGuard` and hands recovery to
/// the supervisor.
pub(crate) fn worker_loop(mut engine: Engine, w: Arc<WorkerShared>, start: Instant) {
    loop {
        w.beat(start);
        // Ingest: bounded block only when idle; otherwise take what's queued.
        let was_idle = !engine.has_work();
        if was_idle && w.pending_is_empty() {
            match w.queue.pop_timeout(HEARTBEAT) {
                Pop::Job(job) => ingest(&mut engine, job, &w),
                Pop::Empty => continue, // idle heartbeat tick
                Pop::Closed => return,  // shutdown
            }
        }
        loop {
            match w.queue.try_pop() {
                Pop::Job(job) => ingest(&mut engine, job, &w),
                Pop::Empty | Pop::Closed => break,
            }
        }

        // Batch forming: when work just arrived at an idle engine and the
        // batch is still smaller than the slot count, wait up to
        // `batch_wait_ms` for more arrivals so they decode together from
        // the first step instead of joining mid-flight.
        let wait_ms = engine.config().batch_wait_ms;
        if was_idle && wait_ms > 0 {
            let deadline = Instant::now() + Duration::from_millis(wait_ms);
            while engine.queued_len() + engine.running_len() + engine.suspended_len()
                < engine.slot_count()
            {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match w.queue.pop_timeout(deadline - now) {
                    Pop::Job(job) => ingest(&mut engine, job, &w),
                    Pop::Empty | Pop::Closed => break, // step what we have
                }
            }
        }

        // One decode step; completed requests are answered immediately.
        // (step() resolves decode faults internally — retry or WorkerError
        // retire — so the Err arm is defensive, for fatal error sources.)
        let outputs = match engine.step() {
            Ok(outs) => outs,
            Err(e) => {
                eprintln!("worker step failed: {e:#}");
                engine.drain()
            }
        };
        // Snapshot counters + latency summaries for the router. Summary
        // re-sorts a histogram only when it gained samples since the last
        // call, and samples are capped engine-side, so this stays cheap
        // relative to a decode step. Health/restart fields are stamped by
        // `Router::snapshots` at read time.
        {
            let sched = engine.sched_metrics().clone();
            let queue_latency = engine.queue_latency().summary();
            let ttft = engine.ttft_latency().summary();
            let itl = engine.itl_latency().summary();
            // Telemetry payloads ride along unless tracing is off, keeping
            // `--trace-level off` snapshots as lean as they were before
            // telemetry existed (phase summaries are empty below `full`).
            let (phases, squeeze, throughput) = if engine.recorder().level().spans() {
                (engine.phase_json(), engine.squeeze_table_json(), engine.throughput_json())
            } else {
                (Json::Null, Json::Null, Json::Null)
            };
            if let Ok(mut m) = w.metrics.lock() {
                *m = WorkerSnapshot {
                    sched,
                    queue_latency,
                    ttft,
                    itl,
                    phases,
                    squeeze,
                    throughput,
                    ..Default::default()
                };
            }
        }
        for mut out in outputs {
            if let Some(p) = w.pending_remove(out.id) {
                out.id = p.original_id;
                let _ = p.reply.send(out);
                w.inflight.fetch_sub(1, Ordering::Relaxed);
            }
        }

        // Defensive: an idle engine with pending entries means outputs were
        // lost (engine invariant violated). Answer the stragglers with
        // WorkerError terminals so the callers error out instead of hanging,
        // and avoid a busy spin here.
        if !engine.has_work() && !w.pending_is_empty() {
            let lost = w.pending_drain();
            eprintln!("worker: {} request(s) vanished without output", lost.len());
            for p in lost {
                let out = supervisor::worker_error_output(p.original_id);
                super::lifecycle::emit_terminal(&p.events, &out);
                let _ = p.reply.send(out);
                w.inflight.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }
}

fn ingest(engine: &mut Engine, job: Job, w: &WorkerShared) {
    match job {
        Job::Poison => {
            // Chaos hook (`Router::kill_worker`): die the way a real crash
            // does — mid-critical-section. The metrics mutex stays poisoned
            // until the supervisor respawns this worker, which is exactly
            // the window `Router::snapshots` must survive.
            let _guard = w.metrics.lock();
            panic!("injected worker death (poison job)");
        }
        Job::Run { mut request, reply } => {
            let original_id = request.id;
            // Keep a sink clone outside the engine: if the worker dies with
            // this request in flight, the supervisor still has a path to the
            // subscriber for the synthesized WorkerError terminal.
            let events = request.events.clone();
            let id = w.ticket.fetch_add(1, Ordering::Relaxed);
            request.id = id;
            // `{"trace": <caller id>}` must resolve even though the engine
            // records spans under the worker-local ticket.
            w.trace.note_alias(id, original_id);
            match engine.submit(request) {
                Ok(()) => {
                    w.pending_insert(id, PendingJob { reply, original_id, events });
                }
                Err(mut out) => {
                    // Queue backpressure: answer the rejection immediately
                    // (the engine already emitted the Error terminal event).
                    out.id = original_id;
                    let _ = reply.send(out);
                    w.inflight.fetch_sub(1, Ordering::Relaxed);
                }
            }
        }
    }
}
