//! Worker supervision: health tracking, liveness, bounded respawn, and the
//! shared primitives the router and its worker threads communicate through.
//!
//! Failure domains, smallest to largest:
//!
//! ```text
//!   backend step error      contained by the ENGINE (engine.rs): affected
//!        |                  sequences re-queue (bounded per-request retry
//!        v                  budget) or retire with FinishReason::WorkerError
//!   worker THREAD death     contained by the SUPERVISOR (this module): the
//!        |                  liveness guard marks the worker Dead; in-flight
//!        v                  requests get synthesized WorkerError terminals,
//!                           queued-but-unstarted jobs are re-routed, and the
//!                           worker is respawned (bounded, with backoff)
//!   router overload         contained at ADMISSION (router.rs): submits are
//!                           shed with RouteError::Overloaded + a Retry-After
//!                           hint before they consume worker resources
//! ```
//!
//! The load-bearing design choice: a worker's job queue is NOT an
//! `mpsc::channel` into the worker thread. A channel's receiver dies with
//! the thread, losing every queued job. Instead each worker owns a
//! [`WorkerQueue`] (mutex + condvar deque) that survives its consumer: when
//! the thread dies, the supervisor drains the queue intact and re-routes the
//! jobs — only requests *inside* the dead engine are lost, and those are
//! answered with synthesized [`FinishReason::WorkerError`] terminals so no
//! caller blocks forever (std threads cannot be killed or reaped mid-call;
//! death is observed via the [`LivenessGuard`] drop during unwind).
//!
//! Supervision loop (one thread per router, ~10ms tick):
//!
//! ```text
//!   Healthy --stale heartbeat--> Draining --fresh heartbeat--> Healthy
//!      |                            |
//!      +---- liveness guard drop ---+--> Dead --respawn ok--> Healthy
//!                                         |  (restarts < max_worker_restarts,
//!                                         |   backoff 10ms * 2^attempt)
//!                                         +--budget spent--> stays Dead
//!                                            (queue keeps being drained so
//!                                             late-routed jobs still fail
//!                                             fast instead of stranding)
//! ```

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::ServeConfig;
use crate::metrics::{FlightRecorder, TraceLevel};
use crate::squeeze::BudgetPlan;

use super::engine::Engine;
use super::lifecycle::{emit_terminal, CancelToken, EventSink};
use super::request::{FinishReason, Request, RequestOutput, RequestTiming};
use super::router::{worker_loop, WorkerSnapshot};

/// Supervisor poll cadence.
const TICK: Duration = Duration::from_millis(10);
/// A worker that has not heartbeat for this long is considered wedged and
/// demoted to `Draining` (de-prioritized for new work, still serving). The
/// bound is deliberately generous: a legitimate decode step under an
/// injected latency spike must not trip it.
const STALE_MS: u64 = 1_000;

/// Routing-layer errors surfaced to `Router::submit*` callers. Implements
/// `std::error::Error`, so `?` into `anyhow::Result` works at every existing
/// call site; the server matches on it directly to render wire responses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteError {
    /// Admission shed the request before it reached a worker (queue depth or
    /// projected queue latency over the configured bound). `retry_after_ms`
    /// is the server's backoff hint, derived from the picked worker's
    /// observed queue wait.
    Overloaded { retry_after_ms: u64 },
    /// Every worker is dead (restart budgets exhausted) — nothing can accept
    /// work.
    NoHealthyWorker,
    /// The worker's queue closed under the submit (router shutdown), or the
    /// reply channel died without an output.
    WorkerClosed,
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::Overloaded { retry_after_ms } => {
                write!(f, "overloaded: retry after {retry_after_ms}ms")
            }
            RouteError::NoHealthyWorker => write!(f, "no healthy worker"),
            RouteError::WorkerClosed => write!(f, "worker closed"),
        }
    }
}

impl std::error::Error for RouteError {}

/// Worker health as seen by the router and supervisor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    /// Heartbeating and accepting work.
    Healthy,
    /// Heartbeat is stale (possibly wedged in a long step): de-prioritized
    /// by `pick()`, promoted back on the next fresh beat.
    Draining,
    /// The thread is gone (liveness guard dropped during unwind). The
    /// supervisor owns recovery.
    Dead,
}

impl Health {
    fn from_u8(v: u8) -> Health {
        match v {
            0 => Health::Healthy,
            1 => Health::Draining,
            _ => Health::Dead,
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            Health::Healthy => 0,
            Health::Draining => 1,
            Health::Dead => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Draining => "draining",
            Health::Dead => "dead",
        }
    }
}

/// One unit of work delivered to a worker thread.
pub(crate) enum Job {
    /// A routed request plus the channel its output is answered on.
    Run { request: Request, reply: mpsc::Sender<RequestOutput> },
    /// Chaos hook (`Router::kill_worker`): the worker panics while holding
    /// its metrics lock — the closest std-thread analog of a hard crash
    /// (dead thread + poisoned mutex), exercising the full death protocol.
    Poison,
}

/// In-flight bookkeeping for one job that entered a worker's engine: where
/// to answer, the caller's original id (ids are rewritten to worker-local
/// tickets in flight), and a clone of the lifecycle sink so the supervisor
/// can synthesize the terminal event if the engine dies with the request
/// inside it.
pub(crate) struct PendingJob {
    pub reply: mpsc::Sender<RequestOutput>,
    pub original_id: u64,
    pub events: Option<EventSink>,
}

/// Result of a queue pop.
pub(crate) enum Pop {
    Job(Job),
    /// Nothing available within the wait budget.
    Empty,
    /// Queue closed and fully drained — the worker should exit.
    Closed,
}

/// A worker's inbox: a mutex+condvar deque that outlives the worker thread
/// (unlike an mpsc receiver), so queued-but-unstarted jobs survive a crash
/// and can be re-routed by the supervisor.
pub(crate) struct WorkerQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

struct QueueInner {
    jobs: VecDeque<Job>,
    closed: bool,
}

impl WorkerQueue {
    fn new() -> Self {
        Self {
            inner: Mutex::new(QueueInner { jobs: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, QueueInner> {
        // The queue lock is never held across a panic site, but recover
        // defensively: a poisoned inbox must not take the router down.
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Enqueue; `Err` returns the job when the queue is closed (shutdown).
    pub fn push(&self, job: Job) -> std::result::Result<(), Job> {
        let mut g = self.lock();
        if g.closed {
            return Err(job);
        }
        g.jobs.push_back(job);
        drop(g);
        self.cv.notify_one();
        Ok(())
    }

    /// Block up to `wait` for a job. `Closed` only after the queue is both
    /// closed and empty, so shutdown never drops accepted work.
    pub fn pop_timeout(&self, wait: Duration) -> Pop {
        let deadline = Instant::now() + wait;
        let mut g = self.lock();
        loop {
            if let Some(job) = g.jobs.pop_front() {
                return Pop::Job(job);
            }
            if g.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::Empty;
            }
            g = self
                .cv
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(|p| p.into_inner())
                .0;
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Pop {
        let mut g = self.lock();
        match g.jobs.pop_front() {
            Some(job) => Pop::Job(job),
            None if g.closed => Pop::Closed,
            None => Pop::Empty,
        }
    }

    /// Close the queue and wake every waiter (the worker exits once drained).
    pub fn close(&self) {
        self.lock().closed = true;
        self.cv.notify_all();
    }

    /// Take every queued job (supervisor death protocol).
    pub fn drain(&self) -> Vec<Job> {
        self.lock().jobs.drain(..).collect()
    }
}

/// State shared between the router, one worker thread, and the supervisor.
/// Everything a worker owns that must survive its death lives here.
pub(crate) struct WorkerShared {
    pub queue: WorkerQueue,
    /// Jobs inside the engine, keyed by worker-local ticket.
    pending: Mutex<HashMap<u64, PendingJob>>,
    pub inflight: AtomicUsize,
    /// Snapshot of the worker's scheduler metrics + latency summaries,
    /// refreshed after every step (engines live on their worker threads;
    /// this is the only window into their counters). Deliberately poisoned
    /// by `Job::Poison` — `Router::snapshots` must survive that.
    pub metrics: Mutex<WorkerSnapshot>,
    health: AtomicU8,
    /// Milliseconds since router start at the worker's last loop iteration.
    last_beat_ms: AtomicU64,
    /// Respawn attempts consumed (successful or not); bounded by
    /// `ServeConfig::max_worker_restarts`.
    pub restarts: AtomicU64,
    /// Worker-local ticket counter; atomic so it stays monotonic across
    /// respawns (a stale in-flight ticket must never collide with a new one).
    pub ticket: AtomicU64,
    /// Span ring shared with this slot's engine (`Engine::set_recorder`).
    /// Living here rather than inside the engine, it survives the worker
    /// thread's death — the supervisor dumps the dead worker's last spans
    /// from it, and `{"trace": <id>}` queries keep answering across a
    /// respawn. Ticket→public-id aliases recorded at ingest let callers
    /// query by the id they submitted with.
    pub trace: Arc<FlightRecorder>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

impl WorkerShared {
    pub fn new(start: Instant, trace_level: TraceLevel) -> Self {
        let s = Self {
            queue: WorkerQueue::new(),
            pending: Mutex::new(HashMap::new()),
            inflight: AtomicUsize::new(0),
            metrics: Mutex::new(WorkerSnapshot::default()),
            health: AtomicU8::new(Health::Healthy.as_u8()),
            last_beat_ms: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            ticket: AtomicU64::new(0),
            trace: Arc::new(FlightRecorder::with_level(trace_level)),
            thread: Mutex::new(None),
        };
        s.beat(start);
        s
    }

    pub fn health(&self) -> Health {
        Health::from_u8(self.health.load(Ordering::Acquire))
    }

    pub fn set_health(&self, h: Health) {
        self.health.store(h.as_u8(), Ordering::Release);
    }

    /// Record liveness (called once per worker loop iteration).
    pub fn beat(&self, start: Instant) {
        self.last_beat_ms.store(start.elapsed().as_millis() as u64, Ordering::Release);
    }

    fn ms_since_beat(&self, start: Instant) -> u64 {
        let now = start.elapsed().as_millis() as u64;
        now.saturating_sub(self.last_beat_ms.load(Ordering::Acquire))
    }

    fn pending_lock(&self) -> std::sync::MutexGuard<'_, HashMap<u64, PendingJob>> {
        self.pending.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn pending_is_empty(&self) -> bool {
        self.pending_lock().is_empty()
    }

    pub fn pending_insert(&self, ticket: u64, p: PendingJob) {
        self.pending_lock().insert(ticket, p);
    }

    pub fn pending_remove(&self, ticket: u64) -> Option<PendingJob> {
        self.pending_lock().remove(&ticket)
    }

    pub fn pending_drain(&self) -> Vec<PendingJob> {
        self.pending_lock().drain().map(|(_, p)| p).collect()
    }

    pub fn thread_take(&self) -> Option<JoinHandle<()>> {
        self.thread.lock().unwrap_or_else(|p| p.into_inner()).take()
    }

    fn thread_set(&self, h: JoinHandle<()>) {
        *self.thread.lock().unwrap_or_else(|p| p.into_inner()) = Some(h);
    }
}

/// Marks the worker `Dead` if its thread unwinds (panic) or returns without
/// disarming — the supervisor's only death signal, since std threads cannot
/// be reaped from outside.
pub(crate) struct LivenessGuard {
    shared: Arc<WorkerShared>,
    armed: bool,
}

impl LivenessGuard {
    pub fn new(shared: Arc<WorkerShared>) -> Self {
        Self { shared, armed: true }
    }

    /// Normal exit (queue closed): no death protocol.
    pub fn disarm(&mut self) {
        self.armed = false;
    }
}

impl Drop for LivenessGuard {
    fn drop(&mut self) {
        if self.armed {
            self.shared.set_health(Health::Dead);
        }
    }
}

/// The caller's end of `Router::submit_async`: the reply receiver plus the
/// request's cancel token. Dropping the handle cancels the request — an
/// abandoned caller must not keep a worker decoding to `max_new_tokens`
/// (after a received output the cancel is a no-op: the request already
/// retired). This is how the worker "notices" a dropped receiver: std mpsc
/// senders cannot probe for a live peer, so abandonment is signaled from the
/// caller side through the lifecycle `CancelToken` the engine already honors
/// at step boundaries.
pub struct ReplyHandle {
    rx: mpsc::Receiver<RequestOutput>,
    cancel: Arc<CancelToken>,
}

impl ReplyHandle {
    pub(crate) fn new(rx: mpsc::Receiver<RequestOutput>, cancel: Arc<CancelToken>) -> Self {
        Self { rx, cancel }
    }

    /// Block for the output. `Err` means the stream died without an answer
    /// (router shutdown mid-request).
    pub fn recv(&self) -> std::result::Result<RequestOutput, mpsc::RecvError> {
        self.rx.recv()
    }

    pub fn try_recv(&self) -> std::result::Result<RequestOutput, mpsc::TryRecvError> {
        self.rx.try_recv()
    }

    /// Cancel the request explicitly (also implied by drop).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }
}

impl Drop for ReplyHandle {
    fn drop(&mut self) {
        self.cancel.cancel();
    }
}

/// Output synthesized for a request lost inside a dead worker: no engine
/// state survives, so the generation is empty and timings zero.
pub(crate) fn worker_error_output(id: u64) -> RequestOutput {
    RequestOutput {
        id,
        generated: Vec::new(),
        finish: FinishReason::WorkerError,
        timing: RequestTiming::default(),
        plan: BudgetPlan::uniform(1, 0),
        peak_kv_bytes: 0,
        final_kv_tokens: 0,
    }
}

/// Answer a job that can no longer run: reply + synthesized terminal event.
fn fail_job(request: &Request, reply: &mpsc::Sender<RequestOutput>) {
    let out = worker_error_output(request.id);
    emit_terminal(&request.events, &out);
    let _ = reply.send(out);
}

/// Spawn (or respawn) worker `idx`'s thread. The engine is constructed
/// inside the thread (the PJRT client holds `Rc` internals and is not
/// `Send`); construction errors are reported back over a readiness channel
/// before this returns. On success the worker is marked `Healthy` with a
/// fresh heartbeat and any mutex poison from a previous incarnation is
/// cleared.
pub(crate) fn spawn_worker(
    idx: usize,
    shared: Arc<WorkerShared>,
    cfg: ServeConfig,
    start: Instant,
) -> Result<()> {
    if cfg.faults.spawn_fail_worker == Some(idx) {
        return Err(anyhow!("worker {idx} failed to start: injected spawn failure"));
    }
    shared.metrics.clear_poison();
    shared.pending.clear_poison();
    let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
    let shared2 = shared.clone();
    let handle = std::thread::Builder::new()
        .name(format!("sa-worker-{idx}"))
        .spawn(move || match Engine::new(cfg) {
            Ok(mut engine) => {
                // The engine records spans into the slot's shared ring so
                // they outlive this thread (crash flight recorder).
                engine.set_recorder(shared2.trace.clone());
                let _ = ready_tx.send(Ok(()));
                let mut guard = LivenessGuard::new(shared2.clone());
                worker_loop(engine, shared2, start);
                guard.disarm();
            }
            Err(e) => {
                let _ = ready_tx.send(Err(format!("{e:#}")));
            }
        })
        .map_err(|e| anyhow!("worker {idx} thread spawn failed: {e}"))?;
    ready_rx
        .recv()
        .map_err(|_| anyhow!("worker {idx} died during startup"))?
        .map_err(|e| anyhow!("worker {idx} failed to start: {e}"))?;
    shared.beat(start);
    shared.set_health(Health::Healthy);
    shared.thread_set(handle);
    Ok(())
}

/// Everything the supervisor thread needs.
pub(crate) struct SupervisorCtx {
    pub workers: Vec<Arc<WorkerShared>>,
    pub cfg: ServeConfig,
    pub start: Instant,
    pub shutdown: Arc<AtomicBool>,
}

/// Supervisor loop: poll worker health every tick until router shutdown.
pub(crate) fn supervise(ctx: SupervisorCtx) {
    while !ctx.shutdown.load(Ordering::Acquire) {
        std::thread::sleep(TICK);
        for (i, w) in ctx.workers.iter().enumerate() {
            match w.health() {
                Health::Dead => handle_death(i, w, &ctx),
                Health::Healthy if w.ms_since_beat(ctx.start) > STALE_MS => {
                    w.set_health(Health::Draining);
                }
                Health::Draining if w.ms_since_beat(ctx.start) <= STALE_MS => {
                    w.set_health(Health::Healthy);
                }
                _ => {}
            }
        }
    }
}

/// The death protocol. Idempotent: a worker whose restart budget is spent
/// stays `Dead` and re-enters here every tick, which keeps draining any job
/// a racing submit managed to enqueue — late work fails fast with a
/// `WorkerError` terminal instead of stranding in a queue nobody reads.
fn handle_death(idx: usize, w: &Arc<WorkerShared>, ctx: &SupervisorCtx) {
    // Reap the dead thread so the slot can be respawned.
    let reaped = if let Some(h) = w.thread_take() {
        let _ = h.join(); // Err carries the panic payload; already reported
        true
    } else {
        false
    };

    // Crash flight recorder: on the first pass over a fresh corpse (this
    // function re-enters every tick while the slot stays Dead), dump the
    // worker's last spans as structured JSON. The dump is also retained on
    // the recorder (`last_flight_dump` wire query) for post-mortems that
    // outlive stderr.
    if reaped && w.trace.level().spans() {
        let dump = w.trace.dump("worker_death");
        eprintln!("worker {idx}: flight recorder: {dump}");
    }

    // 1. Fail in-flight: requests inside the engine died with it. Each gets
    //    a synthesized WorkerError terminal (event + reply), so stream
    //    subscribers and blocked submit() callers both resolve.
    let lost = w.pending_drain();
    if !lost.is_empty() {
        eprintln!("worker {idx}: died with {} request(s) in flight", lost.len());
    }
    for p in lost {
        let out = worker_error_output(p.original_id);
        emit_terminal(&p.events, &out);
        let _ = p.reply.send(out);
        w.inflight.fetch_sub(1, Ordering::Relaxed);
    }

    // 2. Queued-but-unstarted jobs survive in the WorkerQueue; pull them out
    //    for re-routing after the respawn decision.
    let stranded = w.queue.drain();
    for job in &stranded {
        if matches!(job, Job::Run { .. }) {
            w.inflight.fetch_sub(1, Ordering::Relaxed);
        }
    }

    // 3. Bounded respawn with exponential backoff.
    let attempt = w.restarts.load(Ordering::Relaxed);
    let mut respawned = false;
    if attempt < ctx.cfg.max_worker_restarts && !ctx.shutdown.load(Ordering::Acquire) {
        w.restarts.fetch_add(1, Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis((10u64 << attempt.min(6)).min(500)));
        match spawn_worker(idx, w.clone(), ctx.cfg.clone(), ctx.start) {
            Ok(()) => respawned = true,
            Err(e) => eprintln!("worker {idx}: respawn failed: {e:#}"),
        }
    }

    // 4. Re-route the stranded jobs: prefer the respawned worker (keeps
    //    least-loaded accounting honest), else any healthy peer, else fail
    //    them so no caller hangs.
    for job in stranded {
        let Job::Run { request, reply } = job else { continue };
        let target = if respawned {
            Some(w)
        } else {
            ctx.workers.iter().find(|p| p.health() == Health::Healthy)
        };
        match target {
            Some(t) => {
                t.inflight.fetch_add(1, Ordering::Relaxed);
                if let Err(Job::Run { request, reply }) =
                    t.queue.push(Job::Run { request, reply })
                {
                    t.inflight.fetch_sub(1, Ordering::Relaxed);
                    fail_job(&request, &reply);
                }
            }
            None => fail_job(&request, &reply),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_survives_close_with_backlog() {
        let q = WorkerQueue::new();
        let (tx, _rx) = mpsc::channel();
        q.push(Job::Run { request: Request::new(1, vec![1], 4), reply: tx.clone() }).unwrap();
        q.close();
        // Closed queue rejects new work but still yields the backlog.
        assert!(q.push(Job::Poison).is_err());
        assert!(matches!(q.try_pop(), Pop::Job(_)));
        assert!(matches!(q.try_pop(), Pop::Closed));
        assert!(matches!(q.pop_timeout(Duration::from_millis(1)), Pop::Closed));
    }

    #[test]
    fn pop_timeout_returns_empty_without_work() {
        let q = WorkerQueue::new();
        assert!(matches!(q.pop_timeout(Duration::from_millis(1)), Pop::Empty));
        assert!(matches!(q.try_pop(), Pop::Empty));
    }

    #[test]
    fn liveness_guard_marks_dead_only_when_armed() {
        let start = Instant::now();
        let w = Arc::new(WorkerShared::new(start, TraceLevel::Spans));
        {
            let mut g = LivenessGuard::new(w.clone());
            g.disarm();
        }
        assert_eq!(w.health(), Health::Healthy);
        {
            let _g = LivenessGuard::new(w.clone());
        }
        assert_eq!(w.health(), Health::Dead);
    }

    #[test]
    fn route_error_displays_and_errors() {
        let e = RouteError::Overloaded { retry_after_ms: 120 };
        assert!(e.to_string().contains("120"));
        let boxed: Box<dyn std::error::Error> = Box::new(e);
        assert!(boxed.to_string().contains("overloaded"));
        assert_eq!(RouteError::NoHealthyWorker.to_string(), "no healthy worker");
    }

    #[test]
    fn reply_handle_drop_fires_cancel() {
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(CancelToken::new());
        let h = ReplyHandle::new(rx, cancel.clone());
        assert!(!cancel.is_cancelled());
        drop(h);
        assert!(cancel.is_cancelled());
        drop(tx);
    }

    #[test]
    fn worker_error_output_preserves_id() {
        let out = worker_error_output(42);
        assert_eq!(out.id, 42);
        assert_eq!(out.finish, FinishReason::WorkerError);
        assert!(out.generated.is_empty());
    }
}
