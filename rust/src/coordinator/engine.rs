//! The serving engine: continuous batching over the AOT decode tiers, with
//! SqueezeAttention layer-budget allocation and per-layer eviction.
//!
//! Lifecycle of a request (Algorithm 1 mapped onto the runtime):
//!   1. **Prefill** — run the bucketed prefill artifact; collect the
//!      per-layer cosine-similarity probe.
//!   2. **Squeeze** — reduce cosine stats to per-layer means, k-means into
//!      3 groups, reallocate `b_init` (allocator::allocate). With squeeze
//!      disabled this is the uniform baseline plan.
//!   3. **Compress prompt cache** — apply the sequence-wise policy per layer
//!      with that layer's own budget.
//!   4. **Decode loop** — batched steps on the smallest capacity tier that
//!      fits the largest per-layer cache; after each step append the new KV
//!      row, fold the attention-mass signal into H2O scores, and re-compress
//!      any layer over budget.
//!
//! The engine is synchronous; the async server (`server.rs`) drives it from
//! a dedicated thread.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{PolicyKind, ServeConfig};
use crate::kvcache::{make_policy, EvictionPolicy, KvPool, Reservation, SequenceCache};
use crate::metrics::ThroughputMeter;
use crate::model::tokenizer::{self, check_token_map};
use crate::model::sample;
use crate::runtime::{Runtime, Tensor, TensorI32};
use crate::squeeze::{allocate, BudgetPlan, CosineStats};
use crate::util::Rng;

use super::request::{BudgetSpec, FinishReason, Request, RequestOutput, RequestTiming};

/// One sequence occupying a decode slot.
struct Active {
    req: Request,
    cache: SequenceCache,
    plan: BudgetPlan,
    reservation: Reservation,
    generated: Vec<i32>,
    /// Absolute position of the *next* token to decode.
    next_pos: usize,
    last_token: i32,
    effective_max_new: usize,
    /// Set when the pool rejected growth mid-decode (paper's OOM cells).
    oom: bool,
    t_admit: Instant,
    timing: RequestTiming,
    peak_bytes: usize,
}

/// Engine-level aggregate statistics for one `generate_batch` run.
#[derive(Debug, Clone, Default)]
pub struct EngineRunStats {
    pub decode_steps: u64,
    pub generated_tokens: u64,
    pub evictions: u64,
    pub peak_pool_bytes: usize,
    pub wall_s: f64,
    /// Sum over steps of the capacity tier bound (proxy for KV traffic).
    pub kv_slots_touched: u64,
}

pub struct Engine {
    runtime: Runtime,
    cfg: ServeConfig,
    policy: Box<dyn EvictionPolicy>,
    pool: KvPool,
    batch: usize,
    n_layer: usize,
    row_elems: usize,
    max_seq: usize,
    /// Scratch decode buffers per (batch, capacity) tier (reused across
    /// steps; padding is never zeroed — the kernel masks by cache_len).
    scratch: std::collections::HashMap<(usize, usize), (Tensor, Tensor)>,
    /// Optional cross-request cosine accumulation (Fig. 2 heatmaps).
    collect_cosine: Option<CosineStats>,
    /// Sampling RNG (deterministic; greedy sampling never consumes it).
    rng: Rng,
    pub last_run: EngineRunStats,
}

impl Engine {
    pub fn new(cfg: ServeConfig) -> Result<Self> {
        let runtime = Runtime::load(&cfg.artifacts, &cfg.kernel)?;
        check_token_map(&runtime.manifest.tokens)?;
        let n_layer = runtime.manifest.model.n_layer;
        let row_elems = runtime.manifest.model.n_head * runtime.manifest.model.head_dim;
        let max_seq = runtime.manifest.model.max_seq;
        let batch = runtime
            .decode_batches()
            .into_iter()
            .filter(|&b| b <= cfg.max_batch)
            .max()
            .ok_or_else(|| anyhow!("no decode artifact with batch <= {}", cfg.max_batch))?;
        let pool = KvPool::new(cfg.kv_pool_bytes);
        let policy = make_policy(&cfg);
        Ok(Self {
            runtime,
            policy,
            pool,
            batch,
            n_layer,
            row_elems,
            max_seq,
            scratch: Default::default(),
            collect_cosine: None,
            rng: Rng::seed_from_u64(0x5A5A_5A5A),
            last_run: Default::default(),
            cfg,
        })
    }

    /// Swap the serving policy/budget configuration without reloading the
    /// runtime (artifacts + kernel must match the loaded ones). Used for
    /// policy sweeps — PJRT clients are expensive and, on some platforms,
    /// unsafe to re-create within a process.
    pub fn reconfigure(&mut self, cfg: ServeConfig) -> Result<()> {
        if cfg.artifacts != self.cfg.artifacts || cfg.kernel != self.cfg.kernel {
            return Err(anyhow!(
                "reconfigure cannot change artifacts/kernel ({} vs {})",
                cfg.artifacts,
                self.cfg.artifacts
            ));
        }
        self.batch = self
            .runtime
            .decode_batches()
            .into_iter()
            .filter(|&b| b <= cfg.max_batch)
            .max()
            .ok_or_else(|| anyhow!("no decode artifact with batch <= {}", cfg.max_batch))?;
        self.policy = make_policy(&cfg);
        self.pool = KvPool::new(cfg.kv_pool_bytes);
        self.cfg = cfg;
        Ok(())
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// Decode slot count actually bound (largest artifact batch <= max_batch).
    pub fn slot_count(&self) -> usize {
        self.batch
    }

    /// Start accumulating cosine heatmap stats across requests (Fig. 2).
    pub fn enable_cosine_collection(&mut self) {
        self.collect_cosine = Some(CosineStats::new(self.n_layer));
    }

    pub fn cosine_stats(&self) -> Option<&CosineStats> {
        self.collect_cosine.as_ref()
    }

    fn budget_spec(&self) -> BudgetSpec {
        if self.cfg.policy == PolicyKind::Full {
            BudgetSpec::Unlimited
        } else if let Some(f) = self.cfg.budget_frac {
            BudgetSpec::Fraction(f)
        } else {
            BudgetSpec::Tokens(self.cfg.budget)
        }
    }

    /// Serve a closed batch of requests to completion (continuous batching:
    /// new requests are admitted into slots as earlier ones finish).
    pub fn generate_batch(&mut self, requests: Vec<Request>) -> Vec<RequestOutput> {
        let t0 = Instant::now();
        let mut meter = ThroughputMeter::new();
        let mut run = EngineRunStats::default();
        let mut queue: VecDeque<Request> = requests.into();
        let mut slots: Vec<Option<Active>> = (0..self.batch).map(|_| None).collect();
        let mut outputs = Vec::new();

        loop {
            // Admission: fill free slots from the queue.
            for s in 0..self.batch {
                if slots[s].is_none() {
                    if let Some(req) = queue.pop_front() {
                        match self.admit(req, t0) {
                            Ok(active) => slots[s] = Some(active),
                            Err(out) => outputs.push(out),
                        }
                    }
                }
            }
            if slots.iter().all(|s| s.is_none()) {
                break;
            }

            // One batched decode step over all occupied slots.
            if let Err(e) = self.step(&mut slots, &mut run, &mut meter) {
                // Runtime failure: fail all in-flight requests loudly.
                eprintln!("decode step failed: {e:#}");
                for slot in slots.iter_mut() {
                    if let Some(a) = slot.take() {
                        outputs.push(Self::finish(a, FinishReason::Oom, t0));
                    }
                }
                break;
            }

            // Collect finished sequences.
            for slot in slots.iter_mut() {
                let done = match slot {
                    Some(a) => {
                        a.oom
                            || a.last_token == tokenizer::EOS
                            || a.generated.len() >= a.effective_max_new
                    }
                    None => false,
                };
                if done {
                    let a = slot.take().unwrap();
                    let reason = if a.oom {
                        FinishReason::Oom
                    } else if a.last_token == tokenizer::EOS {
                        FinishReason::Eos
                    } else {
                        FinishReason::Length
                    };
                    meter.add_request();
                    outputs.push(Self::finish(a, reason, t0));
                }
            }
        }

        run.wall_s = t0.elapsed().as_secs_f64();
        run.peak_pool_bytes = self.pool.peak();
        run.generated_tokens = meter.tokens();
        self.last_run = run;
        outputs.sort_by_key(|o| o.id);
        outputs
    }

    /// Prefill + squeeze + prompt compression. Returns the slot state, or a
    /// terminal output (reject / OOM).
    fn admit(&mut self, req: Request, t0: Instant) -> std::result::Result<Active, RequestOutput> {
        let t_admit = Instant::now();
        let mut timing = RequestTiming { queue_s: t_admit.duration_since(t0).as_secs_f64(), ..Default::default() };
        let prompt_len = req.prompt.len();

        let largest = self
            .runtime
            .manifest
            .prefill_buckets(self.runtime.kernel())
            .last()
            .copied()
            .unwrap_or(0);
        if prompt_len == 0 || prompt_len > largest {
            return Err(RequestOutput {
                id: req.id,
                generated: vec![],
                finish: FinishReason::Rejected,
                timing,
                plan: BudgetPlan::uniform(self.n_layer, 0),
                peak_kv_bytes: 0,
                final_kv_tokens: 0,
            });
        }

        let tp = Instant::now();
        let pre = match self.runtime.prefill(&req.prompt) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("prefill failed: {e:#}");
                return Err(RequestOutput {
                    id: req.id,
                    generated: vec![],
                    finish: FinishReason::Rejected,
                    timing,
                    plan: BudgetPlan::uniform(self.n_layer, 0),
                    peak_kv_bytes: 0,
                    final_kv_tokens: 0,
                });
            }
        };
        timing.prefill_s = tp.elapsed().as_secs_f64();

        // --- SqueezeAttention: importance -> groups -> budgets -------------
        let ts = Instant::now();
        let b_init = self.budget_spec().resolve(prompt_len, self.max_seq);
        let plan = if self.cfg.squeeze.enabled && self.cfg.policy != PolicyKind::Full {
            let mut stats = CosineStats::new(self.n_layer);
            stats.observe(&pre.cos_sims, prompt_len);
            allocate(&stats.layer_means(), b_init, &self.cfg.squeeze)
        } else {
            BudgetPlan::uniform(self.n_layer, b_init)
        };
        timing.squeeze_s = ts.elapsed().as_secs_f64();
        if let Some(collect) = &mut self.collect_cosine {
            collect.observe(&pre.cos_sims, prompt_len);
        }

        let mut cache = match SequenceCache::from_prefill(&pre.k, &pre.v, prompt_len) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("cache build failed: {e:#}");
                return Err(RequestOutput {
                    id: req.id,
                    generated: vec![],
                    finish: FinishReason::Rejected,
                    timing,
                    plan,
                    peak_kv_bytes: 0,
                    final_kv_tokens: 0,
                });
            }
        };

        // --- compress the prompt cache per layer with its own budget -------
        for layer in 0..self.n_layer {
            let budget = plan.budgets[layer];
            if cache.layer_len(layer) > budget {
                let keep = self.policy.keep(&cache.layers[layer].meta, budget);
                cache.retain(layer, &keep).expect("policy produced valid keep-set");
            }
        }

        let reservation = match Reservation::new(&self.pool, cache.bytes()) {
            Ok(r) => r,
            Err(_) => {
                return Err(RequestOutput {
                    id: req.id,
                    generated: vec![],
                    finish: FinishReason::Oom,
                    timing,
                    plan,
                    peak_kv_bytes: 0,
                    final_kv_tokens: cache.total_tokens(),
                });
            }
        };

        // First decoded token comes from the prefill logits.
        let first = sample(&pre.logits.data, req.sampling, &mut self.rng);
        timing.first_token_s = t_admit.elapsed().as_secs_f64() + timing.queue_s;

        let effective_max_new = req
            .max_new_tokens
            .min(self.max_seq.saturating_sub(prompt_len + 8))
            .max(1);
        let peak = cache.bytes();
        Ok(Active {
            generated: vec![first],
            next_pos: prompt_len,
            last_token: first,
            effective_max_new,
            oom: false,
            t_admit,
            timing,
            peak_bytes: peak,
            req,
            cache,
            plan,
            reservation,
        })
    }

    fn finish(a: Active, reason: FinishReason, _t0: Instant) -> RequestOutput {
        let mut timing = a.timing;
        timing.total_s = a.t_admit.elapsed().as_secs_f64() + timing.queue_s;
        let mut generated = a.generated;
        // Trim a trailing EOS for downstream exact-match scoring? No: keep
        // the raw stream; scorers decide.
        if reason == FinishReason::Oom {
            generated.clear();
        }
        RequestOutput {
            id: a.req.id,
            generated,
            finish: reason,
            timing,
            plan: a.plan,
            peak_kv_bytes: a.peak_bytes,
            final_kv_tokens: a.cache.total_tokens(),
        }
    }

    /// One batched decode step over occupied slots.
    fn step(
        &mut self,
        slots: &mut [Option<Active>],
        run: &mut EngineRunStats,
        meter: &mut ThroughputMeter,
    ) -> Result<()> {
        let b = self.batch;
        // Tier: smallest capacity covering every layer cache + the new token.
        let needed = slots
            .iter()
            .flatten()
            .map(|a| a.cache.max_layer_len())
            .max()
            .unwrap_or(0)
            + 1;
        let tier = self.runtime.decode_tier_for(b, needed)?;
        let (_, m) = tier;
        let (h, d) = (
            self.runtime.manifest.model.n_head,
            self.runtime.manifest.model.head_dim,
        );

        // Take the scratch pair out of the map so the runtime call below can
        // borrow `self` — padding is never zeroed, the kernel masks by len.
        let (mut k_buf, mut v_buf) = self.scratch.remove(&tier).unwrap_or_else(|| {
            (
                Tensor::zeros(&[self.n_layer, b, m, h, d]),
                Tensor::zeros(&[self.n_layer, b, m, h, d]),
            )
        });

        let mut tokens = vec![tokenizer::PAD; b];
        let mut positions = vec![0i32; b];
        let mut lens = vec![0i32; self.n_layer * b];
        for (i, slot) in slots.iter().enumerate() {
            if let Some(a) = slot {
                tokens[i] = a.last_token;
                positions[i] = a.next_pos as i32;
                a.cache.write_into_batch(&mut k_buf, &mut v_buf, &mut lens, i)?;
            }
        }

        let out = self.runtime.decode(
            tier,
            &TensorI32::from_vec(&[b], tokens)?,
            &TensorI32::from_vec(&[b], positions)?,
            &k_buf,
            &v_buf,
            &TensorI32::from_vec(&[self.n_layer, b], lens.clone())?,
        );
        self.scratch.insert(tier, (k_buf, v_buf));
        let out = out?;
        run.decode_steps += 1;
        run.kv_slots_touched += (self.n_layer * b * m) as u64;
        meter.add_decode_step();

        let vocab = self.runtime.manifest.model.vocab;
        let needs_scores = self.policy.needs_scores();
        for (i, slot) in slots.iter_mut().enumerate() {
            let Some(a) = slot else { continue };

            // Append the new KV row to every layer, then fold H2O scores.
            let pos = a.next_pos as u32;
            for layer in 0..self.n_layer {
                let base = (layer * b + i) * self.row_elems;
                let k_row = &out.new_k.data[base..base + self.row_elems];
                let v_row = &out.new_v.data[base..base + self.row_elems];
                a.cache.append(layer, k_row, v_row, pos)?;
                if needs_scores {
                    let sbase = (layer * b + i) * m;
                    let n = a.cache.layer_len(layer).min(m);
                    a.cache.add_scores(layer, &out.scores.data[sbase..sbase + n]);
                }
            }

            // Charge the pool for the appended rows; OOM kills the request.
            let new_bytes = a.cache.bytes();
            if a.reservation.resize(new_bytes).is_err() {
                a.oom = true;
                continue;
            }
            a.peak_bytes = a.peak_bytes.max(new_bytes);

            // Sample the next token from this slot's logits row.
            let row = &out.logits.data[i * vocab..(i + 1) * vocab];
            let tok = sample(row, a.req.sampling, &mut self.rng);
            a.generated.push(tok);
            a.last_token = tok;
            a.next_pos += 1;
            meter.add_tokens(1);
            if a.generated.len() == 1 {
                a.timing.first_token_s = a.t_admit.elapsed().as_secs_f64() + a.timing.queue_s;
            }

            // Per-layer re-compression with each layer's own budget
            // (Algorithm 1, lines 15–19).
            for layer in 0..self.n_layer {
                let budget = a.plan.budgets[layer];
                if a.cache.layer_len(layer) > budget {
                    let keep = self.policy.keep(&a.cache.layers[layer].meta, budget);
                    a.cache.retain(layer, &keep)?;
                    run.evictions += 1;
                }
            }
            let shrunk = a.cache.bytes();
            if shrunk != new_bytes {
                let _ = a.reservation.resize(shrunk);
            }
        }
        Ok(())
    }
}
