//! The serving engine: a step-driven continuous-batching scheduler over the
//! runtime's decode tiers, with SqueezeAttention layer-budget allocation,
//! per-layer eviction, and a two-tier (device + host-spill) KV hierarchy
//! with suspend/resume preemption.
//!
//! Lifecycle of a request (Algorithm 1 mapped onto the runtime):
//!   1. **Prefill** — run the bucketed prefill artifact; collect the
//!      per-layer cosine-similarity probe.
//!   2. **Squeeze** — reduce cosine stats to per-layer means, k-means into
//!      3 groups, reallocate `b_init` (allocator::allocate). With squeeze
//!      disabled this is the uniform baseline plan.
//!   3. **Compress prompt cache** — apply the sequence-wise policy per layer
//!      with that layer's own budget.
//!   4. **Decode loop** — batched steps on the smallest capacity tier that
//!      fits the largest per-layer cache; after each step append the new KV
//!      row, fold the attention-mass signal into H2O scores, and re-compress
//!      any layer over budget.
//!   5. **Speculative bursts** (`spec.enabled`, `--spec-k`) — each decode
//!      step becomes a draft→verify→rollback burst per sequence:
//!
//!      ```text
//!      charge k+1 rows ─► draft k tokens ─► truncate + shrink ─► verify
//!      (page envelope,    (draft model,      (rollback: KV rows,  (target
//!       preempt on OOM)    optimistic         positions, H2O       model,
//!                          appends, no        scores restored      batched
//!                          events)            byte-exactly)        across
//!                                                                  seqs)
//!      ```
//!
//!      The paired draft model (`sim://tiny-draft`) proposes up to k tokens
//!      by greedy argmax, appending their KV rows optimistically inside the
//!      pre-charged k+1-row page envelope; the rows are then rolled back
//!      (`SequenceCache::truncate` + `PageTable::shrink`) and the target
//!      verifies by running its exact per-token decode sequence — batched
//!      across sequences per micro-step — committing the longest prefix
//!      that matches the draft plus one bonus token. A `Token` event fires
//!      per committed token (rollback never emits), and ITL records one
//!      interval per committed token. Output is token-identical to
//!      non-speculative decode under every eviction policy, because
//!      verification *is* the non-speculative code path. (Exact for greedy
//!      sampling — the default; temperature sampling draws from the shared
//!      rng in burst order, which interleaves differently across a
//!      multi-sequence batch.)
//!
//! The engine is driven one decode step at a time (`step`), so requests can
//! join and leave the running batch mid-flight:
//!
//! * `submit` enqueues (with `queue_depth` backpressure);
//! * each `step` admits into free slots — suspended sequences swap back in
//!   first (host→device migration, no prefill), then queued requests under
//!   KV-pool admission control — runs one batched decode, retires finished
//!   sequences immediately, and resolves pool OOM by preempting the
//!   youngest running sequence: with `host_spill_bytes > 0` its squeezed
//!   cache is *suspended* to the host tier (swap-out) and later resumed
//!   token-identically; otherwise it restarts from scratch (see
//!   `coordinator::scheduler`);
//! * `generate_batch` is the closed-batch compatibility wrapper: enqueue
//!   everything, `step` until idle, sort outputs by id.
//!
//! Requests may carry lifecycle hooks (`coordinator::lifecycle`): an event
//! sink the engine publishes into at every transition (admission, each
//! decoded token, suspend/resume, terminal), a cancel token, and a
//! deadline. Every step begins with a `lifecycle_phase` that retires
//! cancelled or deadline-expired requests from the queue, the decode
//! slots, and the suspended set — releasing their device or host pages
//! without finishing decode (a cancel while swapped out frees the host
//! tier with no swap-in).
//!
//! KV bytes are charged through the paged allocator (`kvcache::paging`):
//! every running or suspended sequence holds a `PageTable`, admission
//! estimates and per-step growth are page-granular, and suspend/resume is
//! a page-table edit whose migration traffic is exactly
//! `page_bytes × pages_moved`. `--kv-page-bytes` sets the page size,
//! clamped to at least one token row per layer.
//!
//! The engine is synchronous; the async server (`server.rs`) drives it from
//! a dedicated thread.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{PolicyKind, ServeConfig};
use crate::kvcache::{
    make_policy, EvictionPolicy, KvPool, PageTable, PagedKvPool, SequenceCache, Tier,
};
use crate::metrics::{
    FlightRecorder, Histogram, LayerTable, PhaseAcc, PhaseTimers, SchedulerMetrics, SpanKind,
    StepPhase, ThroughputMeter,
};
use crate::model::tokenizer::{self, check_token_map};
use crate::model::{argmax, sample};
use crate::runtime::{DecodeOut, FaultPlan, Runtime, TensorI32};
use crate::squeeze::{allocate, BudgetPlan, CosineStats};
use crate::util::{Json, Rng};

use super::lifecycle::{self, RequestEvent};
use super::request::{BudgetSpec, FinishReason, Request, RequestOutput, RequestTiming};
use super::residency::{GatherStats, ScratchTier};
use super::scheduler::{Active, Queued, Scheduler, Suspended};

/// Engine-level aggregate statistics for one run (`generate_batch` resets
/// them; in step-driven mode they accumulate until the next reset).
#[derive(Debug, Clone, Default)]
pub struct EngineRunStats {
    pub decode_steps: u64,
    pub generated_tokens: u64,
    pub evictions: u64,
    /// Sequences preempted (suspended or requeued) to resolve KV-pool OOM.
    pub preemptions: u64,
    pub peak_pool_bytes: usize,
    pub wall_s: f64,
    /// Sum over steps of the capacity tier bound (proxy for KV traffic).
    pub kv_slots_touched: u64,
}

/// Why an admission attempt did not produce a running sequence.
enum AdmitError {
    /// The request is finished (rejected, or permanently OOM): forward the
    /// output to the caller.
    Terminal(RequestOutput),
    /// The pool is transiently full: requeue and retry after retirements.
    Retry(Queued),
    /// The device pool is transiently full but the finished prefill is too
    /// valuable to discard: the squeezed cache + plan were parked on the
    /// host tier, so re-admission is a swap-in instead of a second prefill.
    Suspend(Box<Suspended>),
}

pub struct Engine {
    runtime: Runtime,
    /// Paired draft model for speculative decoding (loaded only while
    /// `cfg.spec` is enabled; geometry checked against the target).
    draft: Option<Runtime>,
    cfg: ServeConfig,
    policy: Box<dyn EvictionPolicy>,
    paged: PagedKvPool,
    batch: usize,
    n_layer: usize,
    row_elems: usize,
    max_seq: usize,
    /// Batch-resident scratch per (batch, capacity) decode tier: buffers
    /// persist across steps with per-slot residency tracking, so the
    /// steady-state gather appends only newly grown rows (padding is never
    /// zeroed — the kernel masks by cache_len). Tiers idle for
    /// `SCRATCH_IDLE_STEPS` decode steps are reclaimed.
    scratch: std::collections::HashMap<(usize, usize), ScratchTier>,
    /// Gather-path counters (bytes copied, full refills vs incremental
    /// appends), exported via `SchedulerMetrics`; reset with the run stats.
    gather: GatherStats,
    /// Scratch tiers reclaimed by the idle sweep since the last reset.
    scratch_tiers_evicted: u64,
    /// Decode-step staging tensors (token ids, positions, per-layer lens),
    /// rewritten in place each batched call instead of reallocated.
    stage_tokens: TensorI32,
    stage_positions: TensorI32,
    stage_lens: TensorI32,
    /// Optional cross-request cosine accumulation (Fig. 2 heatmaps).
    collect_cosine: Option<CosineStats>,
    /// Sampling RNG (deterministic; greedy sampling never consumes it).
    rng: Rng,
    sched: Scheduler,
    meter: ThroughputMeter,
    /// Per-request queue latency (submit → decode slot), including time
    /// spent suspended in the host tier.
    queue_hist: Histogram,
    /// Time-to-first-token per request: submit → first token sampled from
    /// the prefill logits at admission (includes queue wait).
    ttft_hist: Histogram,
    /// Inter-token latency: gap between consecutive sampled tokens of a
    /// sequence, including any suspended time in between.
    itl_hist: Histogram,
    /// Shared span ring: every lifecycle transition is recorded here. The
    /// engine creates its own from `cfg.trace_level`; the supervisor swaps
    /// in a worker-shared one (`set_recorder`) so the spans survive the
    /// engine when a worker thread dies.
    recorder: Arc<FlightRecorder>,
    /// Per-phase step timing (`--trace-level full` only): where a decode
    /// millisecond goes — admission / gather / model / verify / evict /
    /// commit.
    phase_timers: PhaseTimers,
    /// Current step's phase durations; flushed into `phase_timers` once per
    /// step so a phase touched per-slot still costs one histogram record.
    phase_acc: PhaseAcc,
    /// Cumulative per-layer evicted rows/bytes (always on: two counter adds
    /// on an eviction event that already rewrites the cache).
    layer_table: LayerTable,
    run: EngineRunStats,
    pub last_run: EngineRunStats,
}

impl Engine {
    /// Largest decode-artifact batch size <= `max_batch` — the single source
    /// of truth for slot sizing (`new`, `reconfigure`, and spec-mode slot
    /// accounting all go through here).
    fn select_batch(runtime: &Runtime, max_batch: usize) -> Result<usize> {
        runtime
            .decode_batches()
            .into_iter()
            .filter(|&b| b <= max_batch)
            .max()
            .ok_or_else(|| anyhow!("no decode artifact with batch <= {max_batch}"))
    }

    /// Artifact spec of the draft model paired with `artifacts`. Only the
    /// sim backend ships a draft variant today (`sim://tiny` →
    /// `sim://tiny-draft`, sharing the target's deterministic KV hashing).
    fn draft_artifacts(artifacts: &str) -> Result<String> {
        match artifacts.strip_prefix("sim://") {
            Some("" | "tiny") => Ok("sim://tiny-draft".to_string()),
            _ => Err(anyhow!(
                "speculative decoding has no draft model for '{artifacts}' (sim://tiny only)"
            )),
        }
    }

    /// Load the draft runtime when spec mode is on, verifying its geometry
    /// matches the target's (drafted KV rows land in the target's cache, so
    /// every shape must agree).
    fn load_draft(runtime: &Runtime, cfg: &ServeConfig) -> Result<Option<Runtime>> {
        if !cfg.spec.enabled || cfg.spec.draft_k == 0 {
            return Ok(None);
        }
        let draft = Runtime::load(&Self::draft_artifacts(&cfg.artifacts)?, &cfg.kernel)?;
        let (d, t) = (&draft.manifest.model, &runtime.manifest.model);
        if d.n_layer != t.n_layer
            || d.n_head != t.n_head
            || d.head_dim != t.head_dim
            || d.vocab != t.vocab
            || d.max_seq != t.max_seq
        {
            return Err(anyhow!(
                "draft model '{}' geometry does not match target '{}'",
                d.name,
                t.name
            ));
        }
        Ok(Some(draft))
    }

    pub fn new(cfg: ServeConfig) -> Result<Self> {
        let runtime = Runtime::load(&cfg.artifacts, &cfg.kernel)?;
        // Chaos testing: arm deterministic fault injection on the *target*
        // runtime only — draft-model faults would be indistinguishable from
        // target faults in the metrics, and the draft path already rolls
        // back cleanly on any error.
        runtime.set_fault_plan(cfg.faults.enabled().then(|| FaultPlan::from_config(&cfg.faults)));
        check_token_map(&runtime.manifest.tokens)?;
        let n_layer = runtime.manifest.model.n_layer;
        let row_elems = runtime.manifest.model.n_head * runtime.manifest.model.head_dim;
        let max_seq = runtime.manifest.model.max_seq;
        let batch = Self::select_batch(&runtime, cfg.max_batch)?;
        let draft = Self::load_draft(&runtime, &cfg)?;
        // Pages must hold at least one token row, or a page could never
        // cover the slot it is charged for.
        let page_bytes = cfg.kv_page_bytes.max(SequenceCache::token_bytes(row_elems));
        let paged = PagedKvPool::new(
            KvPool::tiered(cfg.kv_pool_bytes, cfg.host_spill_bytes),
            page_bytes,
        );
        let policy = make_policy(&cfg);
        let sched = Scheduler::new(batch, cfg.queue_depth);
        Ok(Self {
            runtime,
            draft,
            policy,
            paged,
            batch,
            n_layer,
            row_elems,
            max_seq,
            scratch: Default::default(),
            gather: GatherStats::default(),
            scratch_tiers_evicted: 0,
            stage_tokens: TensorI32::zeros(&[batch]),
            stage_positions: TensorI32::zeros(&[batch]),
            stage_lens: TensorI32::zeros(&[n_layer, batch]),
            collect_cosine: None,
            rng: Rng::seed_from_u64(0x5A5A_5A5A),
            sched,
            meter: ThroughputMeter::new(),
            queue_hist: Histogram::new(),
            ttft_hist: Histogram::new(),
            itl_hist: Histogram::new(),
            recorder: Arc::new(FlightRecorder::with_level(cfg.trace_level)),
            phase_timers: PhaseTimers::new(),
            phase_acc: PhaseAcc::default(),
            layer_table: LayerTable::new(n_layer),
            run: Default::default(),
            last_run: Default::default(),
            cfg,
        })
    }

    /// Swap the serving policy/budget configuration without reloading the
    /// runtime (artifacts + kernel must match the loaded ones). Used for
    /// policy sweeps — PJRT clients are expensive and, on some platforms,
    /// unsafe to re-create within a process. Requires an idle scheduler.
    pub fn reconfigure(&mut self, cfg: ServeConfig) -> Result<()> {
        if cfg.artifacts != self.cfg.artifacts || cfg.kernel != self.cfg.kernel {
            return Err(anyhow!(
                "reconfigure cannot change artifacts/kernel ({} vs {})",
                cfg.artifacts,
                self.cfg.artifacts
            ));
        }
        if !self.sched.is_idle() {
            return Err(anyhow!("reconfigure requires an idle scheduler"));
        }
        self.batch = Self::select_batch(&self.runtime, cfg.max_batch)?;
        self.draft = Self::load_draft(&self.runtime, &cfg)?;
        self.runtime
            .set_fault_plan(cfg.faults.enabled().then(|| FaultPlan::from_config(&cfg.faults)));
        self.policy = make_policy(&cfg);
        // Residency entries reference sequence ordinals of the scheduler
        // being replaced below — drop every scratch tier wholesale.
        self.scratch.clear();
        self.gather = GatherStats::default();
        self.scratch_tiers_evicted = 0;
        self.stage_tokens = TensorI32::zeros(&[self.batch]);
        self.stage_positions = TensorI32::zeros(&[self.batch]);
        self.stage_lens = TensorI32::zeros(&[self.n_layer, self.batch]);
        let page_bytes = cfg.kv_page_bytes.max(SequenceCache::token_bytes(self.row_elems));
        self.paged = PagedKvPool::new(
            KvPool::tiered(cfg.kv_pool_bytes, cfg.host_spill_bytes),
            page_bytes,
        );
        self.sched = Scheduler::new(self.batch, cfg.queue_depth);
        self.queue_hist = Histogram::new();
        self.ttft_hist = Histogram::new();
        self.itl_hist = Histogram::new();
        self.recorder = Arc::new(FlightRecorder::with_level(cfg.trace_level));
        self.phase_timers = PhaseTimers::new();
        self.phase_acc = PhaseAcc::default();
        self.layer_table = LayerTable::new(self.n_layer);
        self.cfg = cfg;
        Ok(())
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn pool(&self) -> &KvPool {
        self.paged.pool()
    }

    /// The page-granular allocator layered over [`pool`](Self::pool).
    pub fn paged_pool(&self) -> &PagedKvPool {
        &self.paged
    }

    /// Decode slot count actually bound (largest artifact batch <= max_batch).
    pub fn slot_count(&self) -> usize {
        self.batch
    }

    /// Scheduler queue/occupancy/preemption/swap counters.
    pub fn sched_metrics(&self) -> &SchedulerMetrics {
        self.sched.metrics()
    }

    /// Requests waiting for admission right now (live gauge, not the
    /// post-step snapshot in `sched_metrics`).
    pub fn queued_len(&self) -> usize {
        self.sched.queue_len()
    }

    /// Sequences in decode slots right now.
    pub fn running_len(&self) -> usize {
        self.sched.running()
    }

    /// Sequences currently swapped out to the host tier.
    pub fn suspended_len(&self) -> usize {
        self.sched.suspended_len()
    }

    /// Per-request queue latency histogram: submit → decode slot, including
    /// time spent suspended after preemption (so swap cost is observable,
    /// not inferred from counters). Reset by `generate_batch`/`reconfigure`.
    pub fn queue_latency(&mut self) -> &mut Histogram {
        &mut self.queue_hist
    }

    /// Time-to-first-token histogram: submit → first token sampled (the
    /// prefill-logits token at admission), queue wait included. Reset by
    /// `generate_batch`/`reconfigure`.
    pub fn ttft_latency(&mut self) -> &mut Histogram {
        &mut self.ttft_hist
    }

    /// Inter-token-latency histogram: gap between consecutive sampled
    /// tokens of a sequence, suspended time included. Reset by
    /// `generate_batch`/`reconfigure`.
    pub fn itl_latency(&mut self) -> &mut Histogram {
        &mut self.itl_hist
    }

    /// Live run counters (cumulative since the last `generate_batch` reset;
    /// `wall_s` is only populated by the `generate_batch` wrapper).
    pub fn run_stats(&self) -> &EngineRunStats {
        &self.run
    }

    /// The span ring lifecycle transitions are recorded into (query with
    /// `spans_for`/`trace_json`, dump on faults).
    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    /// Share a caller-owned recorder (the supervisor installs one per
    /// worker so its spans outlive a dead engine thread). The recorder's
    /// own level wins over `cfg.trace_level` from here on.
    pub fn set_recorder(&mut self, recorder: Arc<FlightRecorder>) {
        self.recorder = recorder;
    }

    /// Per-phase step-timing summaries (populated at `--trace-level full`;
    /// empty histograms otherwise).
    pub fn phase_json(&mut self) -> Json {
        self.phase_timers.to_json()
    }

    /// Requests the engine currently owns: queued + running + suspended.
    /// With the `SchedulerMetrics` counters this closes the conservation
    /// identity `submitted == completed + cancelled + deadline_exceeded +
    /// oom_failures + requests_failed + rejected + in_flight`.
    pub fn in_flight(&self) -> usize {
        self.sched.queue_len() + self.sched.running() + self.sched.suspended_len()
    }

    /// Lifetime + windowed throughput (tokens/s, requests/s) as JSON.
    pub fn throughput_json(&mut self) -> Json {
        self.meter.to_json()
    }

    /// The live squeeze table: cumulative per-layer eviction counters plus
    /// each active (running or suspended) sequence's resolved budget plan —
    /// the paper's Figure-1 layer view reconstructed from a serving engine.
    pub fn squeeze_table_json(&self) -> Json {
        fn nums(v: &[usize]) -> Json {
            Json::Arr(v.iter().map(|&x| Json::num(x as f64)).collect())
        }
        fn floats(v: &[f64]) -> Json {
            Json::Arr(v.iter().copied().map(Json::num).collect())
        }
        fn plan_json(id: u64, plan: &BudgetPlan) -> Json {
            Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("total_budget", Json::num(plan.total() as f64)),
                ("budgets", nums(&plan.budgets)),
                ("groups", nums(&plan.groups)),
                ("layer_means", floats(&plan.layer_means)),
            ])
        }
        let mut seqs: Vec<Json> = Vec::new();
        for a in self.sched.slots.iter().flatten() {
            seqs.push(plan_json(a.req.id, &a.plan));
        }
        for s in &self.sched.suspended {
            seqs.push(plan_json(s.req.id, &s.snapshot.plan));
        }
        Json::obj(vec![("layers", self.layer_table.to_json()), ("sequences", Json::Arr(seqs))])
    }

    /// True while any request is queued, running, or suspended.
    pub fn has_work(&self) -> bool {
        !self.sched.is_idle()
    }

    /// Start accumulating cosine heatmap stats across requests (Fig. 2).
    pub fn enable_cosine_collection(&mut self) {
        self.collect_cosine = Some(CosineStats::new(self.n_layer));
    }

    pub fn cosine_stats(&self) -> Option<&CosineStats> {
        self.collect_cosine.as_ref()
    }

    fn budget_spec(&self) -> BudgetSpec {
        if self.cfg.policy == PolicyKind::Full {
            BudgetSpec::Unlimited
        } else if let Some(f) = self.cfg.budget_frac {
            BudgetSpec::Fraction(f)
        } else {
            BudgetSpec::Tokens(self.cfg.budget)
        }
    }

    /// Whether preempted sequences are suspended to the host tier instead of
    /// restarted from scratch (`host_spill_bytes = 0` disables the tier and
    /// reproduces the restart semantics).
    fn swap_enabled(&self) -> bool {
        self.cfg.preemption && self.cfg.host_spill_bytes > 0
    }

    /// Enqueue a request for continuous batching; it will join the running
    /// batch at the next `step`. `Err` is the immediate backpressure
    /// rejection produced when the queue is at `cfg.queue_depth`.
    pub fn submit(&mut self, req: Request) -> std::result::Result<(), RequestOutput> {
        let id = req.id;
        self.recorder.record(id, SpanKind::Submit, 0);
        let q = Queued { req, t_submit: Instant::now(), restarted: false };
        match self.sched.enqueue(q, true) {
            Ok(()) => Ok(()),
            Err(q) => {
                self.recorder.record(id, SpanKind::Retire, 0);
                Err(Self::immediate_output(&q, FinishReason::Rejected, self.n_layer))
            }
        }
    }

    /// Advance the scheduler by one cycle: admit from the suspended set and
    /// the queue into free slots, run one batched decode step, retire
    /// finished sequences. Returns the requests that finished during this
    /// step.
    pub fn step(&mut self) -> Result<Vec<RequestOutput>> {
        let mut sched = std::mem::take(&mut self.sched);
        let res = self.step_inner(&mut sched);
        self.sched = sched;
        // One histogram record per touched phase per step, even for phases
        // accumulated across many slots or micro-steps.
        self.phase_acc.flush_into(&mut self.phase_timers);
        res
    }

    /// Step until idle, collecting every output (order of completion).
    pub fn drain(&mut self) -> Vec<RequestOutput> {
        let mut outputs = Vec::new();
        while self.has_work() {
            match self.step() {
                Ok(outs) => outputs.extend(outs),
                Err(e) => {
                    // Defensive only: step() currently resolves decode
                    // faults internally (fail-in-place), so this arm is for
                    // future genuinely-fatal error sources. Never hang the
                    // caller with requests still queued.
                    eprintln!("scheduler step failed: {e:#}");
                    outputs.extend(self.fail_all());
                    break;
                }
            }
        }
        outputs
    }

    /// Serve a closed batch of requests to completion — a compatibility
    /// wrapper that drains the continuous-batching scheduler. The queue cap
    /// is bypassed (a closed batch is not an open-loop arrival process).
    ///
    /// Requires an idle scheduler: mixing this with in-flight `submit`ted
    /// requests would reset their run counters and misdeliver their outputs
    /// into this batch's return value.
    pub fn generate_batch(&mut self, requests: Vec<Request>) -> Vec<RequestOutput> {
        assert!(
            self.sched.is_idle(),
            "generate_batch called with requests in flight; use submit/step"
        );
        let t0 = Instant::now();
        self.meter = ThroughputMeter::new();
        self.run = EngineRunStats::default();
        // Gather counters reset with the run so bytes-copied/step is
        // well-defined per closed batch; scratch residency itself survives
        // (sequence ordinals keep growing, so stale entries cannot alias).
        self.gather = GatherStats::default();
        self.scratch_tiers_evicted = 0;
        self.queue_hist = Histogram::new();
        self.ttft_hist = Histogram::new();
        self.itl_hist = Histogram::new();
        self.phase_timers = PhaseTimers::new();
        self.phase_acc = PhaseAcc::default();
        for req in requests {
            let _ = self.sched.enqueue(Queued { req, t_submit: t0, restarted: false }, false);
        }
        let mut outputs = self.drain();
        self.run.wall_s = t0.elapsed().as_secs_f64();
        self.run.peak_pool_bytes = self.pool().peak();
        self.run.generated_tokens = self.meter.tokens();
        self.last_run = self.run.clone();
        outputs.sort_by_key(|o| o.id);
        outputs
    }

    fn step_inner(&mut self, sched: &mut Scheduler) -> Result<Vec<RequestOutput>> {
        let mut outputs = Vec::new();
        let t_admission = self.recorder.level().full().then(Instant::now);
        // Terminal lifecycle transitions first: cancelled or expired
        // requests must not occupy a slot this step (nor block admission).
        self.lifecycle_phase(sched, &mut outputs);
        self.admit_phase(sched, &mut outputs);
        // Retire sequences that are already done at admission — the prefill
        // logits sampled EOS, or max_new_tokens == 1 — before spending a
        // decode step on them (and before they could over-generate).
        self.retire_phase(sched, &mut outputs);
        if let Some(t) = t_admission {
            self.phase_acc.add(StepPhase::Admission, t.elapsed().as_secs_f64());
        }
        let occupancy = sched.running();
        if occupancy == 0 {
            self.stamp_kv_gauges(sched);
            self.note_outputs(&outputs);
            return Ok(outputs);
        }
        if let Err(e) = self.decode_phase(sched, &mut outputs) {
            // Backend fault: contain it to the sequences that were in the
            // failed batch instead of poisoning the whole engine. Queued and
            // suspended requests are untouched; affected slots re-queue from
            // their step-boundary snapshot (bounded per-request retries) or
            // retire with `WorkerError`. Outputs already collected this step
            // (pre-decode retirements) are preserved either way.
            self.contain_step_error(sched, &mut outputs, &e);
            self.stamp_kv_gauges(sched);
            self.note_outputs(&outputs);
            return Ok(outputs);
        }
        self.retire_phase(sched, &mut outputs);
        sched.note_step(occupancy);
        self.prune_scratch();
        // Keep the live counters coherent for step-driven observers
        // (`wall_s` is only meaningful for the generate_batch window).
        self.run.generated_tokens = self.meter.tokens();
        self.run.peak_pool_bytes = self.pool().peak();
        self.stamp_kv_gauges(sched);
        self.note_outputs(&outputs);
        Ok(outputs)
    }

    /// Record per-request queue latency (queue wait + suspended time) and
    /// the terminal `Retire` span for every output leaving the engine this
    /// step. The histogram is reservoir-bounded, so a long-running
    /// step-driven engine (router worker) records every sample without
    /// growing without bound.
    fn note_outputs(&mut self, outputs: &[RequestOutput]) {
        for out in outputs {
            self.queue_hist.record(out.timing.queue_s + out.timing.suspended_s);
            self.recorder.record(out.id, SpanKind::Retire, out.peak_kv_bytes as u64);
        }
    }

    /// Record one time-to-first-token sample.
    fn note_ttft(&mut self, v: f64) {
        self.ttft_hist.record(v);
    }

    /// Record one inter-token-latency sample.
    fn note_itl(&mut self, v: f64) {
        self.itl_hist.record(v);
    }

    /// The deadline a request is serving under: its own, else the config
    /// default (`request_deadline_ms`, 0 = none).
    fn effective_deadline(&self, req: &Request) -> Option<Duration> {
        req.deadline.or_else(|| {
            (self.cfg.request_deadline_ms > 0)
                .then(|| Duration::from_millis(self.cfg.request_deadline_ms))
        })
    }

    /// Whether a request must leave the scheduler now: cancelled (the
    /// explicit signal wins) or past its deadline.
    fn lapse(&self, req: &Request, t_submit: Instant, now: Instant) -> Option<FinishReason> {
        if req.cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
            return Some(FinishReason::Cancelled);
        }
        if let Some(d) = self.effective_deadline(req) {
            if now.duration_since(t_submit) >= d {
                return Some(FinishReason::DeadlineExceeded);
            }
        }
        None
    }

    fn note_lapse(sched: &mut Scheduler, reason: FinishReason) {
        match reason {
            FinishReason::Cancelled => sched.metrics.cancelled += 1,
            FinishReason::DeadlineExceeded => sched.metrics.deadline_exceeded += 1,
            _ => {}
        }
    }

    /// Terminal lifecycle transitions decided at the step boundary:
    /// cancelled requests and expired deadlines leave the queue, the decode
    /// slots, and the suspended set. Dropping the slot or suspended state
    /// releases its device/host pages (RAII), so a cancel while
    /// swapped out frees the host tier directly — no swap-in. Partial
    /// generations are preserved in the outputs.
    fn lifecycle_phase(&mut self, sched: &mut Scheduler, outputs: &mut Vec<RequestOutput>) {
        let now = Instant::now();
        let mut i = 0;
        while i < sched.queue.len() {
            match self.lapse(&sched.queue[i].req, sched.queue[i].t_submit, now) {
                Some(reason) => {
                    let q = sched.queue.remove(i).expect("index in bounds");
                    Self::note_lapse(sched, reason);
                    outputs.push(Self::immediate_output(&q, reason, self.n_layer));
                }
                None => i += 1,
            }
        }
        for idx in 0..sched.slots.len() {
            let lapsed = match &sched.slots[idx] {
                Some(a) => self.lapse(&a.req, a.t_submit, now),
                None => None,
            };
            if let Some(reason) = lapsed {
                let a = sched.slots[idx].take().expect("checked occupied");
                Self::note_lapse(sched, reason);
                outputs.push(Self::finish(a, reason));
            }
        }
        if !sched.suspended.is_empty() {
            let suspended = std::mem::take(&mut sched.suspended);
            for s in suspended {
                match self.lapse(&s.req, s.t_submit, now) {
                    Some(reason) => {
                        Self::note_lapse(sched, reason);
                        outputs.push(Self::finish_suspended(s, reason));
                    }
                    None => sched.suspended.push_back(s),
                }
            }
        }
        sched.refresh_gauges();
    }

    /// Fill free slots: suspended sequences swap back in first (queue-front
    /// priority — no prefill needed), then queued requests under KV-pool
    /// admission control.
    fn admit_phase(&mut self, sched: &mut Scheduler, outputs: &mut Vec<RequestOutput>) {
        while sched.has_free_slot() {
            if sched.peek_suspended().is_some() {
                if self.try_resume(sched) {
                    continue;
                }
                // No device headroom for the resume. Hold the queue too:
                // admitting new work ahead of a suspended sequence would
                // invert priority and consume the headroom it waits for.
                sched.metrics.deferred_admissions += 1;
                break;
            }
            let est = match sched.queue.front() {
                Some(q) => self.estimate_admit_bytes(&q.req),
                None => break,
            };
            let running = sched.running();
            if self.pool().capacity() > 0 && running > 0 {
                // `est` approximates the admission cache (the plan's
                // per-layer min(budget, prompt) sum never exceeds the
                // uniform estimate byte-wise; page rounding can nudge it
                // either way by tail-page slack), so deferring on it avoids
                // a wasted prefill per step while the pool is saturated.
                // Terminal Oom decisions are made only by the plan-aware
                // predicted-peak check in `admit`, once the batch has
                // drained.
                let available = self.pool().capacity().saturating_sub(self.pool().in_use());
                if est > available {
                    sched.metrics.deferred_admissions += 1;
                    break;
                }
            }
            let q = sched.pop_queue().expect("peeked head exists");
            let allow_retry = running > 0 && self.cfg.preemption;
            // A restart-from-scratch requeue already delivered its first
            // token in a previous admission: re-admitting it must not
            // record a second TTFT sample.
            let restarted = q.restarted;
            match self.admit(q, allow_retry, sched.next_seq) {
                Ok(active) => {
                    sched.next_seq += 1;
                    if !restarted {
                        self.note_ttft(active.timing.first_token_s);
                    }
                    lifecycle::emit(
                        &active.req.events,
                        RequestEvent::Started {
                            id: active.req.id,
                            prompt_tokens: active.req.prompt.len(),
                        },
                    );
                    lifecycle::emit(
                        &active.req.events,
                        RequestEvent::Token { id: active.req.id, token: active.last_token, pos: 0 },
                    );
                    sched.place(active);
                }
                Err(AdmitError::Terminal(out)) => {
                    match out.finish {
                        FinishReason::Oom => sched.metrics.oom_failures += 1,
                        FinishReason::Rejected => sched.metrics.rejected += 1,
                        _ => {}
                    }
                    outputs.push(out);
                }
                Err(AdmitError::Retry(q)) => {
                    self.recorder.record(q.req.id, SpanKind::Retry, 0);
                    sched.metrics.deferred_admissions += 1;
                    sched.requeue_front(q);
                    break;
                }
                Err(AdmitError::Suspend(s)) => {
                    // The prefill is preserved on the host tier; the next
                    // loop iteration (or step) resumes it once device bytes
                    // free up. The first token was already sampled, so the
                    // stream sees Started → Token(0) → Suspended.
                    sched.next_seq += 1;
                    if !restarted {
                        self.note_ttft(s.snapshot.timing.first_token_s);
                    }
                    lifecycle::emit(
                        &s.req.events,
                        RequestEvent::Started { id: s.req.id, prompt_tokens: s.req.prompt.len() },
                    );
                    lifecycle::emit(
                        &s.req.events,
                        RequestEvent::Token { id: s.req.id, token: s.snapshot.last_token, pos: 0 },
                    );
                    lifecycle::emit(&s.req.events, RequestEvent::Suspended { id: s.req.id });
                    self.note_swap_out(sched);
                    sched.suspend(*s);
                }
            }
        }
    }

    /// Swap the front suspended sequence back into a decode slot: migrate
    /// its pages host→device, restore the snapshot, and continue decoding
    /// from `next_pos` — no prefill, partial output kept. Returns false when
    /// the device tier lacks headroom (caller defers).
    fn try_resume(&mut self, sched: &mut Scheduler) -> bool {
        let needed = match sched.peek_suspended() {
            Some(s) => {
                // Headroom must cover the next decode step's page growth
                // too, or a barely-fitting resume is immediately
                // re-preempted — burning a swap cycle (and a decode slot)
                // per step with zero progress. Admission's predicted-peak
                // check guarantees budget+1 rows per layer fit an empty
                // pool, so this can never wedge a sequence.
                let n = s.snapshot.cache.n_layer();
                let mut lens = Vec::with_capacity(n);
                for layer in 0..n {
                    lens.push(s.snapshot.cache.layer_len(layer) + 1);
                }
                s.table.migratable_bytes(Tier::Device) + s.table.grow_bytes_for(&lens)
            }
            None => return false,
        };
        if self.pool().capacity() > 0 {
            let available = self.pool().capacity().saturating_sub(self.pool().in_use());
            if needed > available {
                return false;
            }
        }
        let mut s = sched.pop_suspended().expect("peeked entry exists");
        match s.table.migrate(Tier::Device) {
            Ok(pages) => {
                sched.metrics.swap_ins += 1;
                sched.metrics.restarts_avoided += 1;
                sched.metrics.pages_swapped_in += pages as u64;
            }
            Err(_) => {
                // The headroom vanished between check and migrate (engine is
                // single-threaded, so this is defensive only).
                sched.suspend(s);
                return false;
            }
        }
        let a = s.into_active();
        lifecycle::emit(&a.req.events, RequestEvent::Resumed { id: a.req.id });
        self.recorder.record(a.req.id, SpanKind::Resume, a.table.bytes() as u64);
        sched.place(a);
        true
    }

    /// Record one device→host migration: a preemption suspend, or a prefill
    /// parked at admission while the device pool was transiently full.
    fn note_swap_out(&self, sched: &mut Scheduler) {
        sched.metrics.swap_outs += 1;
        sched.metrics.host_bytes_peak =
            sched.metrics.host_bytes_peak.max(self.pool().peak_of(Tier::Host));
    }

    /// Refresh the paged-KV gauges exported with the scheduler metrics:
    /// allocated vs used bytes per tier (the gap is tail-page
    /// fragmentation), shared/COW page counts, and absorbed accounting
    /// faults.
    fn stamp_kv_gauges(&self, sched: &mut Scheduler) {
        let token_bytes = SequenceCache::token_bytes(self.row_elems);
        let mut dev_used = 0;
        for a in sched.slots.iter().flatten() {
            dev_used += a.cache.bytes();
        }
        let mut host_used = 0;
        for s in &sched.suspended {
            host_used += s.snapshot.cache.total_tokens() * token_bytes;
        }
        sched.metrics.kv_alloc_bytes = self.paged.allocated_bytes_of(Tier::Device);
        sched.metrics.kv_used_bytes = dev_used;
        sched.metrics.host_alloc_bytes = self.paged.allocated_bytes_of(Tier::Host);
        sched.metrics.host_used_bytes = host_used;
        sched.metrics.shared_pages = self.paged.shared_pages();
        sched.metrics.cow_copies = self.paged.cow_copies() as u64;
        sched.metrics.accounting_errors = self.pool().accounting_errors() as u64;
        sched.metrics.kv_bytes_copied = self.gather.kv_bytes_copied;
        sched.metrics.gather_full_refills = self.gather.full_refills;
        sched.metrics.gather_incremental_appends = self.gather.incremental_appends;
        sched.metrics.scratch_retained_bytes = self.scratch.values().map(|t| t.bytes()).sum();
        sched.metrics.scratch_tiers_evicted = self.scratch_tiers_evicted;
        sched.metrics.faults_injected = self.runtime.faults_injected();
    }

    /// Decode steps a scratch tier may sit unused before the idle sweep
    /// reclaims its buffers — the tier map no longer retains every `(B, M)`
    /// pair it ever touched. Generous relative to tier-switch cadence: a
    /// sequence crossing a capacity boundary comes back to the smaller tier
    /// only via retirement + admission, well past any hot reuse window.
    const SCRATCH_IDLE_STEPS: u64 = 256;

    /// Drop scratch tiers unused for `SCRATCH_IDLE_STEPS` decode steps.
    /// Retained bytes are exported as `scratch_retained_bytes`.
    fn prune_scratch(&mut self) {
        let now = self.run.decode_steps;
        let before = self.scratch.len();
        self.scratch
            .retain(|_, t| now.saturating_sub(t.last_used_step) <= Self::SCRATCH_IDLE_STEPS);
        self.scratch_tiers_evicted += (before - self.scratch.len()) as u64;
    }

    /// Token rows (slots) per KV page for this model's row width.
    fn slots_per_page(&self) -> usize {
        (self.paged.page_bytes() / SequenceCache::token_bytes(self.row_elems)).max(1)
    }

    /// Pages needed to hold `tokens` rows of one layer.
    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.slots_per_page())
    }

    /// Bytes the prompt cache will occupy right after admission (prompt
    /// compression applied), estimated without running prefill: per layer at
    /// most `min(b_init, prompt_len)` tokens, rounded up to whole pages.
    /// Squeeze reallocation conserves the per-layer total, so the uniform
    /// estimate is exact up to min-budget floors and tail-page slack.
    fn estimate_admit_bytes(&self, req: &Request) -> usize {
        let prompt_len = req.prompt.len();
        let b_init = self.budget_spec().resolve(prompt_len, self.max_seq);
        self.n_layer * self.pages_for(b_init.min(prompt_len)) * self.paged.page_bytes()
    }

    /// New tokens a request can actually generate: `max_new_tokens` clamped
    /// to the model's sequence capacity (with the engine's 8-token slack).
    /// Shared by admission (`effective_max_new`) and growth prediction so
    /// the two can never disagree.
    fn effective_new_tokens(&self, prompt_len: usize, max_new: usize) -> usize {
        max_new.min(self.max_seq.saturating_sub(prompt_len + 8)).max(1)
    }

    /// Peak bytes a sequence can reach under its budget plan: each layer
    /// grows to at most budget+1 rows (append-then-evict overshoot), never
    /// beyond the final sequence length, rounded up to whole pages.
    fn predicted_peak_bytes(&self, plan: &BudgetPlan, prompt_len: usize, max_new: usize) -> usize {
        let final_len = prompt_len + self.effective_new_tokens(prompt_len, max_new);
        let mut pages = 0;
        for &b in &plan.budgets {
            pages += self.pages_for((b + 1).min(final_len));
        }
        pages * self.paged.page_bytes()
    }

    /// Prefill + squeeze + prompt compression. Returns the slot state, or
    /// why the request could not start.
    fn admit(
        &mut self,
        q: Queued,
        allow_retry: bool,
        seq: u64,
    ) -> std::result::Result<Active, AdmitError> {
        let Queued { req, t_submit, restarted } = q;
        let t_admit = Instant::now();
        let mut timing = RequestTiming {
            queue_s: t_admit.duration_since(t_submit).as_secs_f64(),
            ..Default::default()
        };
        let prompt_len = req.prompt.len();
        self.recorder.record(req.id, SpanKind::Admit, 0);

        fn reject(
            req: &Request,
            timing: RequestTiming,
            plan: BudgetPlan,
            finish: FinishReason,
            kv: usize,
        ) -> AdmitError {
            let out = RequestOutput {
                id: req.id,
                generated: vec![],
                finish,
                timing,
                plan,
                peak_kv_bytes: 0,
                final_kv_tokens: kv,
            };
            lifecycle::emit_terminal(&req.events, &out);
            AdmitError::Terminal(out)
        }

        let largest = self
            .runtime
            .manifest
            .prefill_buckets(self.runtime.kernel())
            .last()
            .copied()
            .unwrap_or(0);
        if prompt_len == 0 || prompt_len > largest {
            return Err(reject(
                &req,
                timing,
                BudgetPlan::uniform(self.n_layer, 0),
                FinishReason::Rejected,
                0,
            ));
        }

        let tp = Instant::now();
        let pre = match self.runtime.prefill(&req.prompt) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("prefill failed: {e:#}");
                return Err(reject(
                    &req,
                    timing,
                    BudgetPlan::uniform(self.n_layer, 0),
                    FinishReason::Rejected,
                    0,
                ));
            }
        };
        timing.prefill_s = tp.elapsed().as_secs_f64();
        self.recorder.record(req.id, SpanKind::Prefill, 0);

        // --- SqueezeAttention: importance -> groups -> budgets -------------
        let ts = Instant::now();
        let b_init = self.budget_spec().resolve(prompt_len, self.max_seq);
        let plan = if self.cfg.squeeze.enabled && self.cfg.policy != PolicyKind::Full {
            let mut stats = CosineStats::new(self.n_layer);
            stats.observe(&pre.cos_sims, prompt_len);
            allocate(&stats.layer_means(), b_init, &self.cfg.squeeze)
        } else {
            BudgetPlan::uniform(self.n_layer, b_init)
        };
        timing.squeeze_s = ts.elapsed().as_secs_f64();
        self.recorder.record(req.id, SpanKind::Squeeze, 0);
        if let Some(collect) = &mut self.collect_cosine {
            collect.observe(&pre.cos_sims, prompt_len);
        }

        let mut cache = match SequenceCache::from_prefill(&pre.k, &pre.v, prompt_len) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("cache build failed: {e:#}");
                return Err(reject(&req, timing, plan, FinishReason::Rejected, 0));
            }
        };

        // --- compress the prompt cache per layer with its own budget -------
        let token_bytes = SequenceCache::token_bytes(self.row_elems) as u64;
        for layer in 0..self.n_layer {
            let budget = plan.budgets[layer];
            let before = cache.layer_len(layer);
            if before > budget {
                let keep = self.policy.keep(&cache.layers[layer].meta, budget);
                cache.retain(layer, &keep).expect("policy produced valid keep-set");
                let evicted = (before - cache.layer_len(layer)) as u64;
                self.layer_table.note_eviction(layer, evicted, evicted * token_bytes);
            }
        }

        // Plan-aware growth prediction: a capped pool that cannot hold this
        // sequence even alone means it can never finish — fail fast rather
        // than preempt the world and still OOM.
        if self.pool().capacity() > 0
            && self.predicted_peak_bytes(&plan, prompt_len, req.max_new_tokens)
                > self.pool().capacity()
        {
            let kv = cache.total_tokens();
            return Err(reject(&req, timing, plan, FinishReason::Oom, kv));
        }

        let table = match PageTable::for_cache(&self.paged, Tier::Device, &cache) {
            Ok(t) => t,
            Err(_) if allow_retry => {
                // Transient device-pool-full. With the host tier enabled,
                // park the finished prefill as a suspended sequence so the
                // eventual re-admission is a swap-in, not a second prefill.
                // The pages are born on the host tier, so the park charges
                // no migration traffic.
                if self.swap_enabled() {
                    if let Ok(host) = PageTable::for_cache(&self.paged, Tier::Host, &cache) {
                        let first = sample(&pre.logits.data, req.sampling, &mut self.rng);
                        timing.first_token_s = t_submit.elapsed().as_secs_f64();
                        let effective_max_new =
                            self.effective_new_tokens(prompt_len, req.max_new_tokens);
                        let peak = host.bytes();
                        self.recorder.record(req.id, SpanKind::FirstToken, peak as u64);
                        self.recorder.record(req.id, SpanKind::Suspend, peak as u64);
                        return Err(AdmitError::Suspend(Box::new(Suspended::from_active(
                            Active {
                                generated: vec![first],
                                next_pos: prompt_len,
                                last_token: first,
                                effective_max_new,
                                seq,
                                t_submit,
                                t_admit,
                                t_last_token: Instant::now(),
                                timing,
                                peak_bytes: peak,
                                req,
                                cache,
                                plan,
                                table: host, // already host-tier pages
                            },
                        ))));
                    }
                }
                return Err(AdmitError::Retry(Queued { req, t_submit, restarted }));
            }
            Err(_) => {
                let kv = cache.total_tokens();
                return Err(reject(&req, timing, plan, FinishReason::Oom, kv));
            }
        };

        // First decoded token comes from the prefill logits.
        let first = sample(&pre.logits.data, req.sampling, &mut self.rng);
        timing.first_token_s = t_submit.elapsed().as_secs_f64();

        let effective_max_new = self.effective_new_tokens(prompt_len, req.max_new_tokens);
        let peak = table.bytes();
        self.recorder.record(req.id, SpanKind::FirstToken, peak as u64);
        Ok(Active {
            generated: vec![first],
            next_pos: prompt_len,
            last_token: first,
            effective_max_new,
            seq,
            t_submit,
            t_admit,
            t_last_token: Instant::now(),
            timing,
            peak_bytes: peak,
            req,
            cache,
            plan,
            table,
        })
    }

    /// Preempt a running sequence to free device bytes: suspend it to the
    /// host tier (page-table migrate + snapshot — resume continues
    /// token-identically) when spill is enabled and fits, otherwise requeue
    /// its request for a restart-from-scratch (dropping the `Active`
    /// releases its pages either way; on migrate only page-table entries
    /// move).
    fn suspend_or_requeue(&mut self, sched: &mut Scheduler, mut a: Active) {
        self.recorder.record(a.req.id, SpanKind::Suspend, a.cache.bytes() as u64);
        if self.swap_enabled() {
            if let Ok(pages) = a.table.migrate(Tier::Host) {
                sched.metrics.pages_swapped_out += pages as u64;
                self.note_swap_out(sched);
                lifecycle::emit(&a.req.events, RequestEvent::Suspended { id: a.req.id });
                sched.suspend(Suspended::from_active(a));
                return;
            }
        }
        // Host tier full or disabled: restart-from-scratch (prompt
        // re-prefilled on re-admission, partial output discarded).
        sched.requeue_front(Queued { req: a.req, t_submit: a.t_submit, restarted: true });
    }

    /// One decode step over the occupied slots. In speculative mode each
    /// step is a draft→verify→rollback burst committing 1..=k+1 tokens per
    /// sequence; otherwise exactly one token per sequence.
    fn decode_phase(
        &mut self,
        sched: &mut Scheduler,
        outputs: &mut Vec<RequestOutput>,
    ) -> Result<()> {
        if self.cfg.spec.enabled && self.cfg.spec.draft_k > 0 && self.draft.is_some() {
            self.decode_step_spec(sched, outputs)
        } else {
            self.decode_step_plain(sched, outputs)
        }
    }

    /// Occupied slot indices oldest-first (admission order): the stable
    /// processing order for charging, committing, and preempting.
    fn slot_order(sched: &Scheduler) -> Vec<usize> {
        let mut order: Vec<(u64, usize)> = sched
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|a| (a.seq, i)))
            .collect();
        order.sort_unstable();
        order.into_iter().map(|(_, i)| i).collect()
    }

    /// One batched decode call over the slots named by `inputs` (`(slot,
    /// token, position)` triples): fills the per-tier scratch buffers from
    /// each slot's cache and runs the target or draft model. Uninvolved
    /// slots stay padded (PAD token, zero lens) and their logits rows are
    /// never read. Returns the decode output and the capacity tier bound
    /// `m` (the score stride `commit_token` needs).
    fn batched_call(
        &mut self,
        sched: &Scheduler,
        use_draft: bool,
        inputs: &[(usize, i32, i32)],
    ) -> Result<(DecodeOut, usize)> {
        let b = self.batch;
        // Tier: smallest capacity covering every participating layer cache
        // + the new token.
        let needed = inputs
            .iter()
            .filter_map(|&(i, _, _)| sched.slots[i].as_ref())
            .map(|a| a.cache.max_layer_len())
            .max()
            .unwrap_or(0)
            + 1;
        let tier = self.runtime.decode_tier_for(b, needed)?;
        let (_, m) = tier;
        let (h, d) = (
            self.runtime.manifest.model.n_head,
            self.runtime.manifest.model.head_dim,
        );

        // Take the resident tier out of the map so the runtime call below
        // can borrow `self`.
        let mut st = self
            .scratch
            .remove(&tier)
            .unwrap_or_else(|| ScratchTier::new(self.n_layer, b, m, h, d));
        st.last_used_step = self.run.decode_steps;

        // Reset the reused staging tensors in place; uninvolved slots stay
        // padded (PAD token, zero lens) and their logits rows are never
        // read.
        self.stage_tokens.data.fill(tokenizer::PAD);
        self.stage_positions.data.fill(0);
        self.stage_lens.data.fill(0);
        let allow_incremental = self.cfg.resident_scratch;
        let timed = self.recorder.level().full();
        let t_gather = timed.then(Instant::now);
        let mut fill = Ok(());
        for &(i, tok, pos) in inputs {
            let a = sched.slots[i].as_ref().expect("inputs list occupied slots");
            self.stage_tokens.data[i] = tok;
            self.stage_positions.data[i] = pos;
            if let Err(e) = st.gather(
                &a.cache,
                a.seq,
                i,
                &mut self.stage_lens.data,
                allow_incremental,
                &mut self.gather,
            ) {
                fill = Err(e);
                break;
            }
        }
        if let Some(t) = t_gather {
            self.phase_acc.add(StepPhase::Gather, t.elapsed().as_secs_f64());
        }

        let t_model = timed.then(Instant::now);
        let out = match fill {
            Ok(()) => {
                let rt = if use_draft {
                    self.draft.as_ref().expect("spec mode loaded a draft runtime")
                } else {
                    &self.runtime
                };
                rt.decode(
                    tier,
                    &self.stage_tokens,
                    &self.stage_positions,
                    &st.k,
                    &st.v,
                    &self.stage_lens,
                )
            }
            Err(e) => Err(e),
        };
        if let Some(t) = t_model {
            self.phase_acc.add(StepPhase::Model, t.elapsed().as_secs_f64());
        }
        self.scratch.insert(tier, st);
        let out = out?;
        self.run.decode_steps += 1;
        self.run.kv_slots_touched += (self.n_layer * b * m) as u64;
        self.meter.add_decode_step();
        Ok((out, m))
    }

    /// Charge page-table growth of `extra` rows per layer for slot `idx`
    /// (`grow` charges only the layers whose new rows cross a page
    /// boundary), resolving pool OOM by preempting the youngest running
    /// sequence — or yielding / failing with `Oom` when alone. Returns true
    /// when the slot is still running with the growth charged.
    fn charge_growth(
        &mut self,
        sched: &mut Scheduler,
        outputs: &mut Vec<RequestOutput>,
        idx: usize,
        extra: usize,
    ) -> bool {
        loop {
            let (old_lens, new_lens) = {
                let a = sched.slots[idx].as_ref().expect("checked occupied");
                let mut old = Vec::with_capacity(self.n_layer);
                let mut new = Vec::with_capacity(self.n_layer);
                for layer in 0..self.n_layer {
                    let len = a.cache.layer_len(layer);
                    old.push(len);
                    new.push(len + extra);
                }
                (old, new)
            };
            if sched.slots[idx]
                .as_mut()
                .expect("checked occupied")
                .table
                .grow(&old_lens, &new_lens)
                .is_ok()
            {
                let a = sched.slots[idx].as_mut().expect("checked occupied");
                a.peak_bytes = a.peak_bytes.max(a.table.bytes());
                return true;
            }
            let victim = if self.cfg.preemption && sched.running() > 1 {
                sched.youngest_running()
            } else {
                None
            };
            match victim {
                Some(v) if v != idx => {
                    // Preempt the youngest running sequence (younger
                    // than idx, so untouched this pass), then retry the
                    // failed grow with the freed device bytes.
                    let va = sched.slots[v].take().expect("victim occupied");
                    sched.metrics.preemptions += 1;
                    self.run.preemptions += 1;
                    self.suspend_or_requeue(sched, va);
                }
                Some(_) => {
                    // This sequence IS the youngest: it yields to the
                    // older work instead of evicting it.
                    let a = sched.slots[idx].take().expect("checked occupied");
                    sched.metrics.preemptions += 1;
                    self.run.preemptions += 1;
                    self.suspend_or_requeue(sched, a);
                    return false;
                }
                None => {
                    // Alone (or preemption disabled) and still too big:
                    // a genuine OOM failure.
                    let a = sched.slots[idx].take().expect("checked occupied");
                    sched.metrics.oom_failures += 1;
                    outputs.push(Self::finish(a, FinishReason::Oom));
                    return false;
                }
            }
        }
    }

    /// Fold one decode-output row into slot `idx`: append the new KV row to
    /// every layer, fold the H2O attention-mass signal, sample the next
    /// token, emit its `Token` event, and re-compress any layer over budget
    /// (returning whole pages). This is the single per-token commit path —
    /// the non-speculative step and every speculative verify micro-step run
    /// exactly this code, which is what makes speculative output
    /// token-identical under every eviction policy. The caller has already
    /// charged table growth for the appended row.
    fn commit_token(
        &mut self,
        sched: &mut Scheduler,
        idx: usize,
        out: &DecodeOut,
        m: usize,
    ) -> Result<i32> {
        let b = self.batch;
        let vocab = self.runtime.manifest.model.vocab;
        let needs_scores = self.policy.needs_scores();
        let timed = self.recorder.level().full();
        let t_commit = timed.then(Instant::now);
        let a = sched.slots[idx].as_mut().expect("checked occupied");

        // Append the new KV row to every layer and fold H2O scores (the
        // grow was charged by the caller, so append cannot over-commit).
        let pos = a.next_pos as u32;
        for layer in 0..self.n_layer {
            let base = (layer * b + idx) * self.row_elems;
            let k_row = &out.new_k.data[base..base + self.row_elems];
            let v_row = &out.new_v.data[base..base + self.row_elems];
            a.cache.append(layer, k_row, v_row, pos)?;
            if needs_scores {
                let sbase = (layer * b + idx) * m;
                let n = a.cache.layer_len(layer).min(m);
                a.cache.add_scores(layer, &out.scores.data[sbase..sbase + n])?;
            }
        }

        // Sample the next token from this slot's logits row.
        let row = &out.logits.data[idx * vocab..(idx + 1) * vocab];
        let tok = sample(row, a.req.sampling, &mut self.rng);
        a.generated.push(tok);
        a.last_token = tok;
        a.next_pos += 1;
        self.meter.add_tokens(1);
        lifecycle::emit(
            &a.req.events,
            RequestEvent::Token { id: a.req.id, token: tok, pos: a.generated.len() - 1 },
        );

        // Per-layer re-compression with each layer's own budget
        // (Algorithm 1, lines 15–19).
        let t_evict = timed.then(Instant::now);
        let token_bytes = SequenceCache::token_bytes(self.row_elems) as u64;
        let grown = a.cache.bytes();
        for layer in 0..self.n_layer {
            let budget = a.plan.budgets[layer];
            let before = a.cache.layer_len(layer);
            if before > budget {
                let keep = self.policy.keep(&a.cache.layers[layer].meta, budget);
                a.cache.retain(layer, &keep)?;
                self.run.evictions += 1;
                let evicted = (before - a.cache.layer_len(layer)) as u64;
                self.layer_table.note_eviction(layer, evicted, evicted * token_bytes);
            }
        }
        let shrunk = a.cache.bytes();
        if shrunk != grown {
            let mut lens = Vec::with_capacity(self.n_layer);
            for layer in 0..self.n_layer {
                lens.push(a.cache.layer_len(layer));
            }
            // Engine tables are never shared, so shrink cannot COW
            // (and therefore cannot fail).
            let _ = a.table.shrink(&lens);
        }
        if let Some(te) = t_evict {
            let evict_s = te.elapsed().as_secs_f64();
            self.phase_acc.add(StepPhase::Evict, evict_s);
            if let Some(tc) = t_commit {
                let commit_s = (tc.elapsed().as_secs_f64() - evict_s).max(0.0);
                self.phase_acc.add(StepPhase::Commit, commit_s);
            }
        }
        Ok(tok)
    }

    /// Record the burst's inter-token intervals for slot `idx`: the gap
    /// since the previous burst (anchored at `t_last_token`, suspended time
    /// included) is split evenly over the `n` tokens just committed, so a
    /// burst of n tokens records n samples and ITL stays comparable between
    /// speculative and non-speculative serving.
    fn note_burst_itl(&mut self, sched: &mut Scheduler, idx: usize, n: usize) {
        if n == 0 {
            return;
        }
        let per = {
            let Some(a) = sched.slots[idx].as_mut() else { return };
            let now = Instant::now();
            let per = now.duration_since(a.t_last_token).as_secs_f64() / n as f64;
            a.t_last_token = now;
            per
        };
        for _ in 0..n {
            self.note_itl(per);
        }
    }

    /// A speculative burst step. Per running sequence: charge the burst's
    /// predicted peak (k drafts + 1 bonus row per layer), draft up to k
    /// tokens with the draft model (optimistic appends — no scores, no
    /// events), roll the drafted rows back (`SequenceCache::truncate` +
    /// page-granular `PageTable::shrink`), then verify with the target
    /// model in micro-steps batched across sequences. Each micro-step runs
    /// the exact non-speculative commit path, so the committed stream is
    /// token-identical to non-speculative decode; a sequence stops at its
    /// first draft mismatch, EOS, length cap, or cancellation.
    fn decode_step_spec(
        &mut self,
        sched: &mut Scheduler,
        outputs: &mut Vec<RequestOutput>,
    ) -> Result<()> {
        struct Burst {
            idx: usize,
            /// Draft budget for this burst (<= cfg draft_k; clamped by the
            /// sequence's remaining length).
            k: usize,
            /// Committed sequence length (== next_pos) at burst start; the
            /// rollback target.
            start_pos: usize,
            drafts: Vec<i32>,
            /// Still proposing (the draft phase stops early at EOS).
            drafting: bool,
            /// Still taking verify micro-steps.
            verifying: bool,
            committed: usize,
            accepted: usize,
        }
        let draft_k = self.cfg.spec.draft_k;
        let mut bursts: Vec<Burst> = Vec::new();
        // Membership + slot accounting, oldest first: the whole burst's
        // page growth (k drafts + 1 bonus row per layer) is charged before
        // any draft work, so a preemption victim is always chosen before
        // its slot holds drafted rows and its snapshot stays step-boundary
        // consistent.
        for idx in Self::slot_order(sched) {
            if sched.slots[idx].is_none() {
                continue; // preempted charging an older burst
            }
            let a = sched.slots[idx].as_ref().expect("checked occupied");
            if a.req.cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
                continue; // the next lifecycle phase retires it; don't decode
            }
            // Never draft past the length cap: k drafts + the bonus token
            // must all fit in the sequence's remaining new-token room.
            let room = a.effective_max_new.saturating_sub(a.generated.len());
            if room == 0 {
                continue;
            }
            let k = draft_k.min(room - 1);
            let start_pos = a.next_pos;
            if !self.charge_growth(sched, outputs, idx, k + 1) {
                continue;
            }
            bursts.push(Burst {
                idx,
                k,
                start_pos,
                drafts: Vec::with_capacity(k),
                drafting: k > 0,
                verifying: true,
                committed: 0,
                accepted: 0,
            });
        }
        if bursts.is_empty() {
            return Ok(());
        }

        // --- draft phase: sequential micro-steps, batched across slots ----
        // A fault mid-draft must not escape before the rollback below runs:
        // slots would be suspended with unverified drafted rows in their
        // caches, violating the "rollback is never observable" contract. So
        // the phase captures its error and the rollback is unconditional.
        let draft_res: Result<()> = (|| {
            for j in 0..draft_k {
                let inputs: Vec<(usize, i32, i32)> = bursts
                    .iter()
                    .filter(|bu| bu.drafting && j < bu.k)
                    .map(|bu| {
                        let a = sched.slots[bu.idx].as_ref().expect("burst slot occupied");
                        let tok = if j == 0 { a.last_token } else { bu.drafts[j - 1] };
                        (bu.idx, tok, (bu.start_pos + j) as i32)
                    })
                    .collect();
                if inputs.is_empty() {
                    break;
                }
                let (out, _m) = self.batched_call(sched, true, &inputs)?;
                let vocab = self.runtime.manifest.model.vocab;
                for bu in bursts.iter_mut().filter(|bu| bu.drafting && j < bu.k) {
                    let a = sched.slots[bu.idx].as_mut().expect("burst slot occupied");
                    // Optimistic append of the drafted KV row — inside the
                    // charged envelope, and never scored, so rollback
                    // restores the H2O accumulators untouched.
                    let pos = (bu.start_pos + j) as u32;
                    for layer in 0..self.n_layer {
                        let base = (layer * self.batch + bu.idx) * self.row_elems;
                        a.cache.append(
                            layer,
                            &out.new_k.data[base..base + self.row_elems],
                            &out.new_v.data[base..base + self.row_elems],
                            pos,
                        )?;
                    }
                    // Greedy proposal — deliberately rng-free so the verify
                    // micro-steps consume the sampling rng in exactly the
                    // non-speculative order.
                    let tok = argmax(&out.logits.data[bu.idx * vocab..(bu.idx + 1) * vocab]);
                    bu.drafts.push(tok);
                    if tok == tokenizer::EOS {
                        bu.drafting = false; // nothing decodes past EOS
                    }
                }
            }
            Ok(())
        })();

        // --- rollback: drop every drafted row, return whole pages ---------
        for bu in &bursts {
            let a = sched.slots[bu.idx].as_mut().expect("burst slot occupied");
            a.cache.truncate(bu.start_pos);
            let mut lens = Vec::with_capacity(self.n_layer);
            for layer in 0..self.n_layer {
                lens.push(a.cache.layer_len(layer));
            }
            // Engine tables are never shared, so shrink cannot COW (and
            // therefore cannot fail).
            let _ = a.table.shrink(&lens);
        }
        // With the caches rolled back to their step-boundary state, a draft
        // fault can now propagate safely: containment sees exactly the
        // snapshot a resume continues from token-identically.
        draft_res?;

        // --- verify: target micro-steps, batched across sequences ---------
        // Micro-step v checks drafts[v]; the step after the last draft is
        // the bonus token the target always commits, so a burst commits
        // between 1 and k+1 tokens. Every commit is `commit_token` — the
        // non-speculative path — run from the rolled-back cache state.
        let t_verify = self.recorder.level().full().then(Instant::now);
        for v in 0..=draft_k {
            // Honor mid-burst cancellation between micro-steps: the
            // sequence keeps its committed prefix, its unverified drafts
            // count as rollback, and the next lifecycle phase retires it
            // (rollback never emits events).
            for bu in bursts.iter_mut() {
                if !bu.verifying {
                    continue;
                }
                match sched.slots[bu.idx].as_ref() {
                    Some(a) if a.req.cancel.as_ref().is_some_and(|c| c.is_cancelled()) => {
                        bu.verifying = false;
                    }
                    Some(_) => {}
                    None => bu.verifying = false, // Oom-finished earlier
                }
            }
            let inputs: Vec<(usize, i32, i32)> = bursts
                .iter()
                .filter(|bu| bu.verifying && v <= bu.drafts.len())
                .map(|bu| {
                    let a = sched.slots[bu.idx].as_ref().expect("burst slot occupied");
                    (bu.idx, a.last_token, a.next_pos as i32)
                })
                .collect();
            if inputs.is_empty() {
                break;
            }
            let (out, m) = self.batched_call(sched, false, &inputs)?;
            for bu in bursts.iter_mut() {
                if !(bu.verifying && v <= bu.drafts.len()) {
                    continue;
                }
                let idx = bu.idx;
                // Charge the verify append. This cannot fail — the burst's
                // peak was charged up-front and rollback freed more than
                // verify re-grows — but handle it defensively.
                let (old_lens, new_lens) = {
                    let a = sched.slots[idx].as_ref().expect("burst slot occupied");
                    let mut old = Vec::with_capacity(self.n_layer);
                    let mut new = Vec::with_capacity(self.n_layer);
                    for layer in 0..self.n_layer {
                        let len = a.cache.layer_len(layer);
                        old.push(len);
                        new.push(len + 1);
                    }
                    (old, new)
                };
                let grew = sched.slots[idx]
                    .as_mut()
                    .expect("burst slot occupied")
                    .table
                    .grow(&old_lens, &new_lens)
                    .is_ok();
                if !grew {
                    let a = sched.slots[idx].take().expect("burst slot occupied");
                    sched.metrics.oom_failures += 1;
                    outputs.push(Self::finish(a, FinishReason::Oom));
                    bu.verifying = false;
                    continue;
                }
                {
                    let a = sched.slots[idx].as_mut().expect("burst slot occupied");
                    a.peak_bytes = a.peak_bytes.max(a.table.bytes());
                }
                let tok = self.commit_token(sched, idx, &out, m)?;
                bu.committed += 1;
                let done = {
                    let a = sched.slots[idx].as_ref().expect("burst slot occupied");
                    tok == tokenizer::EOS || a.generated.len() >= a.effective_max_new
                };
                if v < bu.drafts.len() && tok == bu.drafts[v] {
                    bu.accepted += 1;
                } else {
                    // First mismatch: the committed token is the target's
                    // correction; everything after it in the draft is dead.
                    bu.verifying = false;
                }
                if done || v == bu.drafts.len() {
                    bu.verifying = false;
                }
            }
        }

        if let Some(t) = t_verify {
            // Wall time of the verify loop: its inner gathers/decodes also
            // accumulate into Gather/Model, which the phase doc calls out.
            self.phase_acc.add(StepPhase::Verify, t.elapsed().as_secs_f64());
        }

        // --- burst end: per-token ITL + spec metrics ----------------------
        for bu in &bursts {
            self.note_burst_itl(sched, bu.idx, bu.committed);
            sched.metrics.spec_steps += 1;
            sched.metrics.spec_drafted += bu.drafts.len() as u64;
            sched.metrics.spec_accepted += bu.accepted as u64;
            sched.metrics.spec_rollback_tokens += (bu.drafts.len() - bu.accepted) as u64;
        }
        Ok(())
    }

    /// The non-speculative step: one batched decode over every occupied
    /// slot, then charge/commit oldest-first with OOM resolved by
    /// preempting the youngest running sequence.
    fn decode_step_plain(
        &mut self,
        sched: &mut Scheduler,
        outputs: &mut Vec<RequestOutput>,
    ) -> Result<()> {
        let inputs: Vec<(usize, i32, i32)> = Self::slot_order(sched)
            .into_iter()
            .map(|i| {
                let a = sched.slots[i].as_ref().expect("order lists occupied slots");
                (i, a.last_token, a.next_pos as i32)
            })
            .collect();
        if inputs.is_empty() {
            return Ok(());
        }
        let (out, m) = self.batched_call(sched, false, &inputs)?;

        // Charge and commit oldest-first; on OOM preempt the youngest other
        // sequence and retry (`charge_growth`). The new KV rows are appended
        // only *after* the grow is charged, so a sequence preempted mid-pass
        // still holds exactly its post-previous-step cache — the snapshot a
        // swap-in can continue from token-identically (the decode output is
        // a pure function of cache + token + position, so re-running this
        // step after resume reproduces it). A sequence fails with Oom only
        // when it cannot grow with the pool otherwise empty.
        for (idx, _, _) in inputs {
            if sched.slots[idx].is_none() {
                continue; // preempted by an older sequence in this pass
            }
            if !self.charge_growth(sched, outputs, idx, 1) {
                continue;
            }
            self.commit_token(sched, idx, &out, m)?;
            self.note_burst_itl(sched, idx, 1);
        }
        Ok(())
    }

    /// Free the slots of finished sequences so the next step can admit.
    fn retire_phase(&mut self, sched: &mut Scheduler, outputs: &mut Vec<RequestOutput>) {
        for slot in sched.slots.iter_mut() {
            let done = match slot {
                Some(a) => {
                    a.last_token == tokenizer::EOS || a.generated.len() >= a.effective_max_new
                }
                None => false,
            };
            if done {
                let a = slot.take().expect("checked occupied");
                let reason = if a.last_token == tokenizer::EOS {
                    FinishReason::Eos
                } else {
                    FinishReason::Length
                };
                self.meter.add_request();
                sched.metrics.completed += 1;
                outputs.push(Self::finish(a, reason));
            }
        }
        sched.refresh_gauges();
    }

    /// Contain a backend step error to the sequences that were in the
    /// failed batch. Each occupied slot either re-queues from its
    /// step-boundary snapshot (suspend when spill is enabled, else
    /// restart-from-scratch — both resume token-identically because decode
    /// is a pure function of cache + token + position) while it has retries
    /// left, or retires with a `WorkerError` terminal that keeps the
    /// partial generation. Dropping/migrating the slot releases its device
    /// pages (RAII), so pool accounting returns to baseline. The queue and
    /// the suspended set are untouched — the engine keeps serving.
    fn contain_step_error(
        &mut self,
        sched: &mut Scheduler,
        outputs: &mut Vec<RequestOutput>,
        e: &anyhow::Error,
    ) {
        eprintln!("decode step failed (contained): {e:#}");
        sched.metrics.worker_errors += 1;
        let mut exhausted = false;
        for idx in 0..sched.slots.len() {
            let Some(mut a) = sched.slots[idx].take() else { continue };
            let retries = *a.req.retries_left.get_or_insert(self.cfg.max_retries);
            if retries > 0 {
                a.req.retries_left = Some(retries - 1);
                sched.metrics.requests_retried += 1;
                self.recorder.record(a.req.id, SpanKind::Retry, a.cache.bytes() as u64);
                self.suspend_or_requeue(sched, a);
            } else {
                exhausted = true;
                sched.metrics.requests_failed += 1;
                outputs.push(Self::finish(a, FinishReason::WorkerError));
            }
        }
        // Crash-context dump: the retained span history at the moment of the
        // fault, under the most severe reason this containment pass hit.
        if self.recorder.level().spans() {
            let _ = self.recorder.dump(if exhausted { "retry_exhausted" } else { "worker_error" });
        }
        sched.refresh_gauges();
    }

    /// Fail every in-flight, suspended, and queued request (runtime fault
    /// path — not a memory condition, so the reason is `Failed`, not `Oom`).
    fn fail_in_place(sched: &mut Scheduler, n_layer: usize, outputs: &mut Vec<RequestOutput>) {
        for slot in sched.slots.iter_mut() {
            if let Some(a) = slot.take() {
                sched.metrics.requests_failed += 1;
                outputs.push(Self::finish(a, FinishReason::Failed));
            }
        }
        while let Some(s) = sched.pop_suspended() {
            sched.metrics.requests_failed += 1;
            outputs.push(Self::finish_suspended(s, FinishReason::Failed));
        }
        while let Some(q) = sched.pop_queue() {
            sched.metrics.requests_failed += 1;
            outputs.push(Self::immediate_output(&q, FinishReason::Failed, n_layer));
        }
        sched.refresh_gauges();
    }

    /// `fail_in_place` over the engine's own scheduler (drain's fault path).
    fn fail_all(&mut self) -> Vec<RequestOutput> {
        let mut outputs = Vec::new();
        let mut sched = std::mem::take(&mut self.sched);
        Self::fail_in_place(&mut sched, self.n_layer, &mut outputs);
        self.sched = sched;
        for out in &outputs {
            self.recorder.record(out.id, SpanKind::Retire, out.peak_kv_bytes as u64);
        }
        outputs
    }

    fn finish(a: Active, reason: FinishReason) -> RequestOutput {
        let mut timing = a.timing;
        timing.total_s = a.t_submit.elapsed().as_secs_f64();
        let mut generated = a.generated;
        // Keep the raw stream on normal finishes (cancel/deadline included);
        // scorers decide about EOS.
        if matches!(reason, FinishReason::Oom | FinishReason::Failed) {
            generated.clear();
        }
        let out = RequestOutput {
            id: a.req.id,
            generated,
            finish: reason,
            timing,
            plan: a.plan,
            peak_kv_bytes: a.peak_bytes,
            final_kv_tokens: a.cache.total_tokens(),
        };
        lifecycle::emit_terminal(&a.req.events, &out);
        out
    }

    /// Output for a sequence that ends while suspended (fault path, cancel,
    /// or deadline): its snapshot carries the timing and plan to report.
    /// Cancel/deadline keep the partial generation; faults drop it (same
    /// contract as `finish`).
    fn finish_suspended(s: Suspended, reason: FinishReason) -> RequestOutput {
        let mut timing = s.snapshot.timing;
        timing.suspended_s += s.t_suspend.elapsed().as_secs_f64();
        timing.total_s = s.t_submit.elapsed().as_secs_f64();
        let generated = if matches!(reason, FinishReason::Oom | FinishReason::Failed) {
            vec![]
        } else {
            s.snapshot.generated
        };
        let out = RequestOutput {
            id: s.req.id,
            generated,
            finish: reason,
            timing,
            plan: s.snapshot.plan,
            peak_kv_bytes: s.snapshot.peak_bytes,
            final_kv_tokens: s.snapshot.cache.total_tokens(),
        };
        lifecycle::emit_terminal(&s.req.events, &out);
        out
    }

    /// Output for a request that never reached a decode slot.
    fn immediate_output(q: &Queued, finish: FinishReason, n_layer: usize) -> RequestOutput {
        let total = q.t_submit.elapsed().as_secs_f64();
        let out = RequestOutput {
            id: q.req.id,
            generated: vec![],
            finish,
            timing: RequestTiming { queue_s: total, total_s: total, ..Default::default() },
            plan: BudgetPlan::uniform(n_layer, 0),
            peak_kv_bytes: 0,
            final_kv_tokens: 0,
        };
        lifecycle::emit_terminal(&q.req.events, &out);
        out
    }
}
