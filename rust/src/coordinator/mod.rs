//! Layer 3 — the serving coordinator.
//!
//! Components, outermost in:
//!
//! * **server** — JSON-lines TCP front-end; pipelines every request on a
//!   connection into the router without waiting for earlier responses.
//! * **router** — spreads requests across engine workers (least-loaded or
//!   round-robin); each worker drives its engine one decode step at a time,
//!   so requests arriving mid-flight join the running batch.
//! * **engine** — prefill, SqueezeAttention budget allocation, per-layer
//!   eviction, and the batched decode hot path.
//! * **scheduler** — the continuous-batching state machine the engine
//!   steps:
//!
//! ```text
//!             submit (queue_depth backpressure)
//!                │
//!                v            admission control
//!   ┌─────────► queue ──────(KvPool headroom + ─────► running batch
//!   │                         BudgetPlan growth      ^ │  one decode
//!   │ requeue (host tier             prediction)     │ │  step at a time
//!   │ full/disabled:                        swap-in  │ │ swap-out on
//!   │ restart-from-scratch)      (device reserve →   │ │ pool OOM
//!   │                             restore snapshot,  │ v (youngest;
//!   └─────────────── suspended ─────── no prefill) ──┘ │  device→host)
//!                    (host tier) ◄──────────────────────┤
//!                                                       v
//!                                                retire on EOS/length
//!                                                       │
//!                                                       v
//!                                                RequestOutput
//! ```
//!
//! A sequence only fails with `FinishReason::Oom` when it cannot fit in the
//! device KV pool even with every other sequence preempted; otherwise OOM
//! pressure is resolved by preempting the youngest running sequence. With
//! `ServeConfig::host_spill_bytes > 0` the preempted sequence is
//! *suspended*: its squeezed per-layer cache (plus budget plan, H2O
//! accumulators, and decode position) migrates to the host-spill tier and
//! later swaps back in to continue decoding token-identically — no
//! re-prefill, no discarded output. With the host tier disabled (the
//! default), preemption degrades to restart-from-scratch requeueing.
//! `Engine::generate_batch` remains as a closed-batch compatibility wrapper
//! that drains the scheduler.

pub mod engine;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use engine::{Engine, EngineRunStats};
pub use request::{BudgetSpec, FinishReason, Request, RequestOutput, RequestTiming};
pub use router::{RoutePolicy, Router};
pub use scheduler::Scheduler;
