//! Layer 3 — the serving coordinator: engine (continuous batching +
//! SqueezeAttention budgets + eviction), router (multi-worker), TCP server,
//! and the request/response types.

pub mod engine;
pub mod request;
pub mod router;
pub mod server;

pub use engine::{Engine, EngineRunStats};
pub use request::{BudgetSpec, FinishReason, Request, RequestOutput, RequestTiming};
pub use router::{RoutePolicy, Router};
