//! Layer 3 — the serving coordinator.
//!
//! Components, outermost in:
//!
//! * **server** — JSON-lines TCP front-end; pipelines every request on a
//!   connection into the router without waiting for earlier responses.
//!   `"stream": true` requests get one `{"id", "token", "pos"}` line per
//!   decoded token ahead of the summary line, and a client disconnect
//!   cancels every request still in flight on that connection.
//! * **router** — spreads requests across engine workers (least-loaded or
//!   round-robin); each worker drives its engine one decode step at a time,
//!   so requests arriving mid-flight join the running batch.
//!   `submit_stream` attaches a lifecycle handle and forwards the
//!   per-request event stream across the worker boundary instead of
//!   waiting on completed outputs. Admission control sheds work with
//!   `RouteError::Overloaded` (+ Retry-After hint) before it consumes
//!   worker resources when a configured queue-depth or queue-latency bound
//!   is exceeded.
//! * **supervisor** — worker health and recovery. Each worker heartbeats
//!   once per loop iteration into shared state; a supervisor thread demotes
//!   stale workers to Draining, and a liveness guard marks a dead (panicked)
//!   worker thread Dead, triggering the death protocol: in-flight requests
//!   get synthesized `WorkerError` terminals (no caller or subscriber ever
//!   hangs), queued-but-unstarted jobs are re-routed to a live worker, and
//!   the dead worker is respawned with exponential backoff, bounded by
//!   `ServeConfig::max_worker_restarts`.
//! * **lifecycle** — per-request event channels (`RequestEvent`), the
//!   cooperative `CancelToken`, deadlines, and the `RequestHandle` callers
//!   observe and cancel through.
//! * **engine** — prefill, SqueezeAttention budget allocation, per-layer
//!   eviction, and the batched decode hot path. KV bytes are owned through
//!   per-sequence page tables over the paged pool
//!   (`kvcache::{PageTable, PagedKvPool}`): admission and per-step growth
//!   allocate whole fixed-size pages (`--kv-page-bytes`), eviction returns
//!   whole pages, and suspend/resume is a page-table retag that moves only
//!   private (refcount-1) pages between tiers. With `--spec-k N` the decode
//!   step runs a speculative *draft → verify → rollback* burst: each burst
//!   first charges its worst-case `N + 1` rows per layer against the pool
//!   (preempting exactly as a plain step would), a draft model proposes
//!   `N` tokens on optimistic KV appends, `SequenceCache::truncate` removes
//!   the drafted rows, and batched one-token verify micro-steps commit the
//!   accepted prefix through the ordinary per-token path — evictions, H2O
//!   scores, lifecycle `Token` events and all. Rolled-back tokens are never
//!   observable: no event fires and no score accumulates for a draft row.
//! * **scheduler** — the continuous-batching state machine the engine
//!   steps:
//!
//! ```text
//!             submit (queue_depth backpressure)
//!                │
//!                v            admission control
//!   ┌─────────► queue ──────(KvPool headroom + ─────► running batch
//!   │                         BudgetPlan growth      ^ │  one decode
//!   │ requeue (host tier             prediction)     │ │  step at a time
//!   │ full/disabled:                        swap-in  │ │ swap-out on
//!   │ restart-from-scratch)      (device reserve →   │ │ pool OOM
//!   │                             restore snapshot,  │ v (youngest;
//!   └─────────────── suspended ─────── no prefill) ──┘ │  device→host)
//!                    (host tier) ◄──────────────────────┤
//!                         │                             v
//!                         │                      retire on EOS/length
//!                         │                             │
//!                         │  cancel / deadline          v
//!                         └──(any state; frees ──► RequestOutput
//!                             host bytes without    (Cancelled /
//!                             a swap-in)            DeadlineExceeded)
//! ```
//!
//! A sequence only fails with `FinishReason::Oom` when it cannot fit in the
//! device KV pool even with every other sequence preempted; otherwise OOM
//! pressure is resolved by preempting the youngest running sequence. With
//! `ServeConfig::host_spill_bytes > 0` the preempted sequence is
//! *suspended*: its squeezed per-layer cache (plus budget plan, H2O
//! accumulators, and decode position) migrates to the host-spill tier and
//! later swaps back in to continue decoding token-identically — no
//! re-prefill, no discarded output. Migration is page-granular: the
//! sequence's page table is re-tagged to the other tier, PCIe traffic is
//! charged as `page_bytes × pages_moved`, and pages shared with another
//! sequence stay put. With the host tier disabled (the default),
//! preemption degrades to restart-from-scratch requeueing.
//! `Engine::generate_batch` remains as a closed-batch compatibility wrapper
//! that drains the scheduler.
//!
//! The lifecycle subsystem threads through every layer: the engine
//! publishes `RequestEvent`s (Started / Token / Suspended / Resumed /
//! Done / Cancelled / Error) at each step boundary, honors `CancelToken`s
//! and deadlines there (`FinishReason::{Cancelled, DeadlineExceeded}`),
//! and the server streams tokens to clients as they decode.
//!
//! ## Failure domains
//!
//! Faults are contained at the smallest layer that can handle them, and
//! each layer's contract is the same: *exactly one terminal event per
//! request, pool bytes back to baseline after drain*.
//!
//! ```text
//!   fault                    contained by        request outcome
//!   ─────                    ────────────        ───────────────
//!   backend step error       engine              re-queued (bounded per-
//!   (injected via            (contain_step_      request retry budget,
//!    FaultConfig on sim://    error)             `max_retries`) or retired
//!    or a real PJRT error)                       with WorkerError
//!   worker thread death      supervisor          in-flight: synthesized
//!   (panic; chaos hook:      (death protocol,    WorkerError terminal;
//!    Router::kill_worker)     bounded respawn)   queued: re-routed
//!   router overload          admission control   shed with Overloaded +
//!   (queue depth/latency     (before a worker    retry_after_ms hint
//!    over configured bound)   is touched)
//! ```
//!
//! Because greedy decode output is a pure function of (cache, token, pos),
//! a retried or restarted request that later succeeds completes
//! token-identically to a fault-free run — the chaos suite pins this.
//!
//! ## Decode hot path: batch-resident scratch
//!
//! The engine owns one scratch `(K, V)` buffer pair per decode tier
//! `(B, M)` — the exact tensors handed to `Runtime::decode` — behind the
//! `residency` module. Slot contents are *resident*: they persist across
//! steps, each occupied slot remembers which sequence filled it, at which
//! `SequenceCache` generation, and how many rows per layer are valid, so
//! the steady-state gather appends only the row(s) the cache grew since
//! the previous step instead of re-copying the whole cache. Residency of a
//! slot is invalidated — one full refill of just that slot — by anything
//! destructive: eviction/compaction (`retain`), speculative rollback
//! (`truncate`), suspend/resume, preemption, slot reassignment, or a tier
//! capacity change (a different tier's buffer simply has no valid entry).
//! COW page privatization needs no invalidation: page tables are pure
//! accounting and never rewrite KV payload rows. The contract is enforced
//! by generation counters on `SequenceCache` (every mutating op bumps one;
//! destructive ops bump the dirty watermark), checked at gather time.
//! Scratch tiers idle for `Engine::SCRATCH_IDLE_STEPS` decode steps are
//! reclaimed; `scratch_retained_bytes`, `kv_bytes_copied`,
//! `gather_full_refills`, and `gather_incremental_appends` export through
//! `SchedulerMetrics`. `--no-resident-scratch` forces the always-refill
//! baseline (the parity and bench reference).
//!
//! ## Observability
//!
//! Telemetry rides the same shared-state paths the fault machinery built,
//! gated by `ServeConfig::trace_level`:
//!
//! * Each worker slot owns an `Arc<metrics::FlightRecorder>` — a bounded
//!   span ring the engine records request lifecycle transitions into
//!   (`Engine::set_recorder`). It lives on `WorkerShared`, not the engine,
//!   so it survives the worker thread: `handle_death` dumps the dead
//!   worker's span history as structured JSON, and the engine dumps on a
//!   contained `WorkerError` / spent retry budget (`contain_step_error`).
//! * The worker loop stamps phase-timing summaries (`Engine::phase_json`),
//!   the per-layer squeeze table (`Engine::squeeze_table_json`), and
//!   throughput windows (`Engine::throughput_json`) into its
//!   `WorkerSnapshot` after every step; the router aggregates them into
//!   `metrics_json` / `metrics_prom` and answers per-request span queries
//!   (`trace_json`) through each worker's ticket alias table.
//! * The server exposes it all as wire control lines: `{"metrics": true}`,
//!   `{"metrics_prom": true}` (Prometheus text 0.0.4), `{"trace": <id>}`,
//!   and `{"flight_dump": <worker>}` — see `server`'s module doc for the
//!   exact shapes.

pub mod engine;
pub mod lifecycle;
pub mod request;
pub(crate) mod residency;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod supervisor;

pub use engine::{Engine, EngineRunStats};
pub use lifecycle::{CancelToken, EventSink, RequestEvent, RequestHandle};
pub use request::{BudgetSpec, FinishReason, Request, RequestOutput, RequestTiming};
pub use router::{RoutePolicy, Router, WorkerSnapshot};
pub use scheduler::Scheduler;
pub use supervisor::{Health, ReplyHandle, RouteError};
