//! Request/response types flowing through the serving engine.

use std::sync::Arc;
use std::time::Duration;

use crate::model::Sampling;
use crate::squeeze::BudgetPlan;

use super::lifecycle::{CancelToken, EventSink};

/// How the per-layer initial budget `b_init` is specified (paper §4.1: "a
/// unified cache budget (like 4096 tokens or 20% of prompt length)").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BudgetSpec {
    /// Absolute tokens per layer.
    Tokens(usize),
    /// Fraction of the prompt length (clamped to >= 4 tokens).
    Fraction(f64),
    /// No limit (Full Cache).
    Unlimited,
}

impl BudgetSpec {
    /// Resolve to an absolute per-layer token budget for a given prompt.
    pub fn resolve(&self, prompt_len: usize, max_seq: usize) -> usize {
        match *self {
            BudgetSpec::Tokens(n) => n.max(4),
            BudgetSpec::Fraction(f) => ((prompt_len as f64 * f).round() as usize).max(4),
            BudgetSpec::Unlimited => max_seq,
        }
    }
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
    /// Wall-clock budget measured from submission; an expired request
    /// finishes with [`FinishReason::DeadlineExceeded`] at the next step
    /// boundary (queued, running, or suspended). `None` falls back to
    /// `ServeConfig::request_deadline_ms` (0 there = no deadline).
    pub deadline: Option<Duration>,
    /// Lifecycle event stream (see `coordinator::lifecycle`); `None` (the
    /// default) publishes nothing.
    pub events: Option<EventSink>,
    /// Cooperative cancellation flag, honored at step boundaries.
    pub cancel: Option<Arc<CancelToken>>,
    /// Remaining worker-fault retries. `None` (the default) resolves lazily
    /// to `ServeConfig::max_retries` the first time a backend step error
    /// hits this request; once it reaches 0 the next fault retires the
    /// request with [`FinishReason::WorkerError`].
    pub retries_left: Option<u32>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            sampling: Sampling::Greedy,
            deadline: None,
            events: None,
            cancel: None,
            retries_left: None,
        }
    }

    /// Set a per-request deadline (overrides the config default).
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// Why a request stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Model emitted EOS.
    Eos,
    /// Hit max_new_tokens (or the capacity clamp).
    Length,
    /// KV pool exhausted (the paper's "OOM" table cells).
    Oom,
    /// Rejected before prefill (queue backpressure).
    Rejected,
    /// Runtime fault (decode/backend error) — not a memory condition.
    Failed,
    /// Cancelled via its `CancelToken` (client disconnect or an explicit
    /// `RequestHandle::cancel`); the partial generation is preserved.
    Cancelled,
    /// A worker fault (backend step error or worker-thread death) retired
    /// the request after its retry budget ran out; the partial generation
    /// is preserved — a retried request that later *succeeds* never carries
    /// this reason.
    WorkerError,
    /// Exceeded its wall-clock deadline at a step boundary; the partial
    /// generation is preserved.
    DeadlineExceeded,
}

/// Timing breakdown of one request.
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestTiming {
    /// Queue wait before prefill started (s).
    pub queue_s: f64,
    /// Total time spent suspended (swapped out to the host tier) after
    /// preemption, accumulated across swap cycles (s). Together with
    /// `queue_s` this is the full not-decoding wait of a request.
    pub suspended_s: f64,
    /// Prefill execution (s).
    pub prefill_s: f64,
    /// Squeeze overhead: cosine-stat reduction + kmeans + allocation (s).
    pub squeeze_s: f64,
    /// First token latency from admission (s).
    pub first_token_s: f64,
    /// Total latency from admission (s).
    pub total_s: f64,
}

/// The engine's answer to a request.
#[derive(Debug, Clone)]
pub struct RequestOutput {
    pub id: u64,
    pub generated: Vec<i32>,
    pub finish: FinishReason,
    pub timing: RequestTiming,
    /// The layer-budget plan that served this request.
    pub plan: BudgetPlan,
    /// Peak KV bytes held by this sequence.
    pub peak_kv_bytes: usize,
    /// Total cached tokens (sum over layers) at end of generation.
    pub final_kv_tokens: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_spec_resolution() {
        assert_eq!(BudgetSpec::Tokens(64).resolve(100, 640), 64);
        assert_eq!(BudgetSpec::Fraction(0.2).resolve(100, 640), 20);
        assert_eq!(BudgetSpec::Fraction(0.001).resolve(100, 640), 4); // floor
        assert_eq!(BudgetSpec::Unlimited.resolve(100, 640), 640);
    }
}
