//! Request-lifecycle subsystem: per-request event streams, cooperative
//! cancellation, and deadlines.
//!
//! The engine is a synchronous step machine; this module is the seam that
//! turns it into an *interactive* serving system. Each request may carry
//!
//! * an [`EventSink`] — a per-request channel the engine publishes a
//!   [`RequestEvent`] into at every lifecycle transition (admission, each
//!   decoded token, suspend/resume, terminal), and
//! * a shared [`CancelToken`] — a cooperative flag checked at every step
//!   boundary, so a disconnected or abandoned request stops decoding and
//!   releases its device/host KV reservations mid-flight instead of burning
//!   pool bytes until `max_new_tokens`.
//!
//! [`RequestHandle::attach`] wires both into a [`Request`] and returns the
//! caller's end: the event receiver plus `cancel()`. The router attaches
//! handles on `Router::submit_stream` and forwards events across the worker
//! thread boundary (the sink rewrites engine-local ticket ids back to the
//! caller's public id); the TCP server turns `Token` events into
//! `{"id", "token", "pos"}` wire lines and cancels every in-flight handle
//! when the client disconnects.
//!
//! Event-order contract per request: `Started` first, then `Token` events in
//! generation order (`pos` 0, 1, 2, …), interleaved with `Suspended` /
//! `Resumed` pairs while preempted, ending in exactly one terminal event
//! (`Done`, `Cancelled`, or `Error`) carrying the final [`RequestOutput`].
//! A restart-from-scratch preemption (host tier full or disabled) re-runs
//! admission, so `Started` and `Token` events repeat from `pos` 0 —
//! consumers must treat `pos` as authoritative, not append blindly.
//! Suspend/resume never re-emits: the partial output is preserved.
//!
//! Worker faults keep the contract intact: a backend step error either
//! re-queues the affected sequences (retry — a later `Resumed` or restarted
//! `Started` follows, still exactly one terminal event at the end) or, once
//! the per-request retry budget is spent, retires them with an `Error`
//! terminal whose output carries `FinishReason::WorkerError`. A worker
//! *thread* death is handled one level up: the router's supervisor
//! synthesizes the `WorkerError` terminal for every request that was in
//! flight on the dead worker, so no subscriber ever hangs waiting for a
//! stream the engine can no longer finish.
//!
//! Speculative decoding (`--spec-k`) does not change the contract, only the
//! cadence: a verify burst emits one `Token` event per *committed* token,
//! so several consecutive-`pos` events can arrive from a single engine
//! step. Draft proposals that are rolled back never emit — an event fires
//! only from the ordinary commit path, after the target model verifies the
//! token.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use super::request::{FinishReason, Request, RequestOutput};

/// One lifecycle transition of a request, published into its [`EventSink`]
/// at the step boundary where the engine decides it.
#[derive(Debug, Clone)]
pub enum RequestEvent {
    /// The request was admitted into a decode slot (prefill + squeeze done).
    Started { id: u64, prompt_tokens: usize },
    /// One decoded token. `pos` is the 0-based index in the generated
    /// stream; the `pos = 0` token is sampled from the prefill logits at
    /// admission. Authoritative on restart: a re-admitted request emits
    /// again from `pos = 0`.
    Token { id: u64, token: i32, pos: usize },
    /// The sequence was swapped out to the host tier (preemption or a
    /// prefill parked at admission). Its partial output is preserved.
    Suspended { id: u64 },
    /// The sequence swapped back into a decode slot and continues from
    /// where it stopped.
    Resumed { id: u64 },
    /// Terminal: finished normally (EOS, length, or deadline — the output's
    /// `finish` field distinguishes them).
    Done(Box<RequestOutput>),
    /// Terminal: cancelled via [`CancelToken`] (client disconnect or an
    /// explicit `cancel()`); the output keeps the partial generation.
    Cancelled(Box<RequestOutput>),
    /// Terminal: the request failed (rejected, OOM, or a runtime fault).
    Error(Box<RequestOutput>),
}

impl RequestEvent {
    /// The request id this event belongs to.
    pub fn id(&self) -> u64 {
        match self {
            RequestEvent::Started { id, .. }
            | RequestEvent::Token { id, .. }
            | RequestEvent::Suspended { id }
            | RequestEvent::Resumed { id } => *id,
            RequestEvent::Done(o) | RequestEvent::Cancelled(o) | RequestEvent::Error(o) => o.id,
        }
    }

    fn set_id(&mut self, new_id: u64) {
        match self {
            RequestEvent::Started { id, .. }
            | RequestEvent::Token { id, .. }
            | RequestEvent::Suspended { id }
            | RequestEvent::Resumed { id } => *id = new_id,
            RequestEvent::Done(o) | RequestEvent::Cancelled(o) | RequestEvent::Error(o) => {
                o.id = new_id
            }
        }
    }

    /// Whether this event ends the request's stream.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            RequestEvent::Done(_) | RequestEvent::Cancelled(_) | RequestEvent::Error(_)
        )
    }

    /// The final output, if this is a terminal event.
    pub fn into_output(self) -> Option<RequestOutput> {
        match self {
            RequestEvent::Done(o) | RequestEvent::Cancelled(o) | RequestEvent::Error(o) => {
                Some(*o)
            }
            _ => None,
        }
    }
}

/// Cooperative cancellation flag shared between a [`RequestHandle`] and the
/// request inside the engine. Setting it is instant and thread-safe; the
/// engine honors it at the next step boundary, releasing the sequence's
/// device or host reservation (a cancel while swapped out frees the host
/// tier directly — no swap-in).
#[derive(Debug, Default)]
pub struct CancelToken {
    cancelled: AtomicBool,
}

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Acquire)
    }
}

/// The engine-side end of a request's event channel. Sends never block or
/// fail the engine: a consumer that hung up (dropped its receiver) simply
/// stops observing. The sink rewrites every event's id to `public_id`
/// before sending — the router rewrites request ids to worker-local tickets
/// in flight, and subscribers must see the id they submitted with.
#[derive(Clone)]
pub struct EventSink {
    tx: mpsc::Sender<RequestEvent>,
    public_id: u64,
}

impl std::fmt::Debug for EventSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "EventSink(public_id={})", self.public_id)
    }
}

impl EventSink {
    pub fn new(tx: mpsc::Sender<RequestEvent>, public_id: u64) -> Self {
        Self { tx, public_id }
    }

    pub fn send(&self, mut event: RequestEvent) {
        event.set_id(self.public_id);
        let _ = self.tx.send(event);
    }
}

/// Publish `event` if the request carries a sink (no-op otherwise, so the
/// closed-batch and bench paths pay nothing).
pub(crate) fn emit(sink: &Option<EventSink>, event: RequestEvent) {
    if let Some(s) = sink {
        s.send(event);
    }
}

/// Publish the terminal event matching an output's finish reason.
pub(crate) fn emit_terminal(sink: &Option<EventSink>, out: &RequestOutput) {
    if let Some(s) = sink {
        let boxed = Box::new(out.clone());
        s.send(match out.finish {
            FinishReason::Cancelled => RequestEvent::Cancelled(boxed),
            FinishReason::Oom
            | FinishReason::Rejected
            | FinishReason::Failed
            | FinishReason::WorkerError => RequestEvent::Error(boxed),
            FinishReason::Eos | FinishReason::Length | FinishReason::DeadlineExceeded => {
                RequestEvent::Done(boxed)
            }
        });
    }
}

/// The caller's end of a request's lifecycle: the event stream plus the
/// cancel control. Obtained from [`RequestHandle::attach`] (direct engine
/// use) or `Router::submit_stream`. Dropping the handle detaches the
/// observer but does NOT cancel the request — call [`RequestHandle::cancel`]
/// for that.
pub struct RequestHandle {
    id: u64,
    events: mpsc::Receiver<RequestEvent>,
    cancel: Arc<CancelToken>,
}

impl RequestHandle {
    /// Wire a fresh event channel and cancel token into `req` and return
    /// the observer handle. The handle reports events under the request's
    /// id *at attach time* (the public id), even if the id is rewritten in
    /// flight.
    pub fn attach(req: &mut Request) -> RequestHandle {
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(CancelToken::new());
        req.events = Some(EventSink::new(tx, req.id));
        req.cancel = Some(cancel.clone());
        RequestHandle { id: req.id, events: rx, cancel }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cancellation; the engine honors it at its next step boundary
    /// and answers with a `Cancelled` terminal event.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// The raw event receiver (for `try_iter`/`iter` composition).
    pub fn events(&self) -> &mpsc::Receiver<RequestEvent> {
        &self.events
    }

    /// Block for the next event. `Err` means the stream closed without a
    /// terminal event (engine dropped — a bug or process teardown).
    pub fn recv(&self) -> Result<RequestEvent, mpsc::RecvError> {
        self.events.recv()
    }

    /// Block until the terminal event and return its output, discarding
    /// intermediate events. `None` if the stream closed without one.
    pub fn wait(&self) -> Option<RequestOutput> {
        while let Ok(ev) = self.events.recv() {
            if ev.is_terminal() {
                return ev.into_output();
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::RequestTiming;
    use crate::squeeze::BudgetPlan;

    fn out(id: u64, finish: FinishReason) -> RequestOutput {
        RequestOutput {
            id,
            generated: vec![1, 2],
            finish,
            timing: RequestTiming::default(),
            plan: BudgetPlan::uniform(1, 4),
            peak_kv_bytes: 0,
            final_kv_tokens: 0,
        }
    }

    #[test]
    fn attach_wires_sink_and_token() {
        let mut req = Request::new(7, vec![1, 2, 3], 4);
        let handle = RequestHandle::attach(&mut req);
        assert_eq!(handle.id(), 7);
        assert!(!handle.is_cancelled());
        handle.cancel();
        assert!(req.cancel.as_ref().unwrap().is_cancelled());

        emit(&req.events, RequestEvent::Started { id: 999, prompt_tokens: 3 });
        let ev = handle.recv().unwrap();
        assert_eq!(ev.id(), 7, "sink must rewrite to the public id");
        assert!(!ev.is_terminal());
    }

    #[test]
    fn terminal_event_matches_finish_reason() {
        let mut req = Request::new(3, vec![1], 4);
        let handle = RequestHandle::attach(&mut req);
        emit_terminal(&req.events, &out(3, FinishReason::Eos));
        assert!(matches!(handle.recv().unwrap(), RequestEvent::Done(_)));
        emit_terminal(&req.events, &out(3, FinishReason::DeadlineExceeded));
        assert!(matches!(handle.recv().unwrap(), RequestEvent::Done(_)));
        emit_terminal(&req.events, &out(3, FinishReason::Cancelled));
        assert!(matches!(handle.recv().unwrap(), RequestEvent::Cancelled(_)));
        emit_terminal(&req.events, &out(3, FinishReason::Oom));
        let ev = handle.recv().unwrap();
        assert!(matches!(ev, RequestEvent::Error(_)));
        assert!(ev.is_terminal());
        assert_eq!(ev.into_output().unwrap().finish, FinishReason::Oom);
    }

    #[test]
    fn wait_skips_to_terminal() {
        let mut req = Request::new(1, vec![1], 4);
        let handle = RequestHandle::attach(&mut req);
        emit(&req.events, RequestEvent::Token { id: 1, token: 5, pos: 0 });
        emit(&req.events, RequestEvent::Suspended { id: 1 });
        emit(&req.events, RequestEvent::Resumed { id: 1 });
        emit_terminal(&req.events, &out(1, FinishReason::Length));
        let final_out = handle.wait().unwrap();
        assert_eq!(final_out.finish, FinishReason::Length);
    }

    #[test]
    fn dropped_receiver_never_errors_sender() {
        let mut req = Request::new(1, vec![1], 4);
        let handle = RequestHandle::attach(&mut req);
        drop(handle);
        emit(&req.events, RequestEvent::Token { id: 1, token: 5, pos: 0 });
        emit_terminal(&req.events, &out(1, FinishReason::Eos)); // must not panic
    }
}
