//! KV-cache substrate: per-sequence 2-D caches (layer × token), the global
//! byte pool (the HBM stand-in), and the sequence-wise eviction policies.

pub mod cache;
pub mod eviction;
pub mod pool;

pub use cache::{LayerCache, SequenceCache, SlotMeta};
pub use eviction::{make_policy, EvictionPolicy, FullCache, H2o, SlidingWindow, StreamingLlm};
pub use pool::{KvPool, OutOfMemory, Reservation};
