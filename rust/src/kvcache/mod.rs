//! KV-cache substrate: per-sequence 2-D caches (layer × token), the global
//! two-tier byte pool (device HBM stand-in + host spill for suspended
//! sequences), the page-granular allocator that quantizes both tiers into
//! ref-counted pages (copy-on-write prefix sharing, page-table migration),
//! and the sequence-wise eviction policies.

pub mod cache;
pub mod eviction;
pub mod paging;
pub mod pool;

pub use cache::{CacheSnapshot, LayerCache, SequenceCache, SlotMeta};
pub use eviction::{make_policy, EvictionPolicy, FullCache, H2o, SlidingWindow, StreamingLlm};
pub use paging::{PageId, PageTable, PagedKvPool};
pub use pool::{KvPool, OutOfMemory, Reservation, Tier};
