//! Per-sequence, per-layer KV cache owned by the coordinator.
//!
//! The cache is the 2-D object the paper manages: one `LayerCache` per
//! attention layer, each holding a *different* number of tokens once
//! SqueezeAttention has reallocated budgets. Rows are stored compacted (valid
//! prefix), so eviction = select keep-set on metadata + in-place compaction,
//! and the decode step only needs a `cache_len` per layer.

use anyhow::{anyhow, Result};

use crate::runtime::Tensor;

/// Metadata for one cached token slot in one layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlotMeta {
    /// Absolute position of the token in the sequence (RoPE was applied with
    /// this position; it never changes after eviction).
    pub position: u32,
    /// Accumulated attention mass received during decode (the H2O signal).
    pub score: f64,
}

/// KV rows + metadata for one layer of one sequence.
#[derive(Debug, Clone, Default)]
pub struct LayerCache {
    /// `len * row_elems` f32, row-major.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub meta: Vec<SlotMeta>,
}

impl LayerCache {
    pub fn len(&self) -> usize {
        self.meta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.meta.is_empty()
    }
}

/// The full KV cache of one sequence.
///
/// Mutations are tracked by two monotonic counters that make batch-scratch
/// residency (`coordinator::residency`) enforceable rather than a
/// convention: `generation` is bumped by *every* mutating op, and
/// `dirty_gen` is set to the new generation by every op that invalidates
/// previously copied rows (compaction, rollback, restore-from-snapshot).
/// A consumer that copied rows at generation `g` may keep them as long as
/// `dirty_generation() <= g` — appends only ever add rows past the copied
/// prefix.
#[derive(Debug, Clone)]
pub struct SequenceCache {
    pub layers: Vec<LayerCache>,
    /// Elements per KV row (= n_head * head_dim).
    pub row_elems: usize,
    /// Bumped by every mutating op (append, add_scores, retain, truncate).
    generation: u64,
    /// Generation of the last *destructive* mutation — one after which rows
    /// copied out earlier may no longer match the cache (retain/truncate
    /// that dropped rows, or restore from a snapshot).
    dirty_gen: u64,
}

impl SequenceCache {
    pub fn new(n_layer: usize, row_elems: usize) -> Self {
        Self { layers: vec![LayerCache::default(); n_layer], row_elems, generation: 0, dirty_gen: 0 }
    }

    /// Monotonic mutation counter (every mutating op bumps it).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Generation of the last destructive mutation. Rows copied out at
    /// generation `g` are still a valid prefix iff `dirty_generation() <= g`.
    pub fn dirty_generation(&self) -> u64 {
        self.dirty_gen
    }

    fn bump(&mut self) {
        self.generation += 1;
    }

    fn bump_dirty(&mut self) {
        self.generation += 1;
        self.dirty_gen = self.generation;
    }

    /// Build from prefill outputs `k`,`v` of shape `[n_layer, L, H, D]`,
    /// keeping the first `prompt_len` rows of each layer.
    pub fn from_prefill(k: &Tensor, v: &Tensor, prompt_len: usize) -> Result<Self> {
        if k.shape.len() != 4 || k.shape != v.shape {
            return Err(anyhow!("bad prefill cache shapes k={:?} v={:?}", k.shape, v.shape));
        }
        let (n_layer, l, h, d) = (k.shape[0], k.shape[1], k.shape[2], k.shape[3]);
        if prompt_len > l {
            return Err(anyhow!("prompt_len {prompt_len} > bucket {l}"));
        }
        let row = h * d;
        let mut cache = Self::new(n_layer, row);
        for layer in 0..n_layer {
            let lc = &mut cache.layers[layer];
            lc.k.reserve(prompt_len * row);
            lc.v.reserve(prompt_len * row);
            let base = layer * l * row;
            lc.k.extend_from_slice(&k.data[base..base + prompt_len * row]);
            lc.v.extend_from_slice(&v.data[base..base + prompt_len * row]);
            lc.meta.extend((0..prompt_len).map(|p| SlotMeta { position: p as u32, score: 0.0 }));
        }
        Ok(cache)
    }

    pub fn n_layer(&self) -> usize {
        self.layers.len()
    }

    pub fn layer_len(&self, layer: usize) -> usize {
        self.layers[layer].len()
    }

    /// Total cached tokens across layers (the paper's 2-D cache size).
    pub fn total_tokens(&self) -> usize {
        self.layers.iter().map(|l| l.len()).sum()
    }

    /// Bytes one cached token occupies in one layer for the given row width
    /// (K+V f32 payload). The single source of truth for pool charging —
    /// admission estimators must use this too.
    pub fn token_bytes(row_elems: usize) -> usize {
        row_elems * 2 * 4
    }

    /// Cache bytes (K+V f32 payload only; metadata is host bookkeeping).
    pub fn bytes(&self) -> usize {
        self.total_tokens() * Self::token_bytes(self.row_elems)
    }

    /// Largest per-layer length (drives decode-tier selection).
    pub fn max_layer_len(&self) -> usize {
        self.layers.iter().map(|l| l.len()).max().unwrap_or(0)
    }

    /// Append one token's K/V row to `layer`.
    pub fn append(&mut self, layer: usize, k_row: &[f32], v_row: &[f32], position: u32) -> Result<()> {
        if k_row.len() != self.row_elems || v_row.len() != self.row_elems {
            return Err(anyhow!("row size {} != {}", k_row.len(), self.row_elems));
        }
        let lc = &mut self.layers[layer];
        lc.k.extend_from_slice(k_row);
        lc.v.extend_from_slice(v_row);
        lc.meta.push(SlotMeta { position, score: 0.0 });
        self.bump();
        Ok(())
    }

    /// Accumulate decode attention mass into slot scores of `layer`.
    /// `scores[i]` corresponds to slot `i`; extra entries (padding) are
    /// ignored, but a slice *shorter* than the slot count is a hard error —
    /// silently leaving newer slots unscored would skew H2O heavy-hitter
    /// ranking toward old tokens.
    pub fn add_scores(&mut self, layer: usize, scores: &[f32]) -> Result<()> {
        let lc = &mut self.layers[layer];
        if scores.len() < lc.meta.len() {
            return Err(anyhow!(
                "layer {layer}: {} scores for {} slots — newer slots would go unscored",
                scores.len(),
                lc.meta.len()
            ));
        }
        for (slot, &s) in lc.meta.iter_mut().zip(scores.iter()) {
            slot.score += s as f64;
        }
        // Scores live in host-side metadata, not in the K/V payload rows, so
        // this bumps the generation but does NOT dirty copied-out rows.
        self.bump();
        Ok(())
    }

    /// Keep exactly the slots in `keep` (sorted ascending, in-range, unique)
    /// for `layer`, compacting payload + metadata. Returns the number of
    /// rows dropped; when rows were dropped the cache is marked dirty
    /// (copied-out prefixes are no longer trustworthy — compaction moves
    /// surviving rows).
    pub fn retain(&mut self, layer: usize, keep: &[usize]) -> Result<usize> {
        let lc = &mut self.layers[layer];
        let n = lc.len();
        let row = self.row_elems;
        let mut prev: Option<usize> = None;
        for &i in keep {
            if i >= n {
                return Err(anyhow!("keep index {i} >= len {n}"));
            }
            if let Some(p) = prev {
                if i <= p {
                    return Err(anyhow!("keep indices must be strictly ascending"));
                }
            }
            prev = Some(i);
        }
        let mut k = Vec::with_capacity(keep.len() * row);
        let mut v = Vec::with_capacity(keep.len() * row);
        let mut meta = Vec::with_capacity(keep.len());
        for &i in keep {
            k.extend_from_slice(&lc.k[i * row..(i + 1) * row]);
            v.extend_from_slice(&lc.v[i * row..(i + 1) * row]);
            meta.push(lc.meta[i]);
        }
        lc.k = k;
        lc.v = v;
        lc.meta = meta;
        let dropped = n - keep.len();
        if dropped > 0 {
            self.bump_dirty();
        } else {
            self.bump();
        }
        Ok(dropped)
    }

    /// Roll the sequence back to logical length `len`: drop every trailing
    /// slot whose absolute position is `>= len`, in every layer. This is the
    /// speculative-decode rollback primitive — drafted rows are always the
    /// contiguous tail of each layer (appended after the last committed
    /// token, never evicted mid-burst, never scored), so removing that tail
    /// restores the cache byte-exactly to its pre-draft state: surviving
    /// K/V payload, positions, *and* H2O score accumulators are untouched.
    /// Returns the number of rows dropped across all layers.
    pub fn truncate(&mut self, len: usize) -> usize {
        let cut = len as u32;
        let row = self.row_elems;
        let mut dropped = 0usize;
        for lc in &mut self.layers {
            let mut keep = lc.meta.len();
            while keep > 0 && lc.meta[keep - 1].position >= cut {
                keep -= 1;
            }
            dropped += lc.meta.len() - keep;
            lc.meta.truncate(keep);
            lc.k.truncate(keep * row);
            lc.v.truncate(keep * row);
        }
        // A rollback is a pure tail drop, but a consumer's copied prefix may
        // extend past the new length; treating it as destructive keeps the
        // residency contract simple (copied length never exceeds live
        // length on the incremental path).
        if dropped > 0 {
            self.bump_dirty();
        } else {
            self.bump();
        }
        dropped
    }

    /// Freeze this cache into a host-side snapshot for swap-out. The cache
    /// is captured as-is — post-eviction, so each layer holds at most its
    /// budget — which is what makes suspended sequences cheap: the bytes
    /// moved to host memory are exactly the squeezed working set. H2O score
    /// accumulators travel inside `SlotMeta`, so a restored sequence ranks
    /// heavy hitters identically to one that was never suspended.
    pub fn snapshot(self) -> CacheSnapshot {
        CacheSnapshot {
            layers: self.layers,
            row_elems: self.row_elems,
            generation: self.generation,
            dirty_gen: self.dirty_gen,
        }
    }

    /// Copy this sequence's cache into slot `b` of a padded decode batch
    /// buffer of shape `[n_layer, B, M, row_elems]` and fill `cache_lens`.
    /// Always a full refill of the slot; the incremental variant is
    /// [`SequenceCache::write_rows_into_batch`].
    pub fn write_into_batch(
        &self,
        k_buf: &mut Tensor,
        v_buf: &mut Tensor,
        lens: &mut [i32],
        b: usize,
    ) -> Result<()> {
        self.write_rows_into_batch(k_buf, v_buf, lens, b, &vec![0; self.n_layer()])?;
        Ok(())
    }

    /// Copy only rows `from[layer]..len(layer)` of each layer into slot `b`
    /// of a padded decode batch buffer of shape `[n_layer, B, M, row_elems]`
    /// — the hot-path primitive behind batch-resident scratch: a slot whose
    /// first `from[layer]` rows are already valid in the buffer pays only
    /// for the rows appended since. `cache_lens` is always refreshed for
    /// every layer. Returns the number of rows copied (summed over layers).
    pub fn write_rows_into_batch(
        &self,
        k_buf: &mut Tensor,
        v_buf: &mut Tensor,
        lens: &mut [i32],
        b: usize,
        from: &[usize],
    ) -> Result<usize> {
        let (n_layer, bsz, m) = (k_buf.shape[0], k_buf.shape[1], k_buf.shape[2]);
        let row = self.row_elems;
        let buf_row = k_buf.shape[3] * k_buf.shape.get(4).copied().unwrap_or(1);
        if buf_row != row {
            // A mis-shaped buffer would copy rows at wrong offsets and feed
            // the kernel scrambled KV — hard error, not a debug assert.
            return Err(anyhow!("batch buffer row width {buf_row} != cache row width {row}"));
        }
        if self.n_layer() != n_layer || b >= bsz {
            return Err(anyhow!("batch buffer mismatch"));
        }
        if from.len() != n_layer {
            return Err(anyhow!("from offsets {} != n_layer {n_layer}", from.len()));
        }
        let mut copied = 0usize;
        for layer in 0..n_layer {
            let lc = &self.layers[layer];
            if lc.len() >= m {
                return Err(anyhow!(
                    "layer {layer} has {} slots but tier capacity is {m} (needs len < M)",
                    lc.len()
                ));
            }
            let start = from[layer];
            if start > lc.len() {
                return Err(anyhow!(
                    "layer {layer}: resident prefix {start} exceeds cache len {} — \
                     residency contract breached",
                    lc.len()
                ));
            }
            let base = (layer * bsz + b) * m * row;
            k_buf.data[base + start * row..base + lc.k.len()]
                .copy_from_slice(&lc.k[start * row..]);
            v_buf.data[base + start * row..base + lc.v.len()]
                .copy_from_slice(&lc.v[start * row..]);
            lens[layer * bsz + b] = lc.len() as i32;
            copied += lc.len() - start;
        }
        Ok(copied)
    }
}

/// A suspended sequence's KV state: the exact per-layer rows + metadata the
/// cache held at swap-out. Byte-identical restoration is the contract that
/// makes suspend/resume token-identical to uninterrupted decoding.
#[derive(Debug, Clone)]
pub struct CacheSnapshot {
    layers: Vec<LayerCache>,
    row_elems: usize,
    generation: u64,
    dirty_gen: u64,
}

impl CacheSnapshot {
    /// Bytes this snapshot occupies (same accounting as the live cache, so
    /// host-tier reservations charge exactly what device-tier ones did).
    pub fn bytes(&self) -> usize {
        self.total_tokens() * SequenceCache::token_bytes(self.row_elems)
    }

    pub fn total_tokens(&self) -> usize {
        self.layers.iter().map(|l| l.len()).sum()
    }

    pub fn n_layer(&self) -> usize {
        self.layers.len()
    }

    /// Slots held by `layer` — page-indexed resume needs per-layer lengths
    /// to rebuild the page table and size the exact first-append headroom.
    pub fn layer_len(&self, layer: usize) -> usize {
        self.layers[layer].len()
    }

    pub fn row_elems(&self) -> usize {
        self.row_elems
    }

    /// Thaw back into a live cache for swap-in. Generations continue
    /// monotonically from where the snapshot froze them (never backward —
    /// a consumer holding a pre-suspend generation must not see it reused),
    /// and the restored cache is marked dirty: any rows copied out before
    /// the suspend must be refilled, because the scratch slot may have been
    /// reassigned while this sequence was parked.
    pub fn restore(self) -> SequenceCache {
        let mut c = SequenceCache {
            layers: self.layers,
            row_elems: self.row_elems,
            generation: self.generation,
            dirty_gen: self.dirty_gen,
        };
        c.bump_dirty();
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_prefill(n_layer: usize, l: usize, h: usize, d: usize) -> (Tensor, Tensor) {
        let n = n_layer * l * h * d;
        let k = Tensor::from_vec(&[n_layer, l, h, d], (0..n).map(|i| i as f32).collect()).unwrap();
        let v = Tensor::from_vec(&[n_layer, l, h, d], (0..n).map(|i| -(i as f32)).collect()).unwrap();
        (k, v)
    }

    #[test]
    fn from_prefill_truncates_to_prompt() {
        let (k, v) = mk_prefill(2, 8, 2, 4);
        let c = SequenceCache::from_prefill(&k, &v, 5).unwrap();
        assert_eq!(c.n_layer(), 2);
        assert_eq!(c.layer_len(0), 5);
        assert_eq!(c.total_tokens(), 10);
        // First row of layer 1 = elements at offset 1*8*8.
        assert_eq!(c.layers[1].k[0], 64.0);
        assert_eq!(c.layers[0].meta[3].position, 3);
    }

    #[test]
    fn append_and_scores() {
        let mut c = SequenceCache::new(1, 4);
        c.append(0, &[1.0; 4], &[2.0; 4], 0).unwrap();
        c.append(0, &[3.0; 4], &[4.0; 4], 1).unwrap();
        c.add_scores(0, &[0.25, 0.75, 99.0]).unwrap(); // padding entry ignored
        assert_eq!(c.layers[0].meta[0].score, 0.25);
        assert_eq!(c.layers[0].meta[1].score, 0.75);
        assert!(c.append(0, &[0.0; 3], &[0.0; 3], 2).is_err());
    }

    #[test]
    fn add_scores_rejects_short_slice() {
        // Regression: a short slice used to be silently zipped, leaving the
        // newest slots unscored and skewing H2O ranking. Now a hard error,
        // and no partial accumulation happens.
        let mut c = SequenceCache::new(1, 4);
        for i in 0..3 {
            c.append(0, &[0.0; 4], &[0.0; 4], i).unwrap();
        }
        assert!(c.add_scores(0, &[0.5, 0.5]).is_err());
        assert!(c.layers[0].meta.iter().all(|m| m.score == 0.0));
        // Exact-length and padded slices still work.
        c.add_scores(0, &[0.1, 0.2, 0.3]).unwrap();
        c.add_scores(0, &[0.1, 0.2, 0.3, 9.0]).unwrap();
        assert!((c.layers[0].meta[2].score - 0.6).abs() < 1e-9);
    }

    #[test]
    fn write_into_batch_rejects_wrong_row_width() {
        // Regression: the row-width check was a debug_assert, so release
        // builds copied rows at wrong offsets. Now a hard error.
        let (k, v) = mk_prefill(2, 4, 1, 2);
        let c = SequenceCache::from_prefill(&k, &v, 3).unwrap();
        let mut kb = Tensor::zeros(&[2, 2, 6, 1, 3]); // row width 3 != 2
        let mut vb = Tensor::zeros(&[2, 2, 6, 1, 3]);
        let mut lens = vec![0i32; 4];
        assert!(c.write_into_batch(&mut kb, &mut vb, &mut lens, 1).is_err());
    }

    #[test]
    fn retain_compacts() {
        let mut c = SequenceCache::new(1, 2);
        for i in 0..5 {
            c.append(0, &[i as f32; 2], &[10.0 + i as f32; 2], i).unwrap();
        }
        c.retain(0, &[0, 3, 4]).unwrap();
        assert_eq!(c.layer_len(0), 3);
        assert_eq!(c.layers[0].k, vec![0.0, 0.0, 3.0, 3.0, 4.0, 4.0]);
        assert_eq!(c.layers[0].meta[1].position, 3);
        // invalid keep sets
        assert!(c.retain(0, &[2, 1]).is_err());
        assert!(c.retain(0, &[9]).is_err());
    }

    #[test]
    fn write_into_batch_pads() {
        let (k, v) = mk_prefill(2, 4, 1, 2);
        let c = SequenceCache::from_prefill(&k, &v, 3).unwrap();
        let mut kb = Tensor::zeros(&[2, 2, 6, 1, 2]);
        let mut vb = Tensor::zeros(&[2, 2, 6, 1, 2]);
        let mut lens = vec![0i32; 4];
        c.write_into_batch(&mut kb, &mut vb, &mut lens, 1).unwrap();
        assert_eq!(lens, vec![0, 3, 0, 3]);
        // layer 0, slot b=1, first row == first prefill row of layer 0
        let base = (0 * 2 + 1) * 6 * 2;
        assert_eq!(&kb.data[base..base + 2], &[0.0, 1.0]);
    }

    #[test]
    fn write_into_batch_rejects_full_capacity() {
        let mut c = SequenceCache::new(1, 2);
        for i in 0..4 {
            c.append(0, &[0.0; 2], &[0.0; 2], i).unwrap();
        }
        let mut kb = Tensor::zeros(&[1, 1, 4, 1, 2]);
        let mut vb = Tensor::zeros(&[1, 1, 4, 1, 2]);
        let mut lens = vec![0i32; 1];
        // len == M is not allowed: the step appends at slot len.
        assert!(c.write_into_batch(&mut kb, &mut vb, &mut lens, 0).is_err());
    }

    #[test]
    fn snapshot_restore_roundtrip_preserves_everything() {
        let mut c = SequenceCache::new(2, 3);
        c.append(0, &[1.0; 3], &[2.0; 3], 0).unwrap();
        c.append(0, &[3.0; 3], &[4.0; 3], 1).unwrap();
        c.append(1, &[5.0; 3], &[6.0; 3], 0).unwrap();
        c.add_scores(0, &[0.5, 0.25]).unwrap();
        let bytes = c.bytes();
        let k0 = c.layers[0].k.clone();
        let meta0 = c.layers[0].meta.clone();
        let snap = c.snapshot();
        assert_eq!(snap.bytes(), bytes);
        assert_eq!(snap.total_tokens(), 3);
        assert_eq!(snap.n_layer(), 2);
        assert_eq!(snap.layer_len(0), 2);
        assert_eq!(snap.layer_len(1), 1);
        assert_eq!(snap.row_elems(), 3);
        let back = snap.restore();
        assert_eq!(back.bytes(), bytes);
        assert_eq!(back.layers[0].k, k0);
        assert_eq!(back.layers[0].meta, meta0); // H2O scores survive
        assert_eq!(back.layer_len(1), 1);
    }

    #[test]
    fn truncate_drops_drafted_tail_only() {
        let mut c = SequenceCache::new(2, 2);
        // Committed prefix: positions 0..3 in layer 0 (with eviction hole at
        // pos 1), positions 0..2 in layer 1.
        for p in [0u32, 2, 3] {
            c.append(0, &[p as f32; 2], &[p as f32 + 10.0; 2], p).unwrap();
        }
        for p in [0u32, 1] {
            c.append(1, &[p as f32; 2], &[p as f32; 2], p).unwrap();
        }
        c.add_scores(0, &[0.5, 0.25, 0.125]).unwrap();
        let k0 = c.layers[0].k.clone();
        let meta0 = c.layers[0].meta.clone();
        // Draft two rows at positions 4, 5 (scores never accumulated).
        for p in [4u32, 5] {
            c.append(0, &[99.0; 2], &[99.0; 2], p).unwrap();
            c.append(1, &[99.0; 2], &[99.0; 2], p).unwrap();
        }
        assert_eq!(c.truncate(4), 4);
        assert_eq!(c.layers[0].k, k0);
        assert_eq!(c.layers[0].meta, meta0); // positions + H2O scores intact
        assert_eq!(c.layer_len(1), 2);
        // Idempotent once the tail is gone.
        assert_eq!(c.truncate(4), 0);
    }

    #[test]
    fn truncate_to_zero_empties() {
        let mut c = SequenceCache::new(1, 3);
        for p in 0..4 {
            c.append(0, &[0.0; 3], &[0.0; 3], p).unwrap();
        }
        assert_eq!(c.truncate(0), 4);
        assert_eq!(c.total_tokens(), 0);
        assert!(c.layers[0].k.is_empty() && c.layers[0].v.is_empty());
    }

    #[test]
    fn generations_track_mutations_and_destructiveness() {
        let mut c = SequenceCache::new(1, 2);
        assert_eq!(c.generation(), 0);
        assert_eq!(c.dirty_generation(), 0);
        for i in 0..4 {
            c.append(0, &[i as f32; 2], &[0.0; 2], i).unwrap();
        }
        let g = c.generation();
        assert_eq!(g, 4);
        assert_eq!(c.dirty_generation(), 0, "appends are not destructive");
        c.add_scores(0, &[0.1; 4]).unwrap();
        assert_eq!(c.generation(), g + 1);
        assert_eq!(c.dirty_generation(), 0, "score folding leaves payload rows intact");
        // Compaction that drops rows dirties the cache.
        assert_eq!(c.retain(0, &[0, 2, 3]).unwrap(), 1);
        assert_eq!(c.dirty_generation(), c.generation());
        // Identity retain bumps but does not dirty.
        let d = c.dirty_generation();
        assert_eq!(c.retain(0, &[0, 1, 2]).unwrap(), 0);
        assert!(c.generation() > d);
        assert_eq!(c.dirty_generation(), d);
        // No-op truncate bumps but does not dirty; a real rollback dirties.
        c.truncate(10);
        assert_eq!(c.dirty_generation(), d);
        assert!(c.truncate(1) > 0);
        assert_eq!(c.dirty_generation(), c.generation());
    }

    #[test]
    fn restore_continues_generations_and_marks_dirty() {
        let mut c = SequenceCache::new(1, 2);
        for i in 0..3 {
            c.append(0, &[0.0; 2], &[0.0; 2], i).unwrap();
        }
        let g = c.generation();
        let back = c.snapshot().restore();
        assert!(back.generation() > g, "generations never move backward across suspend");
        assert_eq!(
            back.dirty_generation(),
            back.generation(),
            "a restored cache must force a scratch refill"
        );
    }

    #[test]
    fn write_rows_into_batch_copies_only_the_tail() {
        let mut c = SequenceCache::new(2, 2);
        for i in 0..3 {
            c.append(0, &[i as f32; 2], &[10.0 + i as f32; 2], i).unwrap();
            c.append(1, &[20.0 + i as f32; 2], &[30.0 + i as f32; 2], i).unwrap();
        }
        let mut kb = Tensor::zeros(&[2, 1, 6, 1, 2]);
        let mut vb = Tensor::zeros(&[2, 1, 6, 1, 2]);
        let mut lens = vec![0i32; 2];
        // Full refill: 3 rows per layer.
        let n = c.write_rows_into_batch(&mut kb, &mut vb, &mut lens, 0, &[0, 0]).unwrap();
        assert_eq!(n, 6);
        assert_eq!(lens, vec![3, 3]);
        // Append one row per layer; incremental copy moves exactly 2 rows.
        c.append(0, &[9.0; 2], &[9.5; 2], 3).unwrap();
        c.append(1, &[8.0; 2], &[8.5; 2], 3).unwrap();
        let n = c.write_rows_into_batch(&mut kb, &mut vb, &mut lens, 0, &[3, 3]).unwrap();
        assert_eq!(n, 2);
        assert_eq!(lens, vec![4, 4]);
        // The buffer now matches a fresh full gather byte-exactly.
        let mut kb2 = kb.clone();
        let mut vb2 = vb.clone();
        c.write_into_batch(&mut kb2, &mut vb2, &mut lens, 0).unwrap();
        assert_eq!(kb.data, kb2.data);
        assert_eq!(vb.data, vb2.data);
        // Contract violations are hard errors.
        assert!(c.write_rows_into_batch(&mut kb, &mut vb, &mut lens, 0, &[0]).is_err());
        assert!(c.write_rows_into_batch(&mut kb, &mut vb, &mut lens, 0, &[5, 0]).is_err());
    }

    #[test]
    fn byte_accounting() {
        let mut c = SequenceCache::new(2, 4);
        c.append(0, &[0.0; 4], &[0.0; 4], 0).unwrap();
        c.append(1, &[0.0; 4], &[0.0; 4], 0).unwrap();
        assert_eq!(c.bytes(), 2 * 4 * 2 * 4);
    }
}
