//! Global KV memory pool with byte-granular accounting.
//!
//! Plays the role of the GPU HBM budget in the paper's Tables 3/9 and Fig. 4:
//! every cached token is charged here, OOM = a reservation that does not fit.
//! `capacity = 0` means unlimited (accuracy experiments); throughput/OOM
//! experiments set a finite capacity so Full Cache hits the same wall the
//! paper's A100s do.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Returned when a reservation exceeds remaining pool capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutOfMemory {
    pub requested: usize,
    pub in_use: usize,
    pub capacity: usize,
}

impl std::fmt::Display for OutOfMemory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "KV pool OOM: requested {} B with {}/{} B in use",
            self.requested, self.in_use, self.capacity
        )
    }
}

impl std::error::Error for OutOfMemory {}

/// Shared KV pool. Cloning shares the underlying accounting.
#[derive(Debug, Clone)]
pub struct KvPool {
    inner: Arc<Inner>,
}

#[derive(Debug)]
struct Inner {
    capacity: usize, // 0 = unlimited
    in_use: AtomicUsize,
    peak: AtomicUsize,
    oom_events: AtomicUsize,
}

impl KvPool {
    pub fn new(capacity_bytes: usize) -> Self {
        Self {
            inner: Arc::new(Inner {
                capacity: capacity_bytes,
                in_use: AtomicUsize::new(0),
                peak: AtomicUsize::new(0),
                oom_events: AtomicUsize::new(0),
            }),
        }
    }

    pub fn unlimited() -> Self {
        Self::new(0)
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    pub fn in_use(&self) -> usize {
        self.inner.in_use.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> usize {
        self.inner.peak.load(Ordering::Relaxed)
    }

    pub fn oom_events(&self) -> usize {
        self.inner.oom_events.load(Ordering::Relaxed)
    }

    /// Reserve `bytes`; fails atomically with `OutOfMemory` when capped.
    pub fn reserve(&self, bytes: usize) -> Result<(), OutOfMemory> {
        if self.inner.capacity == 0 {
            let now = self.inner.in_use.fetch_add(bytes, Ordering::Relaxed) + bytes;
            self.inner.peak.fetch_max(now, Ordering::Relaxed);
            return Ok(());
        }
        let mut cur = self.inner.in_use.load(Ordering::Relaxed);
        loop {
            let next = cur + bytes;
            if next > self.inner.capacity {
                self.inner.oom_events.fetch_add(1, Ordering::Relaxed);
                return Err(OutOfMemory {
                    requested: bytes,
                    in_use: cur,
                    capacity: self.inner.capacity,
                });
            }
            match self.inner.in_use.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.inner.peak.fetch_max(next, Ordering::Relaxed);
                    return Ok(());
                }
                Err(actual) => cur = actual,
            }
        }
    }

    /// Release previously reserved bytes.
    pub fn release(&self, bytes: usize) {
        let prev = self.inner.in_use.fetch_sub(bytes, Ordering::Relaxed);
        debug_assert!(prev >= bytes, "pool release underflow: {prev} - {bytes}");
    }
}

/// RAII reservation that releases on drop and supports resizing as a
/// sequence's cache grows (append) or shrinks (eviction).
pub struct Reservation {
    pool: KvPool,
    bytes: usize,
}

impl Reservation {
    pub fn new(pool: &KvPool, bytes: usize) -> Result<Self, OutOfMemory> {
        pool.reserve(bytes)?;
        Ok(Self { pool: pool.clone(), bytes })
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Adjust the reservation to `new_bytes` (grow may OOM; shrink cannot).
    pub fn resize(&mut self, new_bytes: usize) -> Result<(), OutOfMemory> {
        if new_bytes > self.bytes {
            self.pool.reserve(new_bytes - self.bytes)?;
        } else {
            self.pool.release(self.bytes - new_bytes);
        }
        self.bytes = new_bytes;
        Ok(())
    }
}

impl Drop for Reservation {
    fn drop(&mut self) {
        self.pool.release(self.bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_release_roundtrip() {
        let pool = KvPool::new(100);
        pool.reserve(60).unwrap();
        assert_eq!(pool.in_use(), 60);
        assert!(pool.reserve(50).is_err());
        assert_eq!(pool.oom_events(), 1);
        pool.release(60);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.peak(), 60);
    }

    #[test]
    fn unlimited_never_ooms() {
        let pool = KvPool::unlimited();
        pool.reserve(usize::MAX / 4).unwrap();
        assert_eq!(pool.oom_events(), 0);
    }

    #[test]
    fn reservation_raii() {
        let pool = KvPool::new(100);
        {
            let mut r = Reservation::new(&pool, 40).unwrap();
            r.resize(80).unwrap();
            assert_eq!(pool.in_use(), 80);
            assert!(r.resize(200).is_err());
            assert_eq!(pool.in_use(), 80); // failed grow leaves state intact
            r.resize(10).unwrap();
            assert_eq!(pool.in_use(), 10);
        }
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn shared_accounting_across_clones() {
        let pool = KvPool::new(100);
        let p2 = pool.clone();
        pool.reserve(70).unwrap();
        assert!(p2.reserve(40).is_err());
        p2.release(70);
        assert_eq!(pool.in_use(), 0);
    }
}
