//! Two-tier KV memory pool with byte-granular accounting.
//!
//! Plays the role of the GPU HBM budget in the paper's Tables 3/9 and Fig. 4:
//! every cached token is charged to the **device** tier, OOM = a reservation
//! that does not fit. The **host** tier accounts for swapped-out (suspended)
//! sequences: a preempted sequence's post-eviction cache — already squeezed
//! to its per-layer budgets — migrates device→host instead of being thrown
//! away, and back host→device on resume. For either tier, `capacity = 0`
//! means unlimited (accuracy experiments); throughput/OOM experiments set a
//! finite device capacity so Full Cache hits the same wall the paper's A100s
//! do. Migrations additionally accumulate per-direction traffic counters
//! (`migrated_into`) so the simulator cost model can price the PCIe
//! transfers a real swap would perform.
//!
//! This module is the byte-accounting substrate; the serving engine charges
//! it through the page-granular allocator in [`super::paging`], which maps
//! each sequence's per-layer slot ranges onto ref-counted fixed-size pages
//! (copy-on-write prefix sharing, page-table-only migration).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Memory tier a reservation's bytes are charged to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Accelerator HBM (the paper's KV-cache budget).
    Device,
    /// Host (CPU) spill memory holding suspended sequences.
    Host,
}

impl Tier {
    fn index(self) -> usize {
        match self {
            Tier::Device => 0,
            Tier::Host => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Tier::Device => "device",
            Tier::Host => "host",
        }
    }
}

/// Returned when a reservation exceeds a tier's remaining capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutOfMemory {
    pub tier: Tier,
    pub requested: usize,
    pub in_use: usize,
    pub capacity: usize,
}

impl std::fmt::Display for OutOfMemory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "KV pool OOM ({} tier): requested {} B with {}/{} B in use",
            self.tier.name(),
            self.requested,
            self.in_use,
            self.capacity
        )
    }
}

impl std::error::Error for OutOfMemory {}

#[derive(Debug)]
struct TierState {
    capacity: usize, // 0 = unlimited
    in_use: AtomicUsize,
    peak: AtomicUsize,
    oom_events: AtomicUsize,
    /// Release-underflow events (double-release / release-without-reserve).
    /// The release saturates at 0 instead of wrapping, and this counter
    /// makes the bug observable through metrics.
    accounting_errors: AtomicUsize,
}

impl TierState {
    fn new(capacity: usize) -> Self {
        Self {
            capacity,
            in_use: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            oom_events: AtomicUsize::new(0),
            accounting_errors: AtomicUsize::new(0),
        }
    }
}

/// Shared two-tier KV pool. Cloning shares the underlying accounting.
#[derive(Debug, Clone)]
pub struct KvPool {
    inner: Arc<Inner>,
}

#[derive(Debug)]
struct Inner {
    tiers: [TierState; 2],
    /// Cumulative bytes migrated *into* each tier (indexed like `tiers`):
    /// `migrated[Host]` is total swap-out traffic, `migrated[Device]` total
    /// swap-in traffic. Each models one PCIe transfer of that many bytes,
    /// which the simulator cost model prices (`Cluster::swap_transfer_s`).
    migrated: [AtomicUsize; 2],
}

impl KvPool {
    /// Device-only pool (host tier unlimited but unused unless spilled to).
    pub fn new(capacity_bytes: usize) -> Self {
        Self::tiered(capacity_bytes, 0)
    }

    /// Pool with explicit device and host-spill capacities (0 = unlimited).
    pub fn tiered(device_bytes: usize, host_bytes: usize) -> Self {
        Self {
            inner: Arc::new(Inner {
                tiers: [TierState::new(device_bytes), TierState::new(host_bytes)],
                migrated: [AtomicUsize::new(0), AtomicUsize::new(0)],
            }),
        }
    }

    pub fn unlimited() -> Self {
        Self::tiered(0, 0)
    }

    fn tier(&self, tier: Tier) -> &TierState {
        &self.inner.tiers[tier.index()]
    }

    pub fn capacity_of(&self, tier: Tier) -> usize {
        self.tier(tier).capacity
    }

    pub fn in_use_of(&self, tier: Tier) -> usize {
        self.tier(tier).in_use.load(Ordering::Relaxed)
    }

    pub fn peak_of(&self, tier: Tier) -> usize {
        self.tier(tier).peak.load(Ordering::Relaxed)
    }

    pub fn oom_events_of(&self, tier: Tier) -> usize {
        self.tier(tier).oom_events.load(Ordering::Relaxed)
    }

    /// Release-underflow events recorded on `tier` (see `release_on`).
    pub fn accounting_errors_of(&self, tier: Tier) -> usize {
        self.tier(tier).accounting_errors.load(Ordering::Relaxed)
    }

    /// Total release-underflow events across both tiers.
    pub fn accounting_errors(&self) -> usize {
        self.accounting_errors_of(Tier::Device) + self.accounting_errors_of(Tier::Host)
    }

    /// Cumulative bytes migrated *into* `tier` (swap traffic in that
    /// direction: into `Host` = swap-outs, into `Device` = swap-ins).
    pub fn migrated_into(&self, tier: Tier) -> usize {
        self.inner.migrated[tier.index()].load(Ordering::Relaxed)
    }

    /// Total swap traffic in bytes, both directions — what a host link
    /// (PCIe) would actually have carried.
    pub fn migrated_total(&self) -> usize {
        self.migrated_into(Tier::Device) + self.migrated_into(Tier::Host)
    }

    /// Device-tier capacity (back-compat shorthand).
    pub fn capacity(&self) -> usize {
        self.capacity_of(Tier::Device)
    }

    /// Device-tier bytes in use.
    pub fn in_use(&self) -> usize {
        self.in_use_of(Tier::Device)
    }

    /// Device-tier high-water mark.
    pub fn peak(&self) -> usize {
        self.peak_of(Tier::Device)
    }

    /// Device-tier OOM events.
    pub fn oom_events(&self) -> usize {
        self.oom_events_of(Tier::Device)
    }

    /// Reserve `bytes` on `tier`; fails atomically with `OutOfMemory` when
    /// the tier is capped and the bytes do not fit. All arithmetic is
    /// checked: a request so large that `in_use + bytes` would wrap `usize`
    /// is an OOM, never a wrap-around that corrupts accounting.
    pub fn reserve_on(&self, tier: Tier, bytes: usize) -> Result<(), OutOfMemory> {
        let t = self.tier(tier);
        if t.capacity == 0 {
            // Unlimited tier: still refuse an overflowing add — a wrapped
            // `in_use` would report near-zero usage with the pool full.
            let updated = t
                .in_use
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| cur.checked_add(bytes));
            return match updated {
                Ok(prev) => {
                    t.peak.fetch_max(prev + bytes, Ordering::Relaxed);
                    Ok(())
                }
                Err(cur) => {
                    t.oom_events.fetch_add(1, Ordering::Relaxed);
                    Err(OutOfMemory { tier, requested: bytes, in_use: cur, capacity: 0 })
                }
            };
        }
        let mut cur = t.in_use.load(Ordering::Relaxed);
        loop {
            let next = match cur.checked_add(bytes) {
                Some(next) if next <= t.capacity => next,
                _ => {
                    // Overflow or over-capacity: both mean "does not fit".
                    t.oom_events.fetch_add(1, Ordering::Relaxed);
                    return Err(OutOfMemory {
                        tier,
                        requested: bytes,
                        in_use: cur,
                        capacity: t.capacity,
                    });
                }
            };
            match t.in_use.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => {
                    t.peak.fetch_max(next, Ordering::Relaxed);
                    return Ok(());
                }
                Err(actual) => cur = actual,
            }
        }
    }

    /// Release previously reserved bytes on `tier`. A release larger than
    /// the current `in_use` (double-release or release-without-reserve)
    /// saturates at 0 instead of wrapping to ~`usize::MAX` — which would
    /// permanently brick admission — and bumps `accounting_errors` so the
    /// bug stays observable through metrics.
    pub fn release_on(&self, tier: Tier, bytes: usize) {
        let t = self.tier(tier);
        let res = t.in_use.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
            Some(cur.saturating_sub(bytes))
        });
        if let Ok(prev) = res {
            if prev < bytes {
                t.accounting_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Record an accounting fault detected by a caller (e.g. the paged
    /// allocator seeing a double-freed page id) on `tier`'s error counter.
    pub(crate) fn note_accounting_error(&self, tier: Tier) {
        self.tier(tier).accounting_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `bytes` of migration traffic into `to` (one PCIe transfer of
    /// that many bytes). Used by `Reservation::migrate` and by the paged
    /// allocator, which moves page-table entries and charges only the pages
    /// that physically change tier.
    pub(crate) fn note_migrated(&self, to: Tier, bytes: usize) {
        self.inner.migrated[to.index()].fetch_add(bytes, Ordering::Relaxed);
    }

    /// Reserve on the device tier (back-compat shorthand).
    pub fn reserve(&self, bytes: usize) -> Result<(), OutOfMemory> {
        self.reserve_on(Tier::Device, bytes)
    }

    /// Release on the device tier (back-compat shorthand).
    pub fn release(&self, bytes: usize) {
        self.release_on(Tier::Device, bytes)
    }
}

/// RAII reservation that releases on drop and supports resizing as a
/// sequence's cache grows (append) or shrinks (eviction), plus migration
/// between tiers (swap-out / swap-in of suspended sequences).
pub struct Reservation {
    pool: KvPool,
    tier: Tier,
    bytes: usize,
}

impl Reservation {
    /// Device-tier reservation (the common case: a running sequence).
    pub fn new(pool: &KvPool, bytes: usize) -> Result<Self, OutOfMemory> {
        Self::on(pool, Tier::Device, bytes)
    }

    /// Reservation on an explicit tier.
    pub fn on(pool: &KvPool, tier: Tier, bytes: usize) -> Result<Self, OutOfMemory> {
        pool.reserve_on(tier, bytes)?;
        Ok(Self { pool: pool.clone(), tier, bytes })
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn tier(&self) -> Tier {
        self.tier
    }

    /// Adjust the reservation to `new_bytes` on its current tier (grow may
    /// OOM; shrink cannot).
    pub fn resize(&mut self, new_bytes: usize) -> Result<(), OutOfMemory> {
        if new_bytes > self.bytes {
            self.pool.reserve_on(self.tier, new_bytes - self.bytes)?;
        } else {
            self.pool.release_on(self.tier, self.bytes - new_bytes);
        }
        self.bytes = new_bytes;
        Ok(())
    }

    /// Atomically move this reservation's bytes to `to`: the target tier is
    /// charged first (failing cleanly with `OutOfMemory` and no state
    /// change), then the source tier is released — mirroring a real copy,
    /// where both copies exist until the source is freed.
    pub fn migrate(&mut self, to: Tier) -> Result<(), OutOfMemory> {
        if to == self.tier {
            return Ok(());
        }
        self.pool.reserve_on(to, self.bytes)?;
        self.pool.release_on(self.tier, self.bytes);
        self.pool.note_migrated(to, self.bytes);
        self.tier = to;
        Ok(())
    }
}

impl Drop for Reservation {
    fn drop(&mut self) {
        self.pool.release_on(self.tier, self.bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_release_roundtrip() {
        let pool = KvPool::new(100);
        pool.reserve(60).unwrap();
        assert_eq!(pool.in_use(), 60);
        assert!(pool.reserve(50).is_err());
        assert_eq!(pool.oom_events(), 1);
        pool.release(60);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.peak(), 60);
    }

    #[test]
    fn unlimited_never_ooms() {
        let pool = KvPool::unlimited();
        pool.reserve(usize::MAX / 4).unwrap();
        assert_eq!(pool.oom_events(), 0);
    }

    #[test]
    fn reservation_raii() {
        let pool = KvPool::new(100);
        {
            let mut r = Reservation::new(&pool, 40).unwrap();
            r.resize(80).unwrap();
            assert_eq!(pool.in_use(), 80);
            assert!(r.resize(200).is_err());
            assert_eq!(pool.in_use(), 80); // failed grow leaves state intact
            r.resize(10).unwrap();
            assert_eq!(pool.in_use(), 10);
        }
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn shared_accounting_across_clones() {
        let pool = KvPool::new(100);
        let p2 = pool.clone();
        pool.reserve(70).unwrap();
        assert!(p2.reserve(40).is_err());
        p2.release(70);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn tiers_account_independently() {
        let pool = KvPool::tiered(100, 50);
        pool.reserve_on(Tier::Device, 90).unwrap();
        pool.reserve_on(Tier::Host, 40).unwrap();
        assert_eq!(pool.in_use_of(Tier::Device), 90);
        assert_eq!(pool.in_use_of(Tier::Host), 40);
        // Each tier hits its own wall.
        assert_eq!(pool.reserve_on(Tier::Device, 20).unwrap_err().tier, Tier::Device);
        assert_eq!(pool.reserve_on(Tier::Host, 20).unwrap_err().tier, Tier::Host);
        assert_eq!(pool.oom_events_of(Tier::Device), 1);
        assert_eq!(pool.oom_events_of(Tier::Host), 1);
        pool.release_on(Tier::Device, 90);
        pool.release_on(Tier::Host, 40);
        assert_eq!(pool.in_use_of(Tier::Device), 0);
        assert_eq!(pool.in_use_of(Tier::Host), 0);
    }

    #[test]
    fn migrate_moves_bytes_between_tiers() {
        let pool = KvPool::tiered(100, 100);
        let mut r = Reservation::new(&pool, 60).unwrap();
        assert_eq!(r.tier(), Tier::Device);
        r.migrate(Tier::Host).unwrap();
        assert_eq!(r.tier(), Tier::Host);
        assert_eq!(pool.in_use_of(Tier::Device), 0);
        assert_eq!(pool.in_use_of(Tier::Host), 60);
        // migrate to the same tier is a no-op (and charges no traffic)
        r.migrate(Tier::Host).unwrap();
        assert_eq!(pool.in_use_of(Tier::Host), 60);
        assert_eq!(pool.migrated_into(Tier::Host), 60);
        r.migrate(Tier::Device).unwrap();
        assert_eq!(pool.in_use_of(Tier::Device), 60);
        assert_eq!(pool.in_use_of(Tier::Host), 0);
        // Swap traffic accounted per direction and in total.
        assert_eq!(pool.migrated_into(Tier::Host), 60);
        assert_eq!(pool.migrated_into(Tier::Device), 60);
        assert_eq!(pool.migrated_total(), 120);
        drop(r);
        assert_eq!(pool.in_use_of(Tier::Device), 0);
        assert_eq!(pool.migrated_total(), 120, "drop is a release, not traffic");
    }

    #[test]
    fn migrate_oom_leaves_state_intact() {
        let pool = KvPool::tiered(100, 50);
        let mut r = Reservation::new(&pool, 80).unwrap();
        let err = r.migrate(Tier::Host).unwrap_err();
        assert_eq!(err.tier, Tier::Host);
        assert_eq!(r.tier(), Tier::Device);
        assert_eq!(pool.in_use_of(Tier::Device), 80);
        assert_eq!(pool.in_use_of(Tier::Host), 0);
        assert_eq!(pool.migrated_total(), 0, "failed migrate moved no bytes");
    }

    #[test]
    fn reserve_near_usize_max_is_oom_not_wraparound() {
        // Regression: `in_use + bytes` used to wrap, pass the capacity
        // check, and corrupt accounting. It must be a clean OOM.
        let pool = KvPool::new(100);
        pool.reserve(60).unwrap();
        let err = pool.reserve(usize::MAX - 10).unwrap_err();
        assert_eq!(err.requested, usize::MAX - 10);
        assert_eq!(err.in_use, 60);
        assert_eq!(pool.in_use(), 60, "failed reserve must not change in_use");
        assert_eq!(pool.oom_events(), 1);
        // Same on the unlimited path: fetch_add used to wrap silently.
        let unlimited = KvPool::unlimited();
        unlimited.reserve(usize::MAX / 2).unwrap();
        assert!(unlimited.reserve(usize::MAX / 2 + 2).is_err());
        assert_eq!(unlimited.in_use(), usize::MAX / 2);
        assert_eq!(unlimited.oom_events(), 1);
    }

    #[test]
    fn double_release_saturates_and_counts() {
        // Regression: release used to `fetch_sub` unchecked, so a release-
        // build double-release wrapped `in_use` to ~usize::MAX and bricked
        // all future admission. It must saturate at 0 and be counted.
        let pool = KvPool::new(100);
        pool.reserve(40).unwrap();
        pool.release(40);
        pool.release(40); // double release
        assert_eq!(pool.in_use(), 0, "underflow must saturate, not wrap");
        assert_eq!(pool.accounting_errors(), 1);
        assert_eq!(pool.accounting_errors_of(Tier::Device), 1);
        // The pool still admits new work afterwards.
        pool.reserve(90).unwrap();
        assert_eq!(pool.in_use(), 90);
        pool.release(90);
        // Partial underflow (release more than held) also saturates.
        pool.reserve(10).unwrap();
        pool.release_on(Tier::Device, 25);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.accounting_errors(), 2);
        assert_eq!(pool.accounting_errors_of(Tier::Host), 0);
    }

    #[test]
    fn host_tier_drop_releases_host_bytes() {
        let pool = KvPool::tiered(0, 100);
        {
            let _r = Reservation::on(&pool, Tier::Host, 70).unwrap();
            assert_eq!(pool.in_use_of(Tier::Host), 70);
            assert_eq!(pool.in_use(), 0);
        }
        assert_eq!(pool.in_use_of(Tier::Host), 0);
        assert_eq!(pool.peak_of(Tier::Host), 70);
    }
}
