//! Paged KV allocator: fixed-size, ref-counted pages behind per-sequence
//! page tables, with copy-on-write prefix sharing.
//!
//! SqueezeAttention's layer-wise budgets make per-layer KV lengths
//! deliberately uneven, so byte-granular contiguous reservations fragment
//! and every preemption swaps the whole blob. This module quantizes the
//! two-tier [`KvPool`](super::KvPool) into pages (vLLM-style blocks):
//!
//! * [`PagedKvPool`] wraps a `KvPool` and owns the page registry — every
//!   live page has a [`PageId`], a tier, and a refcount. Allocating a page
//!   charges `page_bytes` to its tier; freeing the last reference releases
//!   them. The underlying `KvPool` stays the single source of byte
//!   accounting (and of OOM), so all existing conservation invariants keep
//!   holding.
//! * [`PageTable`] maps one sequence's (layer, slot-range) pairs onto
//!   pages: layer `l`, slots `[i*spp, (i+1)*spp)` live in the i-th page of
//!   that layer (`spp` = slots per page). Growth and eviction move the
//!   table in whole-page steps; suspend/resume ([`PageTable::migrate`]) is
//!   a page-table edit that charges PCIe traffic for exactly the pages
//!   that change tier.
//! * `share_prefix` lets a second sequence reference the *full* pages of a
//!   prompt prefix by bumping refcounts — the shared bytes are charged
//!   once. Copy-on-write triggers on the first divergent write: appending
//!   into, or evicting/compacting, a shared page first re-homes that range
//!   onto a fresh private page (`cow_copies` counts these).
//!
//! The payload rows themselves still live in `SequenceCache` vectors (the
//! sim runtime is host-memory); the page table is the accounting and
//! placement layer a real block allocator would index into device HBM.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};

use super::cache::SequenceCache;
use super::pool::{KvPool, OutOfMemory, Tier};

/// Opaque handle to one fixed-size page in a [`PagedKvPool`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PageId(u64);

#[derive(Debug)]
struct PageState {
    tier: Tier,
    refs: usize,
}

#[derive(Debug, Default)]
struct Registry {
    next_id: u64,
    pages: HashMap<u64, PageState>,
    /// Live pages per tier (indexed Device=0, Host=1).
    tier_pages: [usize; 2],
    /// Gauge: pages currently referenced by more than one table.
    shared_pages: usize,
    /// Cumulative copy-on-write privatizations.
    cow_copies: usize,
    /// Cumulative pages ever allocated / fully freed.
    pages_allocated: usize,
    pages_freed: usize,
}

fn tier_idx(t: Tier) -> usize {
    match t {
        Tier::Device => 0,
        Tier::Host => 1,
    }
}

#[derive(Debug)]
struct PagedInner {
    pool: KvPool,
    page_bytes: usize,
    reg: Mutex<Registry>,
}

/// Page-granular allocator over a two-tier [`KvPool`]. Cloning shares the
/// registry and the underlying byte accounting.
#[derive(Debug, Clone)]
pub struct PagedKvPool {
    inner: Arc<PagedInner>,
}

impl PagedKvPool {
    /// Wrap `pool`, carving reservations into `page_bytes`-sized pages
    /// (clamped to at least 1 byte).
    pub fn new(pool: KvPool, page_bytes: usize) -> Self {
        Self {
            inner: Arc::new(PagedInner {
                pool,
                page_bytes: page_bytes.max(1),
                reg: Mutex::new(Registry::default()),
            }),
        }
    }

    /// The underlying byte-accounted pool (capacities, in-use, peaks, OOM
    /// and migration-traffic counters all live there).
    pub fn pool(&self) -> &KvPool {
        &self.inner.pool
    }

    pub fn page_bytes(&self) -> usize {
        self.inner.page_bytes
    }

    fn reg(&self) -> MutexGuard<'_, Registry> {
        self.inner.reg.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Allocate `n` fresh pages on `tier` (refcount 1 each). Atomic: on
    /// OOM nothing is charged and no page is created.
    pub fn alloc_pages(&self, tier: Tier, n: usize) -> Result<Vec<PageId>, OutOfMemory> {
        if n == 0 {
            return Ok(Vec::new());
        }
        let bytes = n.checked_mul(self.inner.page_bytes).ok_or(OutOfMemory {
            tier,
            requested: usize::MAX,
            in_use: self.inner.pool.in_use_of(tier),
            capacity: self.inner.pool.capacity_of(tier),
        })?;
        self.inner.pool.reserve_on(tier, bytes)?;
        let mut reg = self.reg();
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            let id = reg.next_id;
            reg.next_id += 1;
            reg.pages.insert(id, PageState { tier, refs: 1 });
            ids.push(PageId(id));
        }
        reg.tier_pages[tier_idx(tier)] += n;
        reg.pages_allocated += n;
        Ok(ids)
    }

    /// Add one reference to `id` (prefix sharing). Panics on a dangling id
    /// — that is a table-logic bug, not a runtime condition.
    pub fn retain_page(&self, id: PageId) {
        let mut reg = self.reg();
        let page = reg.pages.get_mut(&id.0).expect("retain of freed page");
        page.refs += 1;
        if page.refs == 2 {
            reg.shared_pages += 1;
        }
    }

    /// Drop one reference to `id`; frees the page (releasing its bytes)
    /// when the last reference goes. Returns true iff the page was freed.
    pub fn release_page(&self, id: PageId) -> bool {
        let mut reg = self.reg();
        let Some(page) = reg.pages.get_mut(&id.0) else {
            // Double-free of a page id: count it through the pool's
            // accounting-error counter rather than corrupting the registry.
            self.inner.pool.note_accounting_error(Tier::Device);
            return false;
        };
        page.refs -= 1;
        match page.refs {
            1 => {
                reg.shared_pages -= 1;
                false
            }
            0 => {
                let tier = page.tier;
                reg.pages.remove(&id.0);
                reg.tier_pages[tier_idx(tier)] -= 1;
                reg.pages_freed += 1;
                drop(reg);
                self.inner.pool.release_on(tier, self.inner.page_bytes);
                true
            }
            _ => false,
        }
    }

    /// Move every page in `ids` whose refcount is 1 to `to`; shared pages
    /// stay put (another table still addresses them on their tier).
    /// Atomic: the target tier is charged for all moving pages first, so on
    /// OOM nothing changes. Returns the number of pages that physically
    /// moved; migration traffic of `pages_moved * page_bytes` is recorded
    /// on the underlying pool.
    pub fn migrate_pages(&self, ids: &[PageId], to: Tier) -> Result<usize, OutOfMemory> {
        let mut reg = self.reg();
        let mut moving: Vec<u64> = Vec::new();
        for id in ids {
            if let Some(p) = reg.pages.get(&id.0) {
                if p.refs == 1 && p.tier != to {
                    moving.push(id.0);
                }
            }
        }
        if moving.is_empty() {
            return Ok(0);
        }
        let bytes = moving.len() * self.inner.page_bytes;
        self.inner.pool.reserve_on(to, bytes)?;
        for id in &moving {
            let page = reg.pages.get_mut(id).expect("filtered above");
            let from = page.tier;
            page.tier = to;
            reg.tier_pages[tier_idx(from)] -= 1;
            reg.tier_pages[tier_idx(to)] += 1;
            self.inner.pool.release_on(from, self.inner.page_bytes);
        }
        self.inner.pool.note_migrated(to, bytes);
        Ok(moving.len())
    }

    fn note_cow(&self) {
        self.reg().cow_copies += 1;
    }

    /// Current refcount of `id`, or None if freed. (Prop-test observability.)
    pub fn refs_of(&self, id: PageId) -> Option<usize> {
        self.reg().pages.get(&id.0).map(|p| p.refs)
    }

    /// Tier `id` currently lives on, or None if freed.
    pub fn tier_of(&self, id: PageId) -> Option<Tier> {
        self.reg().pages.get(&id.0).map(|p| p.tier)
    }

    /// Live (not yet freed) pages across both tiers.
    pub fn live_pages(&self) -> usize {
        self.reg().pages.len()
    }

    /// Live pages on `tier`.
    pub fn live_pages_of(&self, tier: Tier) -> usize {
        self.reg().tier_pages[tier_idx(tier)]
    }

    /// Bytes currently allocated (page-granular) on `tier`.
    pub fn allocated_bytes_of(&self, tier: Tier) -> usize {
        self.live_pages_of(tier) * self.inner.page_bytes
    }

    /// Gauge: pages referenced by ≥ 2 tables right now.
    pub fn shared_pages(&self) -> usize {
        self.reg().shared_pages
    }

    /// Cumulative copy-on-write privatizations.
    pub fn cow_copies(&self) -> usize {
        self.reg().cow_copies
    }

    /// Cumulative pages ever allocated.
    pub fn pages_allocated(&self) -> usize {
        self.reg().pages_allocated
    }

    /// Cumulative pages fully freed.
    pub fn pages_freed(&self) -> usize {
        self.reg().pages_freed
    }
}

/// One sequence's mapping of (layer, slot-range) → pages. Layer `l`'s
/// slots `[i*spp, (i+1)*spp)` live in `layer_pages(l)[i]`. Dropping the
/// table releases every reference it holds.
#[derive(Debug)]
pub struct PageTable {
    pool: PagedKvPool,
    /// Home tier: where new pages are allocated and where `migrate` last
    /// landed the table.
    tier: Tier,
    slots_per_page: usize,
    layers: Vec<Vec<PageId>>,
}

impl PageTable {
    /// Empty table for `n_layer` layers whose slots are `token_bytes` wide.
    /// `slots_per_page = page_bytes / token_bytes` (at least 1 — callers
    /// should size pages ≥ one token or pages under-charge).
    pub fn new(pool: &PagedKvPool, tier: Tier, n_layer: usize, token_bytes: usize) -> Self {
        let spp = (pool.page_bytes() / token_bytes.max(1)).max(1);
        Self {
            pool: pool.clone(),
            tier,
            slots_per_page: spp,
            layers: vec![Vec::new(); n_layer],
        }
    }

    /// Table covering every layer of `cache`, allocated on `tier`.
    pub fn for_cache(
        pool: &PagedKvPool,
        tier: Tier,
        cache: &SequenceCache,
    ) -> Result<Self, OutOfMemory> {
        let token_bytes = SequenceCache::token_bytes(cache.row_elems);
        let mut table = Self::new(pool, tier, cache.n_layer(), token_bytes);
        let lens: Vec<usize> = (0..cache.n_layer()).map(|l| cache.layer_len(l)).collect();
        let zeros = vec![0; lens.len()];
        table.grow(&zeros, &lens)?;
        Ok(table)
    }

    pub fn tier(&self) -> Tier {
        self.tier
    }

    pub fn slots_per_page(&self) -> usize {
        self.slots_per_page
    }

    pub fn page_bytes(&self) -> usize {
        self.pool.page_bytes()
    }

    pub fn n_layer(&self) -> usize {
        self.layers.len()
    }

    /// Pages needed to hold `len` slots.
    pub fn pages_for(&self, len: usize) -> usize {
        len.div_ceil(self.slots_per_page)
    }

    /// Pages mapped by `layer`.
    pub fn layer_pages(&self, layer: usize) -> &[PageId] {
        &self.layers[layer]
    }

    /// Total pages mapped across layers.
    pub fn mapped_pages(&self) -> usize {
        self.layers.iter().map(|l| l.len()).sum()
    }

    /// Bytes this table charges its pool: `mapped_pages * page_bytes`.
    /// (Shared pages are charged to the pool once but appear in every
    /// sharing table's `bytes()` — the pool, not the table, is the source
    /// of truth for tier usage.)
    pub fn bytes(&self) -> usize {
        self.mapped_pages() * self.pool.page_bytes()
    }

    /// Bytes a `grow` to `lens` would newly allocate (page deficits only;
    /// COW copies — absent for unshared tables — not included).
    pub fn grow_bytes_for(&self, lens: &[usize]) -> usize {
        let mut pages = 0;
        for (l, mapped) in self.layers.iter().enumerate() {
            let len = lens.get(l).copied().unwrap_or(0);
            pages += self.pages_for(len).saturating_sub(mapped.len());
        }
        pages * self.pool.page_bytes()
    }

    /// Grow the table so layer `l` covers `lens[l]` slots, given it
    /// currently holds `old_lens[l]`. New pages are allocated on the home
    /// tier; any already-mapped **shared** page the new slots `[old, new)`
    /// would write into is first privatized (copy-on-write). Atomic: all
    /// new pages are reserved in one step, so on OOM the table is
    /// unchanged. Returns pages newly allocated (growth + COW copies).
    pub fn grow(&mut self, old_lens: &[usize], lens: &[usize]) -> Result<usize, OutOfMemory> {
        let spp = self.slots_per_page;
        let mut privatize: Vec<(usize, usize)> = Vec::new(); // (layer, page idx)
        let mut deficits: Vec<usize> = vec![0; self.layers.len()];
        for (l, pages) in self.layers.iter().enumerate() {
            let old = old_lens.get(l).copied().unwrap_or(0);
            let new = lens.get(l).copied().unwrap_or(0);
            if new <= old {
                continue;
            }
            deficits[l] = self.pages_for(new).saturating_sub(pages.len());
            // Already-mapped pages the write range [old, new) touches.
            let first = old / spp;
            let last = (new - 1) / spp;
            for idx in first..=last.min(pages.len().saturating_sub(1)) {
                if idx < pages.len() && self.pool.refs_of(pages[idx]).unwrap_or(1) > 1 {
                    privatize.push((l, idx));
                }
            }
        }
        let total = privatize.len() + deficits.iter().sum::<usize>();
        if total == 0 {
            return Ok(0);
        }
        let mut fresh = self.pool.alloc_pages(self.tier, total)?.into_iter();
        for (l, idx) in privatize {
            let new_id = fresh.next().expect("allocated above");
            let old_id = std::mem::replace(&mut self.layers[l][idx], new_id);
            self.pool.release_page(old_id);
            self.pool.note_cow();
        }
        for (l, deficit) in deficits.iter().enumerate() {
            for _ in 0..*deficit {
                let id = fresh.next().expect("allocated above");
                self.layers[l].push(id);
            }
        }
        Ok(total)
    }

    /// Shrink the table so layer `l` maps exactly `pages_for(lens[l])`
    /// pages: excess pages are unmapped (freed when this was the last
    /// reference), and retained **shared** pages are privatized — eviction
    /// compacts the payload in place, a divergent write the other sharer
    /// must not observe. Returns pages unmapped. Only the (engine-unused)
    /// COW path can fail; the unmapping itself is infallible and is
    /// completed first.
    pub fn shrink(&mut self, lens: &[usize]) -> Result<usize, OutOfMemory> {
        let mut unmapped = 0;
        let mut privatize: Vec<(usize, usize)> = Vec::new();
        for (l, pages) in self.layers.iter_mut().enumerate() {
            let keep = lens.get(l).copied().unwrap_or(0).div_ceil(self.slots_per_page);
            while pages.len() > keep {
                let id = pages.pop().expect("len checked");
                self.pool.release_page(id);
                unmapped += 1;
            }
        }
        for (l, pages) in self.layers.iter().enumerate() {
            for (idx, &id) in pages.iter().enumerate() {
                if self.pool.refs_of(id).unwrap_or(1) > 1 {
                    privatize.push((l, idx));
                }
            }
        }
        if !privatize.is_empty() {
            let mut fresh = self.pool.alloc_pages(self.tier, privatize.len())?.into_iter();
            for (l, idx) in privatize {
                let new_id = fresh.next().expect("allocated above");
                let old_id = std::mem::replace(&mut self.layers[l][idx], new_id);
                self.pool.release_page(old_id);
                self.pool.note_cow();
            }
        }
        Ok(unmapped)
    }

    /// Fork a table for a second sequence sharing this table's prompt
    /// prefix: the **full** pages of the first `prefix_len` slots of every
    /// layer are referenced (refcount bump — no new bytes charged); the
    /// partial tail page, if any, is not shared. Returns the new table on
    /// the same home tier; grow it to the new sequence's lengths next.
    pub fn share_prefix(&self, prefix_len: usize) -> PageTable {
        let full = prefix_len / self.slots_per_page;
        let mut layers: Vec<Vec<PageId>> = Vec::with_capacity(self.layers.len());
        for pages in &self.layers {
            let mut shared = Vec::new();
            for &id in &pages[..full.min(pages.len())] {
                self.pool.retain_page(id);
                shared.push(id);
            }
            layers.push(shared);
        }
        PageTable {
            pool: self.pool.clone(),
            tier: self.tier,
            slots_per_page: self.slots_per_page,
            layers,
        }
    }

    /// Bytes that would physically move on `migrate` (unshared pages only).
    pub fn migratable_bytes(&self, to: Tier) -> usize {
        let mut pages = 0;
        for &id in self.layers.iter().flatten() {
            if self.pool.refs_of(id) == Some(1) && self.pool.tier_of(id) != Some(to) {
                pages += 1;
            }
        }
        pages * self.pool.page_bytes()
    }

    /// Suspend/resume as a page-table edit: move every unshared page to
    /// `to` (shared pages stay put), charging migration traffic of exactly
    /// `page_bytes * pages_moved`. Atomic on OOM. Returns pages moved.
    pub fn migrate(&mut self, to: Tier) -> Result<usize, OutOfMemory> {
        let ids: Vec<PageId> = self.layers.iter().flatten().copied().collect();
        let moved = self.pool.migrate_pages(&ids, to)?;
        self.tier = to;
        Ok(moved)
    }
}

impl Drop for PageTable {
    fn drop(&mut self) {
        for pages in &self.layers {
            for &id in pages {
                self.pool.release_page(id);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paged(device: usize, host: usize, page_bytes: usize) -> PagedKvPool {
        PagedKvPool::new(KvPool::tiered(device, host), page_bytes)
    }

    /// 4 slots per page: token_bytes 16, page_bytes 64.
    fn table(pool: &PagedKvPool, n_layer: usize) -> PageTable {
        PageTable::new(pool, Tier::Device, n_layer, 16)
    }

    #[test]
    fn grow_and_shrink_in_page_steps() {
        let pool = paged(0, 0, 64);
        let mut t = table(&pool, 2);
        assert_eq!(t.slots_per_page(), 4);
        // 1 slot on each layer -> one page each.
        assert_eq!(t.grow(&[0, 0], &[1, 1]).unwrap(), 2);
        assert_eq!(t.bytes(), 128);
        assert_eq!(pool.pool().in_use(), 128);
        // Growing within the page allocates nothing.
        assert_eq!(t.grow(&[1, 1], &[4, 2]).unwrap(), 0);
        // Crossing the boundary allocates exactly the deficit.
        assert_eq!(t.grow(&[4, 2], &[5, 9]).unwrap(), 1 + 2);
        assert_eq!(t.layer_pages(0).len(), 2);
        assert_eq!(t.layer_pages(1).len(), 3);
        // Shrink frees whole pages only.
        assert_eq!(t.shrink(&[5, 3]).unwrap(), 2);
        assert_eq!(t.mapped_pages(), 3);
        assert_eq!(pool.pool().in_use(), 3 * 64);
        drop(t);
        assert_eq!(pool.pool().in_use(), 0);
        assert_eq!(pool.live_pages(), 0);
        assert_eq!(pool.pages_allocated(), pool.pages_freed());
    }

    #[test]
    fn grow_oom_is_atomic() {
        let pool = paged(2 * 64, 0, 64);
        let mut t = table(&pool, 1);
        t.grow(&[0], &[4]).unwrap();
        // Needs 2 more pages, only 1 fits: nothing must change.
        assert!(t.grow(&[4], &[12]).is_err());
        assert_eq!(t.mapped_pages(), 1);
        assert_eq!(pool.pool().in_use(), 64);
        assert_eq!(pool.pool().oom_events(), 1);
        // The single-page grow still succeeds afterwards.
        t.grow(&[4], &[5]).unwrap();
        assert_eq!(t.mapped_pages(), 2);
    }

    #[test]
    fn share_prefix_charges_shared_pages_once() {
        let pool = paged(0, 0, 64);
        let mut a = table(&pool, 2);
        a.grow(&[0, 0], &[10, 10]).unwrap(); // 3 pages/layer (slots 0..10)
        let base = pool.pool().in_use();
        assert_eq!(base, 6 * 64);

        // Share the 8-slot prefix: 2 full pages per layer, charged once.
        let mut b = a.share_prefix(8);
        assert_eq!(b.mapped_pages(), 4);
        assert_eq!(pool.pool().in_use(), base, "sharing must not charge new bytes");
        assert_eq!(pool.shared_pages(), 4);
        for l in 0..2 {
            assert_eq!(a.layer_pages(l)[..2], b.layer_pages(l)[..2]);
            for &id in &b.layer_pages(l)[..2] {
                assert_eq!(pool.refs_of(id), Some(2));
            }
        }

        // b grows past the shared prefix: fresh private pages only.
        b.grow(&[8, 8], &[10, 10]).unwrap();
        assert_eq!(pool.pool().in_use(), base + 2 * 64);
        assert_eq!(pool.cow_copies(), 0, "append past full shared pages needs no COW");

        // Dropping b releases only b's references.
        drop(b);
        assert_eq!(pool.pool().in_use(), base);
        assert_eq!(pool.shared_pages(), 0);
        drop(a);
        assert_eq!(pool.pool().in_use(), 0);
    }

    #[test]
    fn eviction_in_shared_page_privatizes() {
        let pool = paged(0, 0, 64);
        let mut a = table(&pool, 1);
        a.grow(&[0], &[8]).unwrap(); // 2 full pages
        let b = a.share_prefix(8);
        assert_eq!(pool.shared_pages(), 2);
        let shared_ids: Vec<PageId> = a.layer_pages(0).to_vec();

        // a evicts down to 3 slots: page 1 unmapped, page 0 retained but
        // compaction rewrites it -> COW privatize.
        assert_eq!(a.shrink(&[3]).unwrap(), 1);
        assert_eq!(pool.cow_copies(), 1);
        assert_ne!(a.layer_pages(0)[0], shared_ids[0], "retained shared page must be re-homed");
        // b still holds both original pages, now unshared.
        assert_eq!(pool.refs_of(shared_ids[0]), Some(1));
        assert_eq!(pool.refs_of(shared_ids[1]), Some(1));
        assert_eq!(pool.shared_pages(), 0);
        // Bytes: b's 2 pages + a's 1 private copy.
        assert_eq!(pool.pool().in_use(), 3 * 64);
        drop(a);
        drop(b);
        assert_eq!(pool.pool().in_use(), 0);
    }

    #[test]
    fn append_into_shared_partial_page_privatizes() {
        // share_prefix only shares full pages, but a table can also end up
        // appending into a shared page after the sharer grew it — exercise
        // the grow-side COW directly by sharing then shrinking the source.
        let pool = paged(0, 0, 64);
        let mut a = table(&pool, 1);
        a.grow(&[0], &[8]).unwrap();
        let mut b = a.share_prefix(8);
        let shared = b.layer_pages(0)[1];
        // b evicts to 6 slots: both pages retained + shared -> both COW.
        b.shrink(&[6]).unwrap();
        assert_eq!(pool.cow_copies(), 2);
        assert_eq!(pool.shared_pages(), 0);
        // ...then appends within its now-private page 1: no further COW.
        b.grow(&[6], &[7]).unwrap();
        assert_eq!(pool.cow_copies(), 2);
        assert_eq!(pool.refs_of(shared), Some(1), "a's copy is private again");
        drop(a);
        drop(b);
        assert_eq!(pool.live_pages(), 0);
    }

    #[test]
    fn migrate_moves_only_unshared_pages_and_charges_exact_traffic() {
        let pool = paged(0, 0, 64);
        let mut a = table(&pool, 2);
        a.grow(&[0, 0], &[8, 8]).unwrap(); // 4 pages
        let b = a.share_prefix(4); // 1 page/layer shared
        assert_eq!(pool.shared_pages(), 2);

        // a suspends to host: only its 2 unshared pages move.
        assert_eq!(a.migratable_bytes(Tier::Host), 2 * 64);
        let moved = a.migrate(Tier::Host).unwrap();
        assert_eq!(moved, 2);
        // Traffic charged = page_bytes * pages_moved, nothing more.
        assert_eq!(pool.pool().migrated_into(Tier::Host), 2 * 64);
        assert_eq!(pool.pool().in_use_of(Tier::Host), 2 * 64);
        assert_eq!(pool.pool().in_use_of(Tier::Device), 4 * 64, "b's pages + shared pages stay");
        // Shared pages stayed on device.
        for l in 0..2 {
            assert_eq!(pool.tier_of(a.layer_pages(l)[0]), Some(Tier::Device));
            assert_eq!(pool.tier_of(a.layer_pages(l)[1]), Some(Tier::Host));
        }

        // Resume: the same 2 pages move back.
        let back = a.migrate(Tier::Device).unwrap();
        assert_eq!(back, 2);
        assert_eq!(pool.pool().migrated_into(Tier::Device), 2 * 64);
        assert_eq!(pool.pool().in_use_of(Tier::Host), 0);
        drop(a);
        drop(b);
        assert_eq!(pool.pool().in_use(), 0);
    }

    #[test]
    fn migrate_oom_changes_nothing() {
        let pool = paged(0, 64, 64); // host fits one page
        let mut t = table(&pool, 1);
        t.grow(&[0], &[8]).unwrap(); // 2 pages
        let err = t.migrate(Tier::Host).unwrap_err();
        assert_eq!(err.tier, Tier::Host);
        assert_eq!(t.tier(), Tier::Device);
        assert_eq!(pool.pool().in_use_of(Tier::Device), 2 * 64);
        assert_eq!(pool.pool().in_use_of(Tier::Host), 0);
        assert_eq!(pool.pool().migrated_total(), 0);
    }

    #[test]
    fn for_cache_quantizes_per_layer_lengths() {
        let pool = paged(0, 0, 64);
        // row_elems 2 -> token_bytes 16 -> 4 slots/page.
        let mut cache = SequenceCache::new(3, 2);
        for l in 0..3 {
            for i in 0..(l * 3 + 1) {
                cache.append(l, &[0.0; 2], &[0.0; 2], i as u32).unwrap();
            }
        }
        // Lens 1, 4, 7 -> 1 + 1 + 2 pages.
        let t = PageTable::for_cache(&pool, Tier::Device, &cache).unwrap();
        assert_eq!(t.layer_pages(0).len(), 1);
        assert_eq!(t.layer_pages(1).len(), 1);
        assert_eq!(t.layer_pages(2).len(), 2);
        assert_eq!(t.bytes(), 4 * 64);
        assert_eq!(t.grow_bytes_for(&[5, 5, 8]), 2 * 64);
        assert_eq!(pool.allocated_bytes_of(Tier::Device), 4 * 64);
    }

    #[test]
    fn tiny_pages_clamp_to_one_slot() {
        let pool = paged(0, 0, 8); // page smaller than a 16-byte token
        let t = PageTable::new(&pool, Tier::Device, 1, 16);
        assert_eq!(t.slots_per_page(), 1);
    }
}
