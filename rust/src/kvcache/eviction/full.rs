//! Full Cache: the no-eviction reference point (dashed line in Fig. 3).

use super::EvictionPolicy;
use crate::kvcache::cache::SlotMeta;

pub struct FullCache;

impl EvictionPolicy for FullCache {
    fn name(&self) -> &'static str {
        "full"
    }

    /// Ignores the budget entirely — Full Cache keeps everything. The engine
    /// must pair this policy with an unbounded budget / largest tier.
    fn keep(&self, meta: &[SlotMeta], _budget: usize) -> Vec<usize> {
        (0..meta.len()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::eviction::mk_meta;

    #[test]
    fn keeps_everything() {
        let meta = mk_meta(10);
        assert_eq!(FullCache.keep(&meta, 3).len(), 10);
        assert_eq!(FullCache.keep(&meta, 100).len(), 10);
    }
}
