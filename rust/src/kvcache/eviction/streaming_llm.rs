//! StreamingLLM (Xiao et al. 2023): attention sinks. Keep the first `sinks`
//! tokens of the sequence (paper recommends n=4) plus the most recent
//! `budget - sinks` tokens.
//!
//! "First tokens of the sequence" means smallest *original positions*, which
//! after compaction are simply the lowest current indices — eviction never
//! reorders slots.

use super::EvictionPolicy;
use crate::kvcache::cache::SlotMeta;

pub struct StreamingLlm {
    sinks: usize,
}

impl StreamingLlm {
    pub fn new(sinks: usize) -> Self {
        Self { sinks }
    }
}

impl EvictionPolicy for StreamingLlm {
    fn name(&self) -> &'static str {
        "streaming_llm"
    }

    fn keep(&self, meta: &[SlotMeta], budget: usize) -> Vec<usize> {
        let n = meta.len();
        if n <= budget {
            return (0..n).collect();
        }
        let sinks = self.sinks.min(budget);
        let recent = budget - sinks;
        let mut keep: Vec<usize> = (0..sinks).collect();
        keep.extend(n - recent..n);
        keep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::eviction::mk_meta;

    #[test]
    fn sinks_plus_recent() {
        let meta = mk_meta(10);
        let keep = StreamingLlm::new(4).keep(&meta, 6);
        assert_eq!(keep, vec![0, 1, 2, 3, 8, 9]);
    }

    #[test]
    fn budget_smaller_than_sinks() {
        let meta = mk_meta(10);
        let keep = StreamingLlm::new(4).keep(&meta, 2);
        assert_eq!(keep, vec![0, 1]);
    }

    #[test]
    fn under_budget_identity() {
        let meta = mk_meta(5);
        assert_eq!(StreamingLlm::new(4).keep(&meta, 8), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn exact_budget_boundary() {
        let meta = mk_meta(6);
        assert_eq!(StreamingLlm::new(4).keep(&meta, 6).len(), 6);
    }
}
