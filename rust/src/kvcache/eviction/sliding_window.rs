//! Sliding Window Attention (Beltagy et al. 2020): keep only the most
//! recent `budget` tokens — the "Local" strategy. The paper's best baseline
//! for Mistral/Mixtral, whose pretraining used windowed attention.

use super::EvictionPolicy;
use crate::kvcache::cache::SlotMeta;

pub struct SlidingWindow;

impl EvictionPolicy for SlidingWindow {
    fn name(&self) -> &'static str {
        "sliding_window"
    }

    fn keep(&self, meta: &[SlotMeta], budget: usize) -> Vec<usize> {
        let n = meta.len();
        let start = n.saturating_sub(budget);
        (start..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::eviction::mk_meta;

    #[test]
    fn keeps_most_recent() {
        let meta = mk_meta(10);
        assert_eq!(SlidingWindow.keep(&meta, 3), vec![7, 8, 9]);
    }

    #[test]
    fn under_budget_identity() {
        let meta = mk_meta(2);
        assert_eq!(SlidingWindow.keep(&meta, 5), vec![0, 1]);
    }

    #[test]
    fn zero_budget_empty() {
        let meta = mk_meta(4);
        assert!(SlidingWindow.keep(&meta, 0).is_empty());
    }
}
