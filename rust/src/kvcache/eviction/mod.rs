//! Sequence-wise KV eviction policies — the paper's baselines.
//!
//! A policy is a pure function from slot metadata to a keep-set: given the
//! per-slot `(position, accumulated attention score)` of one layer and that
//! layer's budget, return the (strictly ascending) indices to keep. The
//! engine applies the same policy per layer with *different* budgets once
//! SqueezeAttention has reallocated them — the policies themselves are
//! budget-agnostic, which is exactly the orthogonality the paper exploits.

mod full;
mod h2o;
mod sliding_window;
mod streaming_llm;

pub use full::FullCache;
pub use h2o::H2o;
pub use sliding_window::SlidingWindow;
pub use streaming_llm::StreamingLlm;

use crate::config::{PolicyKind, ServeConfig};
use crate::kvcache::cache::SlotMeta;

/// A sequence-wise KV-cache compressor (`C_seq` in Algorithm 1).
pub trait EvictionPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Indices (strictly ascending) of slots to keep; `len() <= budget`
    /// whenever `budget <= meta.len()`, and identity when under budget.
    fn keep(&self, meta: &[SlotMeta], budget: usize) -> Vec<usize>;

    /// Whether this policy consumes the decode attention-mass signal.
    fn needs_scores(&self) -> bool {
        false
    }
}

/// Instantiate the policy selected by a serve config.
pub fn make_policy(cfg: &ServeConfig) -> Box<dyn EvictionPolicy> {
    match cfg.policy {
        PolicyKind::Full => Box::new(FullCache),
        PolicyKind::SlidingWindow => Box::new(SlidingWindow),
        PolicyKind::StreamingLlm => Box::new(StreamingLlm::new(cfg.sinks)),
        PolicyKind::H2o => Box::new(H2o::new(cfg.h2o_recent_frac)),
    }
}

#[cfg(test)]
pub(crate) fn mk_meta(n: usize) -> Vec<SlotMeta> {
    (0..n).map(|i| SlotMeta { position: i as u32, score: 0.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;

    #[test]
    fn factory_matches_kind() {
        let mut cfg = ServeConfig::new("x");
        for (kind, name) in [
            (PolicyKind::Full, "full"),
            (PolicyKind::SlidingWindow, "sliding_window"),
            (PolicyKind::StreamingLlm, "streaming_llm"),
            (PolicyKind::H2o, "h2o"),
        ] {
            cfg.policy = kind;
            assert_eq!(make_policy(&cfg).name(), name);
        }
    }
}
