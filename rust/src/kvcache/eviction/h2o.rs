//! Heavy-Hitter Oracle (Zhang et al. 2024): rank tokens by accumulated
//! attention mass and keep the heavy hitters, alongside a recency window
//! (H2O keeps `budget/2` recent + `budget/2` top-score by default).
//!
//! The score signal comes for free from the decode kernel (per-slot
//! probability mass summed over heads), accumulated into `SlotMeta.score` by
//! the engine after every step.

use super::EvictionPolicy;
use crate::kvcache::cache::SlotMeta;

pub struct H2o {
    /// Fraction of the budget reserved for the most recent tokens.
    recent_frac: f64,
}

impl H2o {
    pub fn new(recent_frac: f64) -> Self {
        Self { recent_frac: recent_frac.clamp(0.0, 1.0) }
    }
}

impl EvictionPolicy for H2o {
    fn name(&self) -> &'static str {
        "h2o"
    }

    fn needs_scores(&self) -> bool {
        true
    }

    fn keep(&self, meta: &[SlotMeta], budget: usize) -> Vec<usize> {
        let n = meta.len();
        if n <= budget {
            return (0..n).collect();
        }
        let recent = ((budget as f64 * self.recent_frac).round() as usize).min(budget);
        let heavy = budget - recent;
        let recent_start = n - recent;

        // Top-`heavy` scores among the non-recent prefix; ties broken toward
        // older tokens (stable heavy-hitter behaviour). The comparator is a
        // strict total order (slot index breaks score ties), so an O(n)
        // selection of the top `heavy` yields exactly the same set as a full
        // sort + take — only the order within the set differs, and the final
        // sort_unstable erases that.
        let cmp = |a: &usize, b: &usize| {
            meta[*b].score
                .partial_cmp(&meta[*a].score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(b))
        };
        let mut prefix: Vec<usize> = (0..recent_start).collect();
        if heavy > 0 {
            // n > budget guarantees recent_start > heavy, so heavy - 1 is in
            // bounds and there is always at least one element past the pivot.
            prefix.select_nth_unstable_by(heavy - 1, cmp);
        }
        prefix.truncate(heavy);
        let mut keep = prefix;
        keep.extend(recent_start..n);
        keep.sort_unstable();
        keep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::cache::SlotMeta;
    use crate::kvcache::eviction::mk_meta;

    fn meta_with_scores(scores: &[f64]) -> Vec<SlotMeta> {
        scores
            .iter()
            .enumerate()
            .map(|(i, &s)| SlotMeta { position: i as u32, score: s })
            .collect()
    }

    #[test]
    fn keeps_heavy_hitters_and_recent() {
        // 8 slots; slot 1 and 3 are heavy. budget 4, half recent.
        let meta = meta_with_scores(&[0.0, 9.0, 0.1, 8.0, 0.2, 0.0, 0.0, 0.0]);
        let keep = H2o::new(0.5).keep(&meta, 4);
        assert_eq!(keep, vec![1, 3, 6, 7]);
    }

    #[test]
    fn pure_recency_when_frac_one() {
        let meta = meta_with_scores(&[9.0, 9.0, 9.0, 0.0, 0.0]);
        let keep = H2o::new(1.0).keep(&meta, 2);
        assert_eq!(keep, vec![3, 4]);
    }

    #[test]
    fn pure_heavy_when_frac_zero() {
        let meta = meta_with_scores(&[1.0, 9.0, 2.0, 8.0, 3.0]);
        let keep = H2o::new(0.0).keep(&meta, 2);
        assert_eq!(keep, vec![1, 3]);
    }

    #[test]
    fn tie_break_prefers_older() {
        let meta = meta_with_scores(&[5.0, 5.0, 5.0, 5.0]);
        let keep = H2o::new(0.0).keep(&meta, 2);
        assert_eq!(keep, vec![0, 1]);
    }

    #[test]
    fn under_budget_identity() {
        let meta = mk_meta(3);
        assert_eq!(H2o::new(0.5).keep(&meta, 10), vec![0, 1, 2]);
    }

    #[test]
    fn selection_matches_full_sort_reference() {
        // The O(n) selection must pick exactly the set a full sort would.
        fn reference_keep(meta: &[SlotMeta], budget: usize, frac: f64) -> Vec<usize> {
            let n = meta.len();
            if n <= budget {
                return (0..n).collect();
            }
            let recent = ((budget as f64 * frac).round() as usize).min(budget);
            let heavy = budget - recent;
            let recent_start = n - recent;
            let mut prefix: Vec<usize> = (0..recent_start).collect();
            prefix.sort_by(|&a, &b| {
                meta[b].score
                    .partial_cmp(&meta[a].score)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            let mut keep: Vec<usize> = prefix.into_iter().take(heavy).collect();
            keep.extend(recent_start..n);
            keep.sort_unstable();
            keep
        }
        let mut rng = crate::util::Rng::seed_from_u64(0x42);
        for case in 0..200 {
            let n = 1 + rng.below(40);
            // Coarse scores force plenty of exact ties to exercise the
            // index tie-break.
            let scores: Vec<f64> =
                (0..n).map(|_| (rng.below(5) as f64) * 0.5).collect();
            let meta = meta_with_scores(&scores);
            let budget = 1 + rng.below(n + 4);
            let frac = [0.0, 0.25, 0.5, 1.0][rng.below(4)];
            let got = H2o::new(frac).keep(&meta, budget);
            let want = reference_keep(&meta, budget, frac);
            assert_eq!(got, want, "case {case}: n={n} budget={budget} frac={frac}");
        }
    }

    #[test]
    fn result_sorted_and_bounded() {
        let meta = meta_with_scores(&[0.5, 0.1, 0.9, 0.3, 0.7, 0.2, 0.8, 0.4]);
        for budget in 1..8 {
            let keep = H2o::new(0.5).keep(&meta, budget);
            assert_eq!(keep.len(), budget);
            assert!(keep.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
