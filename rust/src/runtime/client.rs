//! PJRT runtime backend (compiled only with `--features pjrt`, which
//! additionally requires the external `xla` crate): loads HLO-text
//! artifacts, uploads the weight set once as device buffers, and exposes
//! typed `prefill` / `decode` calls.
//!
//! Pattern per /opt/xla-example: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute_b`. Executables are compiled lazily per
//! (kind, shape-tier) and memoized; weights are device-resident so a decode
//! step moves only the step tensors (tokens + KV cache).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::Manifest;

use super::tensor::{Tensor, TensorI32};
use super::{DecodeOut, PrefillOut, RuntimeStats};

/// A borrowed host array heading into an execution. Uploaded with
/// `buffer_from_host_buffer` (synchronous copy semantics), so the borrow only
/// needs to live for the duration of the call.
enum HostInput<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

impl HostInput<'_> {
    fn upload(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        match self {
            HostInput::F32(data, dims) => {
                Ok(client.buffer_from_host_buffer::<f32>(data, dims, None)?)
            }
            HostInput::I32(data, dims) => {
                Ok(client.buffer_from_host_buffer::<i32>(data, dims, None)?)
            }
        }
    }
}

pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    kernel: String,
    weights: Vec<xla::PjRtBuffer>,
    prefill_exes: Mutex<HashMap<usize, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    decode_exes: Mutex<HashMap<(usize, usize), std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<RuntimeStats>,
}

impl PjrtRuntime {
    /// Load manifest + weights from an artifact directory and bind a kernel
    /// variant ("pallas" — the shipped default — or "jnp" for the ablation).
    pub fn load(artifact_dir: &str, kernel: &str) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        if client.devices().is_empty() {
            return Err(anyhow!("no PJRT devices"));
        }
        let mut weights = Vec::new();
        for (entry, data) in manifest.load_weights()? {
            // buffer_from_host_buffer copies during the call
            // (kImmutableOnlyDuringCall) — buffer_from_host_literal is async
            // and reads the literal after we would have freed it.
            weights.push(client.buffer_from_host_buffer::<f32>(&data, &entry.shape, None)?);
        }
        Ok(Self {
            client,
            manifest,
            kernel: kernel.to_string(),
            weights,
            prefill_exes: Mutex::new(HashMap::new()),
            decode_exes: Mutex::new(HashMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().unwrap().clone()
    }

    /// Smallest prefill bucket >= `len`.
    fn prefill_bucket_for(&self, len: usize) -> Result<usize> {
        self.manifest
            .prefill_buckets(&self.kernel)
            .into_iter()
            .find(|&b| b >= len)
            .ok_or_else(|| anyhow!("prompt of {len} tokens exceeds largest prefill bucket"))
    }

    fn compile(&self, file: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(file)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.stats.lock().unwrap().compile_secs += t0.elapsed().as_secs_f64();
        Ok(exe)
    }

    fn prefill_exe(&self, bucket: usize) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.prefill_exes.lock().unwrap().get(&bucket) {
            return Ok(e.clone());
        }
        let entry = self.manifest.find_prefill(&self.kernel, bucket)?;
        let exe = std::sync::Arc::new(self.compile(&self.manifest.artifact_path(entry))?);
        self.prefill_exes.lock().unwrap().insert(bucket, exe.clone());
        Ok(exe)
    }

    fn decode_exe(&self, tier: (usize, usize)) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.decode_exes.lock().unwrap().get(&tier) {
            return Ok(e.clone());
        }
        let entry = self.manifest.find_decode(&self.kernel, tier.0, tier.1)?;
        let exe = std::sync::Arc::new(self.compile(&self.manifest.artifact_path(entry))?);
        self.decode_exes.lock().unwrap().insert(tier, exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every artifact of the bound kernel (warmup).
    pub fn compile_all(&self) -> Result<()> {
        for b in self.manifest.prefill_buckets(&self.kernel) {
            self.prefill_exe(b)?;
        }
        for t in self.manifest.decode_tiers(&self.kernel) {
            self.decode_exe(t)?;
        }
        Ok(())
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        step_inputs: &[HostInput<'_>],
    ) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        let step_bufs: Vec<xla::PjRtBuffer> = step_inputs
            .iter()
            .map(|h| h.upload(&self.client))
            .collect::<Result<_>>()?;
        args.extend(step_bufs.iter());
        let h2d = t0.elapsed().as_secs_f64();
        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
        let t1 = Instant::now();
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        let mut s = self.stats.lock().unwrap();
        s.h2d_secs += h2d;
        s.d2h_secs += t1.elapsed().as_secs_f64();
        Ok(outs)
    }

    /// Run prefill for a prompt (padded internally to the bucket size).
    ///
    /// Returned K/V/cos tensors are sliced views over the *bucket* length;
    /// callers should only read the first `prompt.len()` positions.
    pub fn prefill(&self, prompt: &[i32]) -> Result<PrefillOut> {
        let bucket = self.prefill_bucket_for(prompt.len())?;
        let exe = self.prefill_exe(bucket)?;
        let mut toks = prompt.to_vec();
        toks.resize(bucket, 0);
        let vlen = [prompt.len() as i32];
        let t0 = Instant::now();
        let outs = self.run(
            &exe,
            &[
                HostInput::I32(&toks, &[bucket]),
                HostInput::I32(&vlen, &[]),
            ],
        )?;
        if outs.len() != 4 {
            return Err(anyhow!("prefill returned {} outputs, want 4", outs.len()));
        }
        let out = PrefillOut {
            logits: Tensor::from_literal(&outs[0])?,
            k: Tensor::from_literal(&outs[1])?,
            v: Tensor::from_literal(&outs[2])?,
            cos_sims: Tensor::from_literal(&outs[3])?,
        };
        let mut s = self.stats.lock().unwrap();
        s.prefill_calls += 1;
        s.prefill_secs += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Run one decode step on tier `(B, M)`.
    ///
    /// * `tokens`, `positions`: `[B]`
    /// * `k_cache`, `v_cache`: `[n_layer, B, M, H, D]`
    /// * `cache_lens`: `[n_layer, B]`, each strictly `< M` for active slots
    ///   (the step appends the new token's KV at slot `len` internally).
    pub fn decode(
        &self,
        tier: (usize, usize),
        tokens: &TensorI32,
        positions: &TensorI32,
        k_cache: &Tensor,
        v_cache: &Tensor,
        cache_lens: &TensorI32,
    ) -> Result<DecodeOut> {
        let exe = self.decode_exe(tier)?;
        let t0 = Instant::now();
        let outs = self.run(
            &exe,
            &[
                HostInput::I32(&tokens.data, &tokens.shape),
                HostInput::I32(&positions.data, &positions.shape),
                HostInput::F32(&k_cache.data, &k_cache.shape),
                HostInput::F32(&v_cache.data, &v_cache.shape),
                HostInput::I32(&cache_lens.data, &cache_lens.shape),
            ],
        )?;
        if outs.len() != 4 {
            return Err(anyhow!("decode returned {} outputs, want 4", outs.len()));
        }
        let out = DecodeOut {
            logits: Tensor::from_literal(&outs[0])?,
            new_k: Tensor::from_literal(&outs[1])?,
            new_v: Tensor::from_literal(&outs[2])?,
            scores: Tensor::from_literal(&outs[3])?,
        };
        let mut s = self.stats.lock().unwrap();
        s.decode_calls += 1;
        s.decode_secs += t0.elapsed().as_secs_f64();
        Ok(out)
    }
}
