//! Minimal host-side tensors used at the runtime boundary.
//!
//! The coordinator keeps all KV state in plain `Vec<f32>`-backed tensors and
//! converts to/from `xla::Literal` only at the execute boundary (PJRT builds
//! only); everything in between (append, evict, compact) is cheap slice
//! manipulation.

use anyhow::{anyhow, Result};

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} wants {} elems, got {}", shape, n, data.len()));
        }
        Ok(Self { shape: shape.to_vec(), data })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Flat index from a multi-index.
    pub fn flat(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut f = 0;
        for (i, &x) in idx.iter().enumerate() {
            debug_assert!(x < self.shape[i], "index {idx:?} out of {:?}", self.shape);
            f = f * self.shape[i] + x;
        }
        f
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.flat(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let f = self.flat(idx);
        self.data[f] = v;
    }

    /// Convert to an XLA literal of this shape.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Build from an XLA literal (must be f32).
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Self::from_vec(&dims, data)
    }
}

/// A dense row-major i32 tensor (token ids, positions, lengths).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} wants {} elems, got {}", shape, n, data.len()));
        }
        Ok(Self { shape: shape.to_vec(), data })
    }

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_indexing_row_major() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set(&[1, 2, 3], 7.5);
        assert_eq!(t.flat(&[1, 2, 3]), 1 * 12 + 2 * 4 + 3);
        assert_eq!(t.at(&[1, 2, 3]), 7.5);
        assert_eq!(t.numel(), 24);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 4]).is_ok());
        assert!(TensorI32::from_vec(&[3], vec![1, 2]).is_err());
    }
}
