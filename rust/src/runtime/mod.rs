//! Layer-3 ↔ XLA boundary: PJRT client wrapper, typed prefill/decode calls,
//! and the host tensor types that carry KV state between steps.

mod client;
mod tensor;

pub use client::{DecodeOut, PrefillOut, Runtime, RuntimeStats};
pub use tensor::{Tensor, TensorI32};
