//! Layer-3 ↔ model-execution boundary: typed prefill/decode calls, the host
//! tensor types that carry KV state between steps, and pluggable backends.
//!
//! Two backends sit behind the one `Runtime` type:
//!
//! * **sim** (always available) — a deterministic simulated model selected
//!   by the `sim://<name>` artifact scheme (`sim://tiny`). Runs the whole
//!   coordinator hermetically with no compiled artifacts; this is what the
//!   test tier exercises.
//! * **pjrt** (`--features pjrt`, additionally requires the external `xla`
//!   crate) — the real PJRT client over AOT HLO-text artifacts produced by
//!   `python/compile/aot.py`, selected by an on-disk artifact directory.

#[cfg(feature = "pjrt")]
mod client;
mod sim;
mod tensor;

use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::Manifest;

pub use sim::{FaultDecision, FaultPlan, SimModel};
pub use tensor::{Tensor, TensorI32};

/// Outputs of one prefill call.
#[derive(Debug, Clone)]
pub struct PrefillOut {
    /// `[vocab]` next-token logits at the last valid prompt position.
    pub logits: Tensor,
    /// `[n_layer, L, H, D]` — K cache (RoPE applied).
    pub k: Tensor,
    /// `[n_layer, L, H, D]` — V cache.
    pub v: Tensor,
    /// `[n_layer, L]` — cosine similarity across each attention block.
    pub cos_sims: Tensor,
}

/// Outputs of one batched decode step.
#[derive(Debug, Clone)]
pub struct DecodeOut {
    /// `[B, vocab]`.
    pub logits: Tensor,
    /// `[n_layer, B, H, D]` — K row for the token just processed.
    pub new_k: Tensor,
    /// `[n_layer, B, H, D]`.
    pub new_v: Tensor,
    /// `[n_layer, B, M]` — per-slot attention mass (H2O signal).
    pub scores: Tensor,
}

/// Cumulative runtime counters (perf pass instrumentation).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub prefill_calls: u64,
    pub decode_calls: u64,
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub h2d_secs: f64,
    pub d2h_secs: f64,
    pub compile_secs: f64,
}

enum Backend {
    Sim(SimModel),
    #[cfg(feature = "pjrt")]
    Pjrt(client::PjrtRuntime),
}

/// Armed fault-injection state: the plan plus the decode-call counter it is
/// evaluated against and how many faults actually fired.
#[derive(Debug, Default)]
struct FaultState {
    plan: Option<FaultPlan>,
    calls: u64,
    injected: u64,
}

pub struct Runtime {
    pub manifest: Manifest,
    kernel: String,
    backend: Backend,
    stats: Mutex<RuntimeStats>,
    faults: Mutex<FaultState>,
}

impl Runtime {
    /// Load a backend for `artifact_dir` and bind a kernel variant ("pallas"
    /// — the shipped default — or "jnp" for the ablation). `sim://<name>`
    /// selects the simulated backend; anything else is an on-disk artifact
    /// directory for the PJRT backend.
    pub fn load(artifact_dir: &str, kernel: &str) -> Result<Self> {
        if let Some(spec) = artifact_dir.strip_prefix("sim://") {
            let model = SimModel::new(spec)?;
            let manifest = model.manifest().clone();
            return Ok(Self {
                manifest,
                kernel: kernel.to_string(),
                backend: Backend::Sim(model),
                stats: Mutex::new(RuntimeStats::default()),
                faults: Mutex::new(FaultState::default()),
            });
        }
        Self::load_disk(artifact_dir, kernel)
    }

    /// On-disk artifact directory → PJRT backend.
    #[cfg(feature = "pjrt")]
    fn load_disk(artifact_dir: &str, kernel: &str) -> Result<Self> {
        let inner = client::PjrtRuntime::load(artifact_dir, kernel)?;
        let manifest = inner.manifest.clone();
        Ok(Self {
            manifest,
            kernel: kernel.to_string(),
            backend: Backend::Pjrt(inner),
            stats: Mutex::new(RuntimeStats::default()),
            faults: Mutex::new(FaultState::default()),
        })
    }

    /// Without the `pjrt` feature there is no backend for on-disk artifacts.
    #[cfg(not(feature = "pjrt"))]
    fn load_disk(artifact_dir: &str, _kernel: &str) -> Result<Self> {
        Err(anyhow!(
            "artifact dir '{artifact_dir}' needs the PJRT backend (build with \
             --features pjrt and the xla crate), or use the sim:// scheme \
             (e.g. sim://tiny)"
        ))
    }

    pub fn kernel(&self) -> &str {
        &self.kernel
    }

    /// Arm (or with `None` disarm) deterministic fault injection on the
    /// decode path. Resets the decode-call counter, so re-arming the same
    /// plan replays the identical fault sequence. Injection is evaluated
    /// for the sim backend only — the PJRT backend produces its own,
    /// non-simulated faults.
    pub fn set_fault_plan(&self, plan: Option<FaultPlan>) {
        let mut st = self.faults.lock().unwrap();
        *st = FaultState { plan, calls: 0, injected: 0 };
    }

    /// Faults actually injected (errors + latency spikes) since the plan
    /// was last armed.
    pub fn faults_injected(&self) -> u64 {
        self.faults.lock().unwrap().injected
    }

    /// Evaluate the armed fault plan for the next decode call. Returns the
    /// error to inject, after serving any latency spike inline.
    fn check_fault(&self) -> Result<()> {
        let decision = {
            let mut st = self.faults.lock().unwrap();
            let Some(plan) = st.plan.as_ref() else { return Ok(()) };
            st.calls += 1;
            let d = plan.decide(st.calls);
            if d.is_some() {
                st.injected += 1;
            }
            d
        };
        match decision {
            None => Ok(()),
            Some(FaultDecision::LatencySpikeMs(ms)) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                Ok(())
            }
            Some(FaultDecision::StepError) => {
                Err(anyhow!("injected fault: backend step error"))
            }
            Some(FaultDecision::Oom) => {
                Err(anyhow!("injected fault: simulated device allocator OOM"))
            }
        }
    }

    pub fn stats(&self) -> RuntimeStats {
        match &self.backend {
            Backend::Sim(_) => self.stats.lock().unwrap().clone(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.stats(),
        }
    }

    /// Smallest prefill bucket >= `len`.
    pub fn prefill_bucket_for(&self, len: usize) -> Result<usize> {
        self.manifest
            .prefill_buckets(&self.kernel)
            .into_iter()
            .find(|&b| b >= len)
            .ok_or_else(|| anyhow!("prompt of {len} tokens exceeds largest prefill bucket"))
    }

    /// Smallest decode capacity tier with batch == `batch` and cap >= `cap`.
    pub fn decode_tier_for(&self, batch: usize, cap: usize) -> Result<(usize, usize)> {
        self.manifest
            .decode_tiers(&self.kernel)
            .into_iter()
            .filter(|&(b, m)| b == batch && m >= cap)
            .min_by_key(|&(_, m)| m)
            .ok_or_else(|| anyhow!("no decode tier batch={batch} cap>={cap}"))
    }

    /// Decode batch sizes available for this kernel.
    pub fn decode_batches(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .manifest
            .decode_tiers(&self.kernel)
            .into_iter()
            .map(|(b, _)| b)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Eagerly compile every artifact of the bound kernel (warmup). The sim
    /// backend has nothing to compile.
    pub fn compile_all(&self) -> Result<()> {
        match &self.backend {
            Backend::Sim(_) => Ok(()),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.compile_all(),
        }
    }

    /// Run prefill for a prompt (padded internally to the bucket size).
    ///
    /// Returned K/V/cos tensors are sliced views over the *bucket* length;
    /// callers should only read the first `prompt.len()` positions.
    pub fn prefill(&self, prompt: &[i32]) -> Result<PrefillOut> {
        match &self.backend {
            Backend::Sim(m) => {
                let bucket = self.prefill_bucket_for(prompt.len())?;
                let t0 = Instant::now();
                let out = m.prefill(prompt, bucket)?;
                let mut s = self.stats.lock().unwrap();
                s.prefill_calls += 1;
                s.prefill_secs += t0.elapsed().as_secs_f64();
                Ok(out)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.prefill(prompt),
        }
    }

    /// Run one decode step on tier `(B, M)`.
    ///
    /// * `tokens`, `positions`: `[B]`
    /// * `k_cache`, `v_cache`: `[n_layer, B, M, H, D]`
    /// * `cache_lens`: `[n_layer, B]`, each strictly `< M` for active slots
    ///   (the step appends the new token's KV at slot `len` internally).
    pub fn decode(
        &self,
        tier: (usize, usize),
        tokens: &TensorI32,
        positions: &TensorI32,
        k_cache: &Tensor,
        v_cache: &Tensor,
        cache_lens: &TensorI32,
    ) -> Result<DecodeOut> {
        match &self.backend {
            Backend::Sim(m) => {
                self.check_fault()?;
                let t0 = Instant::now();
                let out = m.decode(tier, tokens, positions, k_cache, v_cache, cache_lens)?;
                let mut s = self.stats.lock().unwrap();
                s.decode_calls += 1;
                s.decode_secs += t0.elapsed().as_secs_f64();
                Ok(out)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.decode(tier, tokens, positions, k_cache, v_cache, cache_lens),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_scheme_loads_and_queries() {
        let rt = Runtime::load("sim://tiny", "pallas").unwrap();
        assert_eq!(rt.kernel(), "pallas");
        assert_eq!(rt.prefill_bucket_for(100).unwrap(), 128);
        assert_eq!(rt.decode_tier_for(8, 100).unwrap(), (8, 128));
        assert_eq!(rt.decode_batches(), vec![1, 2, 4, 8]);
        assert!(rt.prefill_bucket_for(600).is_err());
        rt.compile_all().unwrap();
    }

    #[test]
    fn sim_prefill_decode_roundtrip_counts_stats() {
        let rt = Runtime::load("sim://tiny", "pallas").unwrap();
        let pre = rt.prefill(&[256, 3, 4, 257]).unwrap();
        assert_eq!(pre.logits.shape, vec![272]);
        assert_eq!(pre.k.shape, vec![8, 64, 4, 32]);
        let tokens = TensorI32::from_vec(&[1], vec![7]).unwrap();
        let positions = TensorI32::from_vec(&[1], vec![4]).unwrap();
        let k = Tensor::zeros(&[8, 1, 64, 4, 32]);
        let v = Tensor::zeros(&[8, 1, 64, 4, 32]);
        let lens = TensorI32::from_vec(&[8, 1], vec![0; 8]).unwrap();
        let out = rt.decode((1, 64), &tokens, &positions, &k, &v, &lens).unwrap();
        assert_eq!(out.logits.shape, vec![1, 272]);
        let s = rt.stats();
        assert_eq!(s.prefill_calls, 1);
        assert_eq!(s.decode_calls, 1);
    }

    #[test]
    fn disk_artifacts_without_pjrt_feature_error() {
        #[cfg(not(feature = "pjrt"))]
        assert!(Runtime::load("artifacts/tiny", "pallas").is_err());
    }

    #[test]
    fn unknown_sim_model_errors() {
        assert!(Runtime::load("sim://nope", "pallas").is_err());
    }

    #[test]
    fn fault_plan_injects_on_exact_call_and_rearms() {
        let rt = Runtime::load("sim://tiny", "pallas").unwrap();
        let plan = FaultPlan {
            seed: 1,
            step_error_rate: 0.0,
            latency_spike_ms: 0,
            latency_spike_rate: 0.0,
            oom_at: 2,
        };
        rt.set_fault_plan(Some(plan.clone()));
        let tokens = TensorI32::from_vec(&[1], vec![7]).unwrap();
        let positions = TensorI32::from_vec(&[1], vec![4]).unwrap();
        let k = Tensor::zeros(&[8, 1, 64, 4, 32]);
        let v = Tensor::zeros(&[8, 1, 64, 4, 32]);
        let lens = TensorI32::from_vec(&[8, 1], vec![0; 8]).unwrap();
        let mut call = || rt.decode((1, 64), &tokens, &positions, &k, &v, &lens);
        assert!(call().is_ok());
        let err = call().unwrap_err().to_string();
        assert!(err.contains("injected fault"), "{err}");
        assert!(call().is_ok());
        assert_eq!(rt.faults_injected(), 1);
        // Re-arming replays the same sequence from call 1.
        rt.set_fault_plan(Some(plan));
        assert_eq!(rt.faults_injected(), 0);
        assert!(call().is_ok());
        assert!(call().is_err());
        // Disarm: no more faults, counter reset.
        rt.set_fault_plan(None);
        assert!(call().is_ok());
        assert_eq!(rt.faults_injected(), 0);
    }
}
