//! Deterministic simulated runtime backend (`sim://` artifact scheme).
//!
//! Stands in for the PJRT/XLA runtime when no compiled artifacts (or no
//! `xla` crate) are available, so the whole coordinator — scheduler, KV
//! pool, eviction, budget allocation, router, TCP server — can run and be
//! tested hermetically. It is a *toy transformer-shaped* model, not a
//! trained one:
//!
//! * K/V rows, queries and the unembedding are pseudo-random but pure
//!   functions of `(token, layer, element)` via 64-bit integer mixing, so
//!   every call is bit-reproducible and never touches libm.
//! * The decode step computes a real (unnormalized) attention reduction
//!   over exactly the cached rows it is handed, masked by `cache_lens`.
//!   Logits therefore depend on the precise cache contents — evicting a
//!   different token yields different generations, which is what makes the
//!   scheduler-parity and eviction tests meaningful.
//! * The cosine probe emits a three-band layer profile (important / middle /
//!   unimportant) with small token-dependent jitter, so Algorithm 1's
//!   k-means grouping reallocates budgets exactly as it would on a real
//!   model (paper Fig. 2's shape).
//!
//! The shape set mirrors the `artifacts/tiny` contract: 8 layers, 4 heads x
//! 32 dims, vocab 272, max_seq 640, prefill buckets {64,128,256,512} and
//! decode tiers {1,2,4,8} x {64,128,192,256,384,512,640}, published for both
//! the "pallas" and "jnp" kernel names (the sim math is kernel-independent,
//! which trivially satisfies the kernel-ablation equivalence the real
//! artifacts are tested for). `sim://long` keeps the per-token math but
//! stretches the shape table to max_seq 1536 (prefill buckets up to 1024,
//! decode caps up to 1536) so benches can exercise kilocontext decode.

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::config::{ArtifactEntry, Manifest, ModelCfg, TokenMap, WeightsIndex};

use super::tensor::{Tensor, TensorI32};
use super::{DecodeOut, PrefillOut};

const SALT_K: u64 = 0xA1B2_C3D4_E5F6_0001;
const SALT_V: u64 = 0xA1B2_C3D4_E5F6_0002;
const SALT_Q: u64 = 0xA1B2_C3D4_E5F6_0003;
const SALT_E: u64 = 0xA1B2_C3D4_E5F6_0004;
const SALT_S: u64 = 0xA1B2_C3D4_E5F6_0005;
const SALT_P: u64 = 0xA1B2_C3D4_E5F6_0006;
const SALT_C: u64 = 0xA1B2_C3D4_E5F6_0007;
const SALT_B: u64 = 0xA1B2_C3D4_E5F6_0008;
const SALT_D: u64 = 0xA1B2_C3D4_E5F6_0009;

/// SplitMix64 finalizer: uniform 64-bit mixing of an arbitrary key.
fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Map a mixed hash to [-1, 1).
fn unit(h: u64) -> f32 {
    ((h >> 11) as f64 * (2.0 / 9_007_199_254_740_992.0) - 1.0) as f32
}

/// Pseudo-random feature in [-1, 1) keyed by two indices and a salt.
fn feat(a: u64, b: u64, salt: u64) -> f32 {
    unit(mix(a ^ b.rotate_left(17) ^ salt))
}

const SALT_F_ERR: u64 = 0xA1B2_C3D4_E5F6_000A;
const SALT_F_LAT: u64 = 0xA1B2_C3D4_E5F6_000B;

/// Map a mixed hash to [0, 1).
fn frac(h: u64) -> f64 {
    (h >> 11) as f64 / 9_007_199_254_740_992.0
}

/// Deterministic fault-injection plan for the sim backend (chaos testing).
///
/// Whether decode call number `n` faults is a pure function of `(seed, n)`
/// via the same SplitMix64 mixing the model weights use, so a fixed config
/// reproduces the identical fault sequence on every run — the chaos suite's
/// token-identity assertions depend on this. The plan is engine-state-blind
/// by design: injection depends only on the call index, never on batch
/// contents, so retried work sees fresh coin flips instead of hitting the
/// same fault forever.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub seed: u64,
    pub step_error_rate: f64,
    pub latency_spike_ms: u64,
    pub latency_spike_rate: f64,
    pub oom_at: u64,
}

/// What [`FaultPlan::decide`] injects into one decode call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultDecision {
    /// Simulated allocator OOM (the `oom_at` exact-call trigger).
    Oom,
    /// Generic backend step error.
    StepError,
    /// Sleep this many milliseconds, then succeed normally.
    LatencySpikeMs(u64),
}

impl FaultPlan {
    pub fn from_config(f: &crate::config::FaultConfig) -> Self {
        Self {
            seed: f.seed,
            step_error_rate: f.step_error_rate,
            latency_spike_ms: f.latency_spike_ms,
            latency_spike_rate: f.latency_spike_rate,
            oom_at: f.oom_at,
        }
    }

    /// The fault (if any) for 1-based decode call number `call`.
    pub fn decide(&self, call: u64) -> Option<FaultDecision> {
        if self.oom_at != 0 && call == self.oom_at {
            return Some(FaultDecision::Oom);
        }
        if self.step_error_rate > 0.0
            && frac(mix(self.seed ^ call.rotate_left(23) ^ SALT_F_ERR)) < self.step_error_rate
        {
            return Some(FaultDecision::StepError);
        }
        if self.latency_spike_ms > 0
            && self.latency_spike_rate > 0.0
            && frac(mix(self.seed ^ call.rotate_left(23) ^ SALT_F_LAT)) < self.latency_spike_rate
        {
            return Some(FaultDecision::LatencySpikeMs(self.latency_spike_ms));
        }
        None
    }
}

pub struct SimModel {
    manifest: Manifest,
    n_layer: usize,
    n_head: usize,
    head_dim: usize,
    vocab: usize,
    /// n_head * head_dim — elements per K (or V) row.
    row: usize,
    /// Draft variant ("tiny-draft"): identical shapes and K/V hashing, but
    /// logits get a small deterministic nudge so greedy argmax agrees with
    /// the target model often — not always. That partial agreement is what
    /// speculative decoding amortizes.
    draft: bool,
}

impl SimModel {
    /// Build the named sim model. Three specs exist: "tiny" (the target
    /// shape; `sim://` with an empty tail also resolves to it), "tiny-draft"
    /// (same geometry, perturbed logits — the speculative draft model), and
    /// "long" (same per-token math but max_seq 1536 with 1k-token prefill
    /// buckets and kilocontext decode tiers — the hot-path bench geometry).
    pub fn new(spec: &str) -> Result<Self> {
        let draft = spec == "tiny-draft";
        let long = spec == "long";
        if !spec.is_empty() && spec != "tiny" && !draft && !long {
            return Err(anyhow!(
                "unknown sim model '{spec}' (available: tiny, tiny-draft, long)"
            ));
        }
        let (n_layer, n_head, head_dim, vocab) = (8usize, 4usize, 32usize, 272usize);
        let max_seq = if long { 1536usize } else { 640usize };
        let buckets: &[usize] =
            if long { &[64, 128, 256, 512, 1024] } else { &[64, 128, 256, 512] };
        let caps: &[usize] = if long {
            &[128, 256, 512, 768, 1088, 1536]
        } else {
            &[64, 128, 192, 256, 384, 512, 640]
        };
        let mut artifacts = Vec::new();
        for kernel in ["pallas", "jnp"] {
            for &len in buckets {
                artifacts.push(ArtifactEntry {
                    file: format!("sim_prefill_{kernel}_l{len}"),
                    kind: "prefill".to_string(),
                    kernel: kernel.to_string(),
                    len: Some(len),
                    batch: None,
                    cap: None,
                });
            }
            for batch in [1usize, 2, 4, 8] {
                for &cap in caps {
                    artifacts.push(ArtifactEntry {
                        file: format!("sim_decode_{kernel}_b{batch}_m{cap}"),
                        kind: "decode".to_string(),
                        kernel: kernel.to_string(),
                        len: None,
                        batch: Some(batch),
                        cap: Some(cap),
                    });
                }
            }
        }
        let name = if draft {
            "sim-tiny-draft"
        } else if long {
            "sim-long"
        } else {
            "sim-tiny"
        };
        let manifest = Manifest {
            model: ModelCfg {
                name: name.to_string(),
                n_layer,
                d_model: n_head * head_dim,
                n_head,
                vocab,
                ffn_mult: 4,
                max_seq,
                rope_theta: 10_000.0,
                head_dim,
            },
            trained: true,
            tokens: TokenMap {
                pad: 0,
                bos: 256,
                sep: 257,
                query: 258,
                answer: 259,
                eos: 260,
                mark: 261,
                equals: 262,
                comma: 263,
            },
            weights: WeightsIndex {
                file: String::new(),
                dtype: "f32".to_string(),
                index: Vec::new(),
            },
            artifacts,
            dir: PathBuf::new(),
        };
        Ok(Self { manifest, n_layer, n_head, head_dim, vocab, row: n_head * head_dim, draft })
    }

    /// Borrow the manifest. Callers that need ownership clone explicitly;
    /// the engine step path only ever reads shape fields, and cloning the
    /// full artifact table per step was pure waste.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn k_elem(&self, token: i32, layer: usize, j: usize) -> f32 {
        feat(token as u64, (layer * 997 + j) as u64, SALT_K)
    }

    fn v_elem(&self, token: i32, layer: usize, j: usize) -> f32 {
        feat(token as u64, (layer * 997 + j) as u64, SALT_V)
    }

    /// Per-layer cosine-probe band: a three-group profile with a small
    /// per-layer tilt so k-means sees clean, stable clusters.
    fn cos_base(&self, layer: usize) -> f32 {
        let band = layer * 3 / self.n_layer.max(1);
        let base = match band {
            0 => 0.16,
            1 => 0.52,
            _ => 0.88,
        };
        base + 0.01 * (layer % 3) as f32
    }

    /// Attention reduction for one query token over one layer's cached rows
    /// `(k_rows, v_rows)`, accumulating into `state` and writing |mass| into
    /// `scores[..len]`.
    fn attend_layer(
        &self,
        token: i32,
        layer: usize,
        rows: (&[f32], &[f32]),
        len: usize,
        state: &mut [f32],
        mut scores: Option<&mut [f32]>,
    ) {
        let (k_rows, v_rows) = rows;
        let row = self.row;
        let inv_row = 1.0f32 / row as f32;
        let inv_layer = 1.0f32 / self.n_layer as f32;
        let q: Vec<f32> = (0..row)
            .map(|j| feat(token as u64, (layer * 997 + j) as u64, SALT_Q))
            .collect();
        for i in 0..len {
            let k = &k_rows[i * row..(i + 1) * row];
            let mut w = 0.0f32;
            for (kj, qj) in k.iter().zip(&q) {
                w += kj * qj;
            }
            w *= inv_row;
            if let Some(s) = scores.as_deref_mut() {
                s[i] = w.abs();
            }
            let v = &v_rows[i * row..(i + 1) * row];
            let scale = w * inv_layer;
            for (sj, vj) in state.iter_mut().zip(v) {
                *sj += scale * vj;
            }
        }
    }

    /// Project an attention state to vocab logits, with the query token's
    /// own embedding and position folded in (so successive steps differ even
    /// over an unchanged cache) and a tiny per-token tiebreak bias.
    fn logits_from_state(&self, token: i32, position: i32, state: &mut [f32], out: &mut [f32]) {
        let row = self.row;
        for (j, s) in state.iter_mut().enumerate() {
            *s += 0.5 * feat(token as u64, j as u64, SALT_S)
                + 0.1 * feat(position as u64, j as u64, SALT_P);
        }
        let inv_row = 1.0f32 / row as f32;
        for (t, o) in out.iter_mut().enumerate() {
            let mut dot = 0.0f32;
            for (j, s) in state.iter().enumerate() {
                dot += *s * feat(t as u64, j as u64, SALT_E);
            }
            *o = dot * inv_row + 1e-3 * unit(mix(t as u64 ^ SALT_B));
        }
        if self.draft {
            // Draft-model nudge: comparable to the top-1/top-2 logit gap, so
            // the draft's greedy pick matches the target's at most positions
            // but diverges at some — giving speculative decode a realistic
            // mix of accepted prefixes and rollbacks.
            let key = (token as u64).wrapping_mul(1009).wrapping_add(position as u64);
            for (t, o) in out.iter_mut().enumerate() {
                *o += 2e-3 * feat(key, t as u64, SALT_D);
            }
        }
        // Greedy decoding must be length-deterministic for the scheduler
        // tests: push EOS far below the argmax range (it stays finite, so
        // temperature sampling can still terminate a sequence).
        let eos = crate::model::tokenizer::EOS as usize;
        if eos < out.len() {
            out[eos] -= 4.0;
        }
    }

    /// Prefill a prompt into a `bucket`-padded KV cache + cosine probe, with
    /// next-token logits at the last prompt position.
    pub fn prefill(&self, prompt: &[i32], bucket: usize) -> Result<PrefillOut> {
        let (nl, h, d, row) = (self.n_layer, self.n_head, self.head_dim, self.row);
        let plen = prompt.len();
        if plen == 0 || plen > bucket {
            return Err(anyhow!("sim prefill: prompt len {plen} does not fit bucket {bucket}"));
        }
        let mut k = Tensor::zeros(&[nl, bucket, h, d]);
        let mut v = Tensor::zeros(&[nl, bucket, h, d]);
        let mut cos = Tensor::zeros(&[nl, bucket]);
        for layer in 0..nl {
            for (i, &t) in prompt.iter().enumerate() {
                let base = (layer * bucket + i) * row;
                for j in 0..row {
                    k.data[base + j] = self.k_elem(t, layer, j);
                    v.data[base + j] = self.v_elem(t, layer, j);
                }
                cos.data[layer * bucket + i] =
                    self.cos_base(layer) + 0.08 * feat(t as u64, layer as u64, SALT_C);
            }
        }
        let last = prompt[plen - 1];
        let mut state = vec![0.0f32; row];
        for layer in 0..nl {
            let base = layer * bucket * row;
            self.attend_layer(
                last,
                layer,
                (&k.data[base..base + plen * row], &v.data[base..base + plen * row]),
                plen,
                &mut state,
                None,
            );
        }
        let mut logits = vec![0.0f32; self.vocab];
        self.logits_from_state(last, plen as i32 - 1, &mut state, &mut logits);
        Ok(PrefillOut {
            logits: Tensor::from_vec(&[self.vocab], logits)?,
            k,
            v,
            cos_sims: cos,
        })
    }

    /// One batched decode step on tier `(b, m)` — same contract as the XLA
    /// decode artifact: per-slot logits, the new token's K/V rows, and the
    /// per-slot attention-mass signal for H2O.
    pub fn decode(
        &self,
        tier: (usize, usize),
        tokens: &TensorI32,
        positions: &TensorI32,
        k_cache: &Tensor,
        v_cache: &Tensor,
        cache_lens: &TensorI32,
    ) -> Result<DecodeOut> {
        let (b, m) = tier;
        let (nl, h, d, row) = (self.n_layer, self.n_head, self.head_dim, self.row);
        if tokens.data.len() != b
            || positions.data.len() != b
            || cache_lens.data.len() != nl * b
            || k_cache.data.len() != nl * b * m * row
            || v_cache.data.len() != v_cache.shape.iter().product::<usize>()
            || k_cache.data.len() != v_cache.data.len()
        {
            return Err(anyhow!("sim decode: shape mismatch for tier ({b}, {m})"));
        }
        let mut logits = vec![0.0f32; b * self.vocab];
        let mut new_k = Tensor::zeros(&[nl, b, h, d]);
        let mut new_v = Tensor::zeros(&[nl, b, h, d]);
        let mut scores = vec![0.0f32; nl * b * m];
        for i in 0..b {
            let t = tokens.data[i];
            let mut state = vec![0.0f32; row];
            for layer in 0..nl {
                let len = (cache_lens.data[layer * b + i].max(0) as usize).min(m);
                let base = (layer * b + i) * m * row;
                let sbase = (layer * b + i) * m;
                self.attend_layer(
                    t,
                    layer,
                    (
                        &k_cache.data[base..base + len * row],
                        &v_cache.data[base..base + len * row],
                    ),
                    len,
                    &mut state,
                    Some(&mut scores[sbase..sbase + m]),
                );
                let nbase = (layer * b + i) * row;
                for j in 0..row {
                    new_k.data[nbase + j] = self.k_elem(t, layer, j);
                    new_v.data[nbase + j] = self.v_elem(t, layer, j);
                }
            }
            self.logits_from_state(
                t,
                positions.data[i],
                &mut state,
                &mut logits[i * self.vocab..(i + 1) * self.vocab],
            );
        }
        Ok(DecodeOut {
            logits: Tensor::from_vec(&[b, self.vocab], logits)?,
            new_k,
            new_v,
            scores: Tensor::from_vec(&[nl, b, m], scores)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> SimModel {
        SimModel::new("tiny").unwrap()
    }

    #[test]
    fn fault_plan_is_deterministic_and_rate_shaped() {
        let plan = FaultPlan {
            seed: 7,
            step_error_rate: 0.05,
            latency_spike_ms: 2,
            latency_spike_rate: 0.1,
            oom_at: 13,
        };
        assert_eq!(plan.decide(13), Some(FaultDecision::Oom));
        // Same (seed, call) → same decision; different seed → independent.
        let mut errors = 0usize;
        for call in 1..=10_000u64 {
            let d = plan.decide(call);
            assert_eq!(d, plan.decide(call));
            if d == Some(FaultDecision::StepError) {
                errors += 1;
            }
        }
        // 5% rate over 10k calls: generous 3–7% band.
        assert!((300..=700).contains(&errors), "errors {errors}");
        // Disarmed plan never fires.
        let off = FaultPlan {
            seed: 7,
            step_error_rate: 0.0,
            latency_spike_ms: 0,
            latency_spike_rate: 0.0,
            oom_at: 0,
        };
        assert!((1..=1000u64).all(|c| off.decide(c).is_none()));
    }

    #[test]
    fn manifest_shape_contract() {
        let sim = model();
        let m = sim.manifest();
        assert_eq!(m.model.n_layer, 8);
        assert_eq!(m.model.n_head * m.model.head_dim, 128);
        assert_eq!(m.prefill_buckets("pallas"), vec![64, 128, 256, 512]);
        assert_eq!(m.prefill_buckets("jnp"), vec![64, 128, 256, 512]);
        assert!(m.decode_tiers("pallas").contains(&(8, 192)));
        assert_eq!(m.decode_tiers("pallas").len(), 4 * 7);
        assert_eq!(m.tokens.eos, 260);
    }

    #[test]
    fn prefill_is_deterministic_and_padded() {
        let sim = model();
        let prompt = vec![256, 5, 9, 22, 257];
        let a = sim.prefill(&prompt, 64).unwrap();
        let b = sim.prefill(&prompt, 64).unwrap();
        assert_eq!(a.logits.data, b.logits.data);
        assert_eq!(a.k.shape, vec![8, 64, 4, 32]);
        // padding rows beyond the prompt stay zero
        let row = 128;
        assert!(a.k.data[5 * row..6 * row].iter().all(|&x| x == 0.0));
        // cosine means land in three distinct bands
        assert!(a.cos_sims.at(&[0, 1]) < 0.35);
        assert!(a.cos_sims.at(&[7, 1]) > 0.7);
    }

    #[test]
    fn decode_depends_on_cache_contents() {
        let sim = model();
        let (b, m) = (1usize, 64usize);
        let prompt = vec![256, 40, 41, 42, 43];
        let pre = sim.prefill(&prompt, 64).unwrap();
        let row = 128;
        let mut k = Tensor::zeros(&[8, b, m, 4, 32]);
        let mut v = Tensor::zeros(&[8, b, m, 4, 32]);
        for layer in 0..8 {
            let src = layer * 64 * row;
            let dst = layer * m * row;
            k.data[dst..dst + 5 * row].copy_from_slice(&pre.k.data[src..src + 5 * row]);
            v.data[dst..dst + 5 * row].copy_from_slice(&pre.v.data[src..src + 5 * row]);
        }
        let tokens = TensorI32::from_vec(&[1], vec![7]).unwrap();
        let positions = TensorI32::from_vec(&[1], vec![5]).unwrap();
        let lens = TensorI32::from_vec(&[8, 1], vec![5; 8]).unwrap();
        let full = sim.decode((b, m), &tokens, &positions, &k, &v, &lens).unwrap();
        // Drop two cached tokens: logits must change.
        let lens3 = TensorI32::from_vec(&[8, 1], vec![3; 8]).unwrap();
        let cut = sim.decode((b, m), &tokens, &positions, &k, &v, &lens3).unwrap();
        assert_ne!(full.logits.data, cut.logits.data);
        // Scores populated only for valid slots.
        assert!(full.scores.data[..5].iter().any(|&s| s > 0.0));
        assert_eq!(full.scores.data[5], 0.0);
        // New KV rows are the token's pure function — independent of cache.
        assert_eq!(full.new_k.data, cut.new_k.data);
    }

    #[test]
    fn unknown_model_rejected() {
        assert!(SimModel::new("huge").is_err());
        assert!(SimModel::new("").is_ok());
        assert!(SimModel::new("tiny-draft").is_ok());
    }

    #[test]
    fn long_spec_extends_context_with_identical_token_math() {
        let long = SimModel::new("long").unwrap();
        let m = long.manifest();
        assert_eq!(m.model.name, "sim-long");
        assert_eq!(m.model.max_seq, 1536);
        assert_eq!(m.prefill_buckets("pallas"), vec![64, 128, 256, 512, 1024]);
        assert_eq!(m.decode_tiers("pallas").len(), 4 * 6);
        assert!(m.decode_tiers("pallas").contains(&(8, 1088)));
        // Same hashing and attention math as tiny — only the shape table
        // differs — so results at shared shapes are byte-identical.
        let tiny = model();
        let prompt = vec![256, 5, 9, 22, 257];
        let a = tiny.prefill(&prompt, 64).unwrap();
        let b = long.prefill(&prompt, 64).unwrap();
        assert_eq!(a.k.data, b.k.data);
        assert_eq!(a.logits.data, b.logits.data);
    }

    #[test]
    fn draft_variant_shares_kv_hashing_but_perturbs_logits() {
        let target = model();
        let draft = SimModel::new("tiny-draft").unwrap();
        assert_eq!(draft.manifest().model.name, "sim-tiny-draft");
        let prompt = vec![256, 5, 9, 22, 257];
        let a = target.prefill(&prompt, 64).unwrap();
        let b = draft.prefill(&prompt, 64).unwrap();
        // Same hashing scheme: a row the draft appends during its burst is
        // byte-identical to the row the target would append for that token.
        assert_eq!(a.k.data, b.k.data);
        assert_eq!(a.v.data, b.v.data);
        assert_eq!(a.cos_sims.data, b.cos_sims.data);
        // Logits differ, but only within the nudge amplitude.
        assert_ne!(a.logits.data, b.logits.data);
        let max_delta = a
            .logits
            .data
            .iter()
            .zip(&b.logits.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_delta > 0.0 && max_delta <= 2.1e-3, "delta {max_delta}");
    }
}
