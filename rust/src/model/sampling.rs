//! Token sampling over logits rows.

use crate::util::Rng;

/// How to pick the next token from a logits row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    /// Argmax (all evaluation benches use this — deterministic).
    Greedy,
    /// Softmax sampling with a temperature.
    Temperature(f32),
}

/// Pick a token id from `logits`.
pub fn sample(logits: &[f32], how: Sampling, rng: &mut Rng) -> i32 {
    match how {
        Sampling::Greedy => argmax(logits),
        Sampling::Temperature(t) => {
            let t = t.max(1e-4);
            let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f64> = logits.iter().map(|&l| (((l - m) / t) as f64).exp()).collect();
            let total: f64 = exps.iter().sum();
            let mut u = rng.f64() * total;
            for (i, e) in exps.iter().enumerate() {
                u -= e;
                if u <= 0.0 {
                    return i as i32;
                }
            }
            (exps.len() - 1) as i32
        }
    }
}

pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &l) in logits.iter().enumerate() {
        if l > bv {
            bv = l;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut rng = Rng::seed_from_u64(0);
        let logits = vec![0.1, 3.0, -1.0, 2.9];
        assert_eq!(sample(&logits, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn low_temperature_matches_greedy() {
        let mut rng = Rng::seed_from_u64(7);
        let logits = vec![0.0, 10.0, 1.0];
        for _ in 0..20 {
            assert_eq!(sample(&logits, Sampling::Temperature(0.01), &mut rng), 1);
        }
    }

    #[test]
    fn temperature_samples_in_range() {
        let mut rng = Rng::seed_from_u64(3);
        let logits = vec![1.0; 8];
        for _ in 0..50 {
            let t = sample(&logits, Sampling::Temperature(1.0), &mut rng);
            assert!((0..8).contains(&t));
        }
    }

    #[test]
    fn temperature_covers_support() {
        let mut rng = Rng::seed_from_u64(5);
        let logits = vec![1.0, 1.0];
        let mut seen = [false, false];
        for _ in 0..100 {
            seen[sample(&logits, Sampling::Temperature(1.0), &mut rng) as usize] = true;
        }
        assert!(seen[0] && seen[1]);
    }
}
