//! Model-adjacent utilities: the shared token vocabulary and sampling.

pub mod sampling;
pub mod tokenizer;

pub use sampling::{argmax, sample, Sampling};
