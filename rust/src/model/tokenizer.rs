//! Token vocabulary shared with the python task suite.
//!
//! The model is token-level (no text): ids 1..=223 are content tokens, the
//! specials below mark task structure. The authoritative ids travel in
//! `manifest.json` (`TokenMap`); the constants here are the compile-time
//! mirror and are cross-checked against the manifest at engine startup.

use crate::config::TokenMap;

pub const PAD: i32 = 0;
pub const BOS: i32 = 256;
pub const SEP: i32 = 257;
pub const QUERY: i32 = 258;
pub const ANSWER: i32 = 259;
pub const EOS: i32 = 260;
pub const MARK: i32 = 261;
pub const EQUALS: i32 = 262;
pub const COMMA: i32 = 263;

/// Content sub-ranges (mirror of python tasks.py).
pub const KEY_LO: i32 = 1;
pub const KEY_HI: i32 = 48;
pub const VAL_LO: i32 = 49;
pub const VAL_HI: i32 = 96;
pub const WORD_LO: i32 = 1;
pub const WORD_HI: i32 = 96;
pub const LM_MOD: i32 = 96;
pub const FIRST_K: usize = 8;

/// Verify the compile-time constants against a manifest's token map; a
/// mismatch means the artifacts were produced by an incompatible task suite.
pub fn check_token_map(map: &TokenMap) -> anyhow::Result<()> {
    let pairs = [
        (PAD, map.pad, "pad"),
        (BOS, map.bos, "bos"),
        (SEP, map.sep, "sep"),
        (QUERY, map.query, "query"),
        (ANSWER, map.answer, "answer"),
        (EOS, map.eos, "eos"),
        (MARK, map.mark, "mark"),
        (EQUALS, map.equals, "equals"),
        (COMMA, map.comma, "comma"),
    ];
    for (ours, theirs, name) in pairs {
        if ours != theirs {
            return Err(anyhow::anyhow!(
                "token map mismatch for {name}: rust {ours} vs manifest {theirs}"
            ));
        }
    }
    Ok(())
}

/// Render a token sequence for logs: specials as names, content as numbers.
pub fn render(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|&t| match t {
            PAD => "<pad>".to_string(),
            BOS => "<bos>".to_string(),
            SEP => "<sep>".to_string(),
            QUERY => "<q>".to_string(),
            ANSWER => "<a>".to_string(),
            EOS => "<eos>".to_string(),
            MARK => "<mark>".to_string(),
            EQUALS => "=".to_string(),
            COMMA => ";".to_string(),
            t => t.to_string(),
        })
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_specials() {
        assert_eq!(render(&[BOS, 5, EQUALS, 60, COMMA, EOS]), "<bos> 5 = 60 ; <eos>");
    }

    #[test]
    fn token_map_check() {
        let ok = TokenMap {
            pad: 0, bos: 256, sep: 257, query: 258, answer: 259,
            eos: 260, mark: 261, equals: 262, comma: 263,
        };
        assert!(check_token_map(&ok).is_ok());
        let bad = TokenMap { bos: 1, ..ok };
        assert!(check_token_map(&bad).is_err());
    }
}
