//! The artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Everything the coordinator needs to know about the compiled
//! model — shapes, special tokens, the weight-blob index, and the available
//! prefill buckets / decode tiers — is read from `manifest.json` so the two
//! sides can never drift silently. Parsed with the in-repo JSON substrate
//! (`util::json`).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

/// Model hyperparameters (mirror of python `ModelConfig`).
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub vocab: usize,
    pub ffn_mult: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub head_dim: usize,
}

impl ModelCfg {
    fn from_json(j: &Json) -> Result<Self> {
        let us = |k: &str| -> Result<usize> {
            j.req(k)?.as_usize().ok_or_else(|| anyhow!("model.{k} not a usize"))
        };
        Ok(Self {
            name: j.req("name")?.as_str().unwrap_or("?").to_string(),
            n_layer: us("n_layer")?,
            d_model: us("d_model")?,
            n_head: us("n_head")?,
            vocab: us("vocab")?,
            ffn_mult: us("ffn_mult")?,
            max_seq: us("max_seq")?,
            rope_theta: j.req("rope_theta")?.as_f64().unwrap_or(10000.0),
            head_dim: us("head_dim")?,
        })
    }

    /// Bytes of KV-cache per cached token per layer (f32 K + V).
    pub fn kv_bytes_per_token_layer(&self) -> usize {
        2 * self.n_head * self.head_dim * 4
    }

    /// Bytes of KV-cache per token across all layers.
    pub fn kv_bytes_per_token(&self) -> usize {
        self.kv_bytes_per_token_layer() * self.n_layer
    }
}

/// Special-token ids shared with the python task generators.
#[derive(Debug, Clone, Copy)]
pub struct TokenMap {
    pub pad: i32,
    pub bos: i32,
    pub sep: i32,
    pub query: i32,
    pub answer: i32,
    pub eos: i32,
    pub mark: i32,
    pub equals: i32,
    pub comma: i32,
}

impl TokenMap {
    fn from_json(j: &Json) -> Result<Self> {
        let t = |k: &str| -> Result<i32> {
            Ok(j.req(k)?.as_i64().ok_or_else(|| anyhow!("tokens.{k} not an int"))? as i32)
        };
        Ok(Self {
            pad: t("pad")?,
            bos: t("bos")?,
            sep: t("sep")?,
            query: t("query")?,
            answer: t("answer")?,
            eos: t("eos")?,
            mark: t("mark")?,
            equals: t("equals")?,
            comma: t("comma")?,
        })
    }
}

/// One weight array inside `weights.bin` (f32 LE, element offsets).
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

#[derive(Debug, Clone)]
pub struct WeightsIndex {
    pub file: String,
    pub dtype: String,
    pub index: Vec<WeightEntry>,
}

/// One HLO artifact. `kind` is "prefill" (has `len`) or "decode" (has
/// `batch` + `cap`).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub kind: String,
    pub kernel: String,
    pub len: Option<usize>,
    pub batch: Option<usize>,
    pub cap: Option<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelCfg,
    pub trained: bool,
    pub tokens: TokenMap,
    pub weights: WeightsIndex,
    pub artifacts: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json` (e.g. `artifacts/tiny`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut m = Self::parse(&text).context("parsing manifest.json")?;
        m.dir = dir.to_path_buf();
        Ok(m)
    }

    /// Parse manifest JSON text (dir left empty).
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let model = ModelCfg::from_json(j.req("model")?)?;
        let tokens = TokenMap::from_json(j.req("tokens")?)?;
        let w = j.req("weights")?;
        let mut index = Vec::new();
        for e in w.req("index")?.as_arr().unwrap_or(&[]) {
            index.push(WeightEntry {
                name: e.req("name")?.as_str().unwrap_or("").to_string(),
                shape: e
                    .req("shape")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect(),
                offset: e.req("offset")?.as_usize().ok_or_else(|| anyhow!("bad offset"))?,
                len: e.req("len")?.as_usize().ok_or_else(|| anyhow!("bad len"))?,
            });
        }
        let weights = WeightsIndex {
            file: w.req("file")?.as_str().unwrap_or("weights.bin").to_string(),
            dtype: w.req("dtype")?.as_str().unwrap_or("f32").to_string(),
            index,
        };
        let mut artifacts = Vec::new();
        for a in j.req("artifacts")?.as_arr().unwrap_or(&[]) {
            artifacts.push(ArtifactEntry {
                file: a.req("file")?.as_str().unwrap_or("").to_string(),
                kind: a.req("kind")?.as_str().unwrap_or("").to_string(),
                kernel: a.req("kernel")?.as_str().unwrap_or("").to_string(),
                len: a.get("len").and_then(|v| v.as_usize()),
                batch: a.get("batch").and_then(|v| v.as_usize()),
                cap: a.get("cap").and_then(|v| v.as_usize()),
            });
        }
        Ok(Manifest {
            model,
            trained: j.req("trained")?.as_bool().unwrap_or(false),
            tokens,
            weights,
            artifacts,
            dir: PathBuf::new(),
        })
    }

    pub fn artifact_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Sorted prefill bucket lengths for `kernel`.
    pub fn prefill_buckets(&self, kernel: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "prefill" && a.kernel == kernel)
            .filter_map(|a| a.len)
            .collect();
        v.sort_unstable();
        v
    }

    /// Available decode tiers (batch, capacity) for `kernel`.
    pub fn decode_tiers(&self, kernel: &str) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "decode" && a.kernel == kernel)
            .filter_map(|a| Some((a.batch?, a.cap?)))
            .collect();
        v.sort_unstable();
        v
    }

    pub fn find_prefill(&self, kernel: &str, len: usize) -> Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "prefill" && a.kernel == kernel && a.len == Some(len))
            .ok_or_else(|| anyhow!("no prefill artifact kernel={kernel} len={len}"))
    }

    pub fn find_decode(&self, kernel: &str, batch: usize, cap: usize) -> Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| {
                a.kind == "decode"
                    && a.kernel == kernel
                    && a.batch == Some(batch)
                    && a.cap == Some(cap)
            })
            .ok_or_else(|| anyhow!("no decode artifact kernel={kernel} b={batch} m={cap}"))
    }

    /// Read `weights.bin` into per-array f32 vectors, manifest order.
    pub fn load_weights(&self) -> Result<Vec<(WeightEntry, Vec<f32>)>> {
        let path = self.dir.join(&self.weights.file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut out = Vec::with_capacity(self.weights.index.len());
        for e in &self.weights.index {
            let start = e.offset * 4;
            let end = start + e.len * 4;
            if end > bytes.len() {
                return Err(anyhow!("weight {} out of range in weights.bin", e.name));
            }
            let mut v = vec![0f32; e.len];
            for (i, chunk) in bytes[start..end].chunks_exact(4).enumerate() {
                v[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            out.push((e.clone(), v));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_json() -> &'static str {
        r#"{
          "model": {"name":"tiny","n_layer":8,"d_model":128,"n_head":4,
                    "vocab":272,"ffn_mult":4,"max_seq":640,
                    "rope_theta":10000.0,"head_dim":32},
          "trained": false,
          "tokens": {"pad":0,"bos":256,"sep":257,"query":258,"answer":259,
                     "eos":260,"mark":261,"equals":262,"comma":263},
          "weights": {"file":"weights.bin","dtype":"f32","index":[
            {"name":"embed","shape":[272,128],"offset":0,"len":34816}
          ]},
          "artifacts": [
            {"file":"prefill_pallas_l64.hlo.txt","kind":"prefill","kernel":"pallas","len":64},
            {"file":"prefill_pallas_l128.hlo.txt","kind":"prefill","kernel":"pallas","len":128},
            {"file":"decode_pallas_b4_m192.hlo.txt","kind":"decode","kernel":"pallas","batch":4,"cap":192}
          ]
        }"#
    }

    #[test]
    fn parse_and_query() {
        let m = Manifest::parse(sample_manifest_json()).unwrap();
        assert_eq!(m.model.n_layer, 8);
        assert_eq!(m.prefill_buckets("pallas"), vec![64, 128]);
        assert_eq!(m.decode_tiers("pallas"), vec![(4, 192)]);
        assert!(m.find_prefill("pallas", 128).is_ok());
        assert!(m.find_prefill("pallas", 999).is_err());
        assert!(m.find_decode("pallas", 4, 192).is_ok());
        assert!(m.find_decode("jnp", 4, 192).is_err());
        assert_eq!(m.weights.index[0].len, 34816);
        assert_eq!(m.tokens.eos, 260);
    }

    #[test]
    fn kv_byte_math() {
        let m = Manifest::parse(sample_manifest_json()).unwrap();
        // 2 (K+V) * 4 heads * 32 dim * 4 bytes = 1024 B per token-layer
        assert_eq!(m.model.kv_bytes_per_token_layer(), 1024);
        assert_eq!(m.model.kv_bytes_per_token(), 8192);
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("{}").is_err());
    }
}
