//! Serving configuration: which eviction policy, what budget, whether
//! SqueezeAttention reallocation is on, engine limits. Loadable from a JSON
//! file (see `configs/*.json`) and overridable from the CLI.

use anyhow::{anyhow, Result};

use crate::metrics::TraceLevel;
use crate::util::Json;

/// Sequence-wise KV eviction policy (the paper's baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Cache everything (the paper's "Full Cache" line).
    Full,
    /// Keep only the most recent tokens (Longformer / Mistral style).
    SlidingWindow,
    /// Keep `sinks` initial tokens + most recent (Xiao et al. 2023).
    StreamingLlm,
    /// Heavy-Hitter Oracle: keep top accumulated-attention tokens + recent.
    H2o,
}

impl PolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Full => "full",
            PolicyKind::SlidingWindow => "sliding_window",
            PolicyKind::StreamingLlm => "streaming_llm",
            PolicyKind::H2o => "h2o",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "full" => Some(Self::Full),
            "sliding_window" | "sliding" | "window" => Some(Self::SlidingWindow),
            "streaming_llm" | "streaming" => Some(Self::StreamingLlm),
            "h2o" | "heavy_hitter" => Some(Self::H2o),
            _ => None,
        }
    }

    pub const ALL: [PolicyKind; 4] =
        [PolicyKind::Full, PolicyKind::SlidingWindow, PolicyKind::StreamingLlm, PolicyKind::H2o];
}

/// SqueezeAttention (layer-dimension) settings — Algorithm 1.
#[derive(Debug, Clone)]
pub struct SqueezeConfig {
    /// Master switch: off = every layer gets `budget` (the baselines).
    pub enabled: bool,
    /// Hyperparameter `p` in (0, 1]: fraction of the initial budget the
    /// unimportant group (G3) keeps. Paper recommends 0.3–0.4.
    pub p: f64,
    /// Number of k-means groups (paper: 3).
    pub groups: usize,
    /// Floor for any layer's budget after reallocation (tokens); protects
    /// degenerate clusterings on very small budgets.
    pub min_budget: usize,
}

impl Default for SqueezeConfig {
    fn default() -> Self {
        Self { enabled: true, p: 0.35, groups: 3, min_budget: 8 }
    }
}

/// Speculative-decoding settings (draft-then-verify decode bursts).
#[derive(Debug, Clone)]
pub struct SpecConfig {
    /// Master switch. Off = one token per decode step (the baseline path).
    pub enabled: bool,
    /// Tokens the draft model proposes per sequence per burst. A burst
    /// commits between 1 (all drafts rejected — the target's own sample
    /// still lands) and `draft_k + 1` tokens (all drafts accepted plus the
    /// bonus token from the final verify step).
    pub draft_k: usize,
}

impl Default for SpecConfig {
    fn default() -> Self {
        Self { enabled: false, draft_k: 4 }
    }
}

/// Deterministic fault-injection plan for the `sim://` backend (chaos
/// testing). All knobs default to off; any non-zero rate/count arms the
/// plan. Injection is a pure function of `(seed, decode-call index)`, so a
/// given config reproduces the same fault sequence on every run.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Probability in [0, 1] that any single backend decode call returns an
    /// injected step error.
    pub step_error_rate: f64,
    /// Injected latency-spike duration (ms) — a decode call selected by
    /// `latency_spike_rate` sleeps this long before returning normally.
    pub latency_spike_ms: u64,
    /// Probability in [0, 1] of a latency spike per decode call.
    pub latency_spike_rate: f64,
    /// Inject a simulated allocator OOM error on exactly the N-th decode
    /// call (1-based). 0 = never.
    pub oom_at: u64,
    /// Seed for the fault hash; distinct seeds give independent fault
    /// sequences at the same rates.
    pub seed: u64,
    /// Test hook: `Router::spawn` worker k fails engine construction (used
    /// by the partial-spawn-failure chaos tests). Not serialized.
    pub spawn_fail_worker: Option<usize>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            step_error_rate: 0.0,
            latency_spike_ms: 0,
            latency_spike_rate: 0.0,
            oom_at: 0,
            seed: 0x5EED,
            spawn_fail_worker: None,
        }
    }
}

impl FaultConfig {
    /// Whether any injection knob is armed (an unarmed plan costs nothing
    /// on the decode path).
    pub fn enabled(&self) -> bool {
        self.step_error_rate > 0.0
            || (self.latency_spike_ms > 0 && self.latency_spike_rate > 0.0)
            || self.oom_at > 0
    }
}

/// Engine-level serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Artifact directory (contains manifest.json).
    pub artifacts: String,
    /// Kernel variant to bind ("pallas" or "jnp" ablation).
    pub kernel: String,
    /// Sequence-wise policy.
    pub policy: PolicyKind,
    /// Per-layer token budget b_init (absolute tokens).
    pub budget: usize,
    /// When set, b_init = budget_frac × prompt_len (overrides `budget`);
    /// this is the paper's "% of sequence length" axis in Fig. 3.
    pub budget_frac: Option<f64>,
    /// StreamingLLM sink count (paper: 4).
    pub sinks: usize,
    /// H2O: fraction of the budget reserved for the recency window.
    pub h2o_recent_frac: f64,
    pub squeeze: SqueezeConfig,
    /// Speculative decoding (draft model + batched verification).
    pub spec: SpecConfig,
    /// Max concurrent decode slots (bound to the largest artifact tier <= this).
    pub max_batch: usize,
    /// Default max new tokens per request.
    pub max_new_tokens: usize,
    /// Global device KV pool capacity in bytes (0 = unlimited). OOM
    /// experiments set this to emulate a fixed HBM budget.
    pub kv_pool_bytes: usize,
    /// Host-spill tier capacity in bytes for suspended sequences. 0 disables
    /// swap entirely: preemption falls back to restart-from-scratch (the
    /// PR 1 semantics). Any positive value caps the host tier; pass
    /// `usize::MAX` for effectively unlimited spill. (At the `KvPool` level
    /// a tier capacity of 0 means unlimited — the engine maps this knob's
    /// 0-means-disabled onto that by never swapping out.)
    pub host_spill_bytes: usize,
    /// KV page size in bytes for the paged allocator: both tiers are carved
    /// into fixed pages of this size, and admission/growth/suspend all move
    /// in whole pages. The engine clamps it up to at least one token row of
    /// the loaded model so a page always covers the slots it is charged
    /// for. Default 16 KiB.
    pub kv_page_bytes: usize,
    /// Admission queue depth before backpressure rejects.
    pub queue_depth: usize,
    /// On KV-pool OOM mid-decode, preempt the youngest running sequence
    /// instead of failing a request (continuous-batching default): suspend
    /// it to the host tier when `host_spill_bytes > 0`, otherwise requeue it
    /// for a restart-from-scratch. Disable to reproduce the paper's hard-OOM
    /// table cells.
    pub preemption: bool,
    /// Batch-forming delay: router workers wait up to this long for more
    /// arrivals before stepping a batch smaller than the slot count, trading
    /// a bounded first-token latency hit for higher step occupancy. 0 =
    /// step immediately (lowest latency).
    pub batch_wait_ms: u64,
    /// Default per-request wall-clock deadline in milliseconds, measured
    /// from submission and enforced at decode-step boundaries
    /// (`FinishReason::DeadlineExceeded`, partial output kept). 0 = no
    /// default; a request's own `deadline` always takes precedence.
    pub request_deadline_ms: u64,
    /// Keep decode scratch slots resident across steps and gather only
    /// newly appended KV rows (the hot-path default). Disable
    /// (`--no-resident-scratch`) to force a full scratch refill every step
    /// — the parity baseline `bench_hotpath` compares against.
    pub resident_scratch: bool,
    /// Deterministic fault injection on the `sim://` backend (off by
    /// default) — see [`FaultConfig`].
    pub faults: FaultConfig,
    /// Worker-fault retries per request before it retires with
    /// `FinishReason::WorkerError`. A retried sequence resumes from its
    /// host-tier snapshot (or restarts from scratch) token-identically.
    pub max_retries: u32,
    /// Times the router's supervisor will respawn a dead worker's engine
    /// before marking the worker permanently dead.
    pub max_worker_restarts: u64,
    /// Load shedding: reject with `Overloaded` when the picked worker
    /// already has this many requests in flight. 0 = shedding on depth off.
    pub shed_queue_depth: usize,
    /// Load shedding: reject with `Overloaded` when the picked worker's
    /// observed queue-latency p95 exceeds this many milliseconds. 0 = off.
    pub shed_queue_latency_ms: u64,
    /// Telemetry depth (`--trace-level {off,spans,full}`): `off` records
    /// nothing on the hot path, `spans` records lifecycle trace spans into
    /// the per-worker flight recorder, `full` additionally times the
    /// decode-step phases. Default `spans`.
    pub trace_level: TraceLevel,
}

impl ServeConfig {
    pub fn new(artifacts: impl Into<String>) -> Self {
        Self {
            artifacts: artifacts.into(),
            kernel: "pallas".into(),
            policy: PolicyKind::SlidingWindow,
            budget: 128,
            budget_frac: None,
            sinks: 4,
            h2o_recent_frac: 0.5,
            squeeze: SqueezeConfig::default(),
            spec: SpecConfig::default(),
            max_batch: 8,
            max_new_tokens: 64,
            kv_pool_bytes: 0,
            host_spill_bytes: 0,
            kv_page_bytes: 16 * 1024,
            queue_depth: 256,
            preemption: true,
            batch_wait_ms: 0,
            request_deadline_ms: 0,
            resident_scratch: true,
            faults: FaultConfig::default(),
            max_retries: 2,
            max_worker_restarts: 3,
            shed_queue_depth: 0,
            shed_queue_latency_ms: 0,
            trace_level: TraceLevel::default(),
        }
    }

    /// Load from a JSON config file; missing fields keep defaults.
    pub fn from_json_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = Self::new(
            j.req("artifacts")?
                .as_str()
                .ok_or_else(|| anyhow!("artifacts must be a string"))?,
        );
        if let Some(k) = j.get("kernel").and_then(|v| v.as_str()) {
            cfg.kernel = k.to_string();
        }
        if let Some(p) = j.get("policy").and_then(|v| v.as_str()) {
            cfg.policy = PolicyKind::parse(p).ok_or_else(|| anyhow!("unknown policy {p}"))?;
        }
        if let Some(b) = j.get("budget").and_then(|v| v.as_usize()) {
            cfg.budget = b;
        }
        if let Some(f) = j.get("budget_frac").and_then(|v| v.as_f64()) {
            cfg.budget_frac = Some(f);
        }
        if let Some(s) = j.get("sinks").and_then(|v| v.as_usize()) {
            cfg.sinks = s;
        }
        if let Some(f) = j.get("h2o_recent_frac").and_then(|v| v.as_f64()) {
            cfg.h2o_recent_frac = f;
        }
        if let Some(sq) = j.get("squeeze") {
            if let Some(e) = sq.get("enabled").and_then(|v| v.as_bool()) {
                cfg.squeeze.enabled = e;
            }
            if let Some(p) = sq.get("p").and_then(|v| v.as_f64()) {
                cfg.squeeze.p = p;
            }
            if let Some(g) = sq.get("groups").and_then(|v| v.as_usize()) {
                cfg.squeeze.groups = g;
            }
            if let Some(m) = sq.get("min_budget").and_then(|v| v.as_usize()) {
                cfg.squeeze.min_budget = m;
            }
        }
        if let Some(sp) = j.get("spec") {
            if let Some(e) = sp.get("enabled").and_then(|v| v.as_bool()) {
                cfg.spec.enabled = e;
            }
            if let Some(k) = sp.get("draft_k").and_then(|v| v.as_usize()) {
                cfg.spec.draft_k = k;
            }
        }
        if let Some(b) = j.get("max_batch").and_then(|v| v.as_usize()) {
            cfg.max_batch = b;
        }
        if let Some(m) = j.get("max_new_tokens").and_then(|v| v.as_usize()) {
            cfg.max_new_tokens = m;
        }
        if let Some(k) = j.get("kv_pool_bytes").and_then(|v| v.as_usize()) {
            cfg.kv_pool_bytes = k;
        }
        if let Some(h) = j.get("host_spill_bytes").and_then(|v| v.as_usize()) {
            cfg.host_spill_bytes = h;
        }
        if let Some(p) = j.get("kv_page_bytes").and_then(|v| v.as_usize()) {
            cfg.kv_page_bytes = p;
        }
        if let Some(q) = j.get("queue_depth").and_then(|v| v.as_usize()) {
            cfg.queue_depth = q;
        }
        if let Some(p) = j.get("preemption").and_then(|v| v.as_bool()) {
            cfg.preemption = p;
        }
        if let Some(w) = j.get("batch_wait_ms").and_then(|v| v.as_usize()) {
            cfg.batch_wait_ms = w as u64;
        }
        if let Some(d) = j.get("request_deadline_ms").and_then(|v| v.as_usize()) {
            cfg.request_deadline_ms = d as u64;
        }
        if let Some(r) = j.get("resident_scratch").and_then(|v| v.as_bool()) {
            cfg.resident_scratch = r;
        }
        if let Some(fa) = j.get("faults") {
            if let Some(r) = fa.get("step_error_rate").and_then(|v| v.as_f64()) {
                cfg.faults.step_error_rate = r;
            }
            if let Some(m) = fa.get("latency_spike_ms").and_then(|v| v.as_usize()) {
                cfg.faults.latency_spike_ms = m as u64;
            }
            if let Some(r) = fa.get("latency_spike_rate").and_then(|v| v.as_f64()) {
                cfg.faults.latency_spike_rate = r;
            }
            if let Some(n) = fa.get("oom_at").and_then(|v| v.as_usize()) {
                cfg.faults.oom_at = n as u64;
            }
            if let Some(s) = fa.get("seed").and_then(|v| v.as_usize()) {
                cfg.faults.seed = s as u64;
            }
        }
        if let Some(r) = j.get("max_retries").and_then(|v| v.as_usize()) {
            cfg.max_retries = r as u32;
        }
        if let Some(r) = j.get("max_worker_restarts").and_then(|v| v.as_usize()) {
            cfg.max_worker_restarts = r as u64;
        }
        if let Some(d) = j.get("shed_queue_depth").and_then(|v| v.as_usize()) {
            cfg.shed_queue_depth = d;
        }
        if let Some(l) = j.get("shed_queue_latency_ms").and_then(|v| v.as_usize()) {
            cfg.shed_queue_latency_ms = l as u64;
        }
        if let Some(t) = j.get("trace_level").and_then(|v| v.as_str()) {
            cfg.trace_level =
                TraceLevel::parse(t).ok_or_else(|| anyhow!("unknown trace_level {t}"))?;
        }
        Ok(cfg)
    }

    /// Serialize to JSON (for experiment logs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("artifacts", Json::str(&self.artifacts)),
            ("kernel", Json::str(&self.kernel)),
            ("policy", Json::str(self.policy.name())),
            ("budget", Json::num(self.budget as f64)),
            (
                "budget_frac",
                self.budget_frac.map(Json::num).unwrap_or(Json::Null),
            ),
            ("sinks", Json::num(self.sinks as f64)),
            ("h2o_recent_frac", Json::num(self.h2o_recent_frac)),
            (
                "squeeze",
                Json::obj(vec![
                    ("enabled", Json::Bool(self.squeeze.enabled)),
                    ("p", Json::num(self.squeeze.p)),
                    ("groups", Json::num(self.squeeze.groups as f64)),
                    ("min_budget", Json::num(self.squeeze.min_budget as f64)),
                ]),
            ),
            (
                "spec",
                Json::obj(vec![
                    ("enabled", Json::Bool(self.spec.enabled)),
                    ("draft_k", Json::num(self.spec.draft_k as f64)),
                ]),
            ),
            ("max_batch", Json::num(self.max_batch as f64)),
            ("max_new_tokens", Json::num(self.max_new_tokens as f64)),
            ("kv_pool_bytes", Json::num(self.kv_pool_bytes as f64)),
            ("host_spill_bytes", Json::num(self.host_spill_bytes as f64)),
            ("kv_page_bytes", Json::num(self.kv_page_bytes as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("preemption", Json::Bool(self.preemption)),
            ("batch_wait_ms", Json::num(self.batch_wait_ms as f64)),
            ("request_deadline_ms", Json::num(self.request_deadline_ms as f64)),
            ("resident_scratch", Json::Bool(self.resident_scratch)),
            (
                "faults",
                Json::obj(vec![
                    ("step_error_rate", Json::num(self.faults.step_error_rate)),
                    ("latency_spike_ms", Json::num(self.faults.latency_spike_ms as f64)),
                    ("latency_spike_rate", Json::num(self.faults.latency_spike_rate)),
                    ("oom_at", Json::num(self.faults.oom_at as f64)),
                    ("seed", Json::num(self.faults.seed as f64)),
                ]),
            ),
            ("max_retries", Json::num(self.max_retries as f64)),
            ("max_worker_restarts", Json::num(self.max_worker_restarts as f64)),
            ("shed_queue_depth", Json::num(self.shed_queue_depth as f64)),
            ("shed_queue_latency_ms", Json::num(self.shed_queue_latency_ms as f64)),
            ("trace_level", Json::str(self.trace_level.name())),
        ])
    }

    pub fn with_policy(mut self, policy: PolicyKind) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_budget(mut self, budget: usize) -> Self {
        self.budget = budget;
        self
    }

    pub fn with_budget_frac(mut self, frac: f64) -> Self {
        self.budget_frac = Some(frac);
        self
    }

    pub fn with_squeeze(mut self, enabled: bool) -> Self {
        self.squeeze.enabled = enabled;
        self
    }

    pub fn with_p(mut self, p: f64) -> Self {
        self.squeeze.p = p;
        self
    }

    pub fn with_kernel(mut self, kernel: &str) -> Self {
        self.kernel = kernel.to_string();
        self
    }

    pub fn with_preemption(mut self, preemption: bool) -> Self {
        self.preemption = preemption;
        self
    }

    pub fn with_host_spill(mut self, bytes: usize) -> Self {
        self.host_spill_bytes = bytes;
        self
    }

    pub fn with_kv_page_bytes(mut self, bytes: usize) -> Self {
        self.kv_page_bytes = bytes;
        self
    }

    pub fn with_batch_wait_ms(mut self, ms: u64) -> Self {
        self.batch_wait_ms = ms;
        self
    }

    pub fn with_request_deadline_ms(mut self, ms: u64) -> Self {
        self.request_deadline_ms = ms;
        self
    }

    pub fn with_resident_scratch(mut self, resident: bool) -> Self {
        self.resident_scratch = resident;
        self
    }

    /// Enable speculative decoding with `k` drafted tokens per burst; `k = 0`
    /// disables it (the `--spec-k` CLI semantics).
    pub fn with_spec_k(mut self, k: usize) -> Self {
        self.spec.enabled = k > 0;
        if k > 0 {
            self.spec.draft_k = k;
        }
        self
    }

    pub fn with_faults(mut self, faults: FaultConfig) -> Self {
        self.faults = faults;
        self
    }

    pub fn with_max_retries(mut self, retries: u32) -> Self {
        self.max_retries = retries;
        self
    }

    pub fn with_max_worker_restarts(mut self, restarts: u64) -> Self {
        self.max_worker_restarts = restarts;
        self
    }

    pub fn with_shed_queue_depth(mut self, depth: usize) -> Self {
        self.shed_queue_depth = depth;
        self
    }

    pub fn with_shed_queue_latency_ms(mut self, ms: u64) -> Self {
        self.shed_queue_latency_ms = ms;
        self
    }

    pub fn with_trace_level(mut self, level: TraceLevel) -> Self {
        self.trace_level = level;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_roundtrip() {
        for p in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(p.name()), Some(p));
        }
        assert_eq!(PolicyKind::parse("nope"), None);
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ServeConfig::new("artifacts/tiny")
            .with_policy(PolicyKind::H2o)
            .with_budget(96)
            .with_p(0.25);
        let j = cfg.to_json();
        let back = ServeConfig::from_json(&j).unwrap();
        assert_eq!(back.policy, PolicyKind::H2o);
        assert_eq!(back.budget, 96);
        assert!((back.squeeze.p - 0.25).abs() < 1e-12);
        assert!(back.squeeze.enabled);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"artifacts": "a", "policy": "streaming_llm"}"#).unwrap();
        let cfg = ServeConfig::from_json(&j).unwrap();
        assert_eq!(cfg.policy, PolicyKind::StreamingLlm);
        assert_eq!(cfg.budget, 128);
        assert_eq!(cfg.sinks, 4);
    }

    #[test]
    fn builder_chain() {
        let cfg = ServeConfig::new("x").with_squeeze(false).with_budget(7).with_budget_frac(0.2);
        assert!(!cfg.squeeze.enabled);
        assert_eq!(cfg.budget, 7);
        assert_eq!(cfg.budget_frac, Some(0.2));
    }

    #[test]
    fn preemption_roundtrip_and_default() {
        let cfg = ServeConfig::new("a");
        assert!(cfg.preemption);
        let off = ServeConfig::from_json(&cfg.clone().with_preemption(false).to_json()).unwrap();
        assert!(!off.preemption);
        // absent key keeps the default
        let j = Json::parse(r#"{"artifacts": "a"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).unwrap().preemption);
    }

    #[test]
    fn swap_knobs_roundtrip_and_defaults() {
        // Defaults: spill disabled (restart-from-scratch preemption), no
        // batch-forming delay.
        let cfg = ServeConfig::new("a");
        assert_eq!(cfg.host_spill_bytes, 0);
        assert_eq!(cfg.batch_wait_ms, 0);
        let set = cfg.with_host_spill(1 << 20).with_batch_wait_ms(25);
        let back = ServeConfig::from_json(&set.to_json()).unwrap();
        assert_eq!(back.host_spill_bytes, 1 << 20);
        assert_eq!(back.batch_wait_ms, 25);
        // absent keys keep the defaults
        let j = Json::parse(r#"{"artifacts": "a"}"#).unwrap();
        let d = ServeConfig::from_json(&j).unwrap();
        assert_eq!(d.host_spill_bytes, 0);
        assert_eq!(d.batch_wait_ms, 0);
    }

    #[test]
    fn kv_page_bytes_roundtrip_and_default() {
        let cfg = ServeConfig::new("a");
        assert_eq!(cfg.kv_page_bytes, 16 * 1024);
        let back = ServeConfig::from_json(&cfg.with_kv_page_bytes(4096).to_json()).unwrap();
        assert_eq!(back.kv_page_bytes, 4096);
        // absent key keeps the default
        let j = Json::parse(r#"{"artifacts": "a"}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().kv_page_bytes, 16 * 1024);
    }

    #[test]
    fn request_deadline_roundtrip_and_default() {
        // Default: no deadline.
        let cfg = ServeConfig::new("a");
        assert_eq!(cfg.request_deadline_ms, 0);
        let back =
            ServeConfig::from_json(&cfg.with_request_deadline_ms(750).to_json()).unwrap();
        assert_eq!(back.request_deadline_ms, 750);
        // absent key keeps the default
        let j = Json::parse(r#"{"artifacts": "a"}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().request_deadline_ms, 0);
    }

    #[test]
    fn spec_roundtrip_and_default() {
        // Default: speculative decoding off, draft_k 4 standing by.
        let cfg = ServeConfig::new("a");
        assert!(!cfg.spec.enabled);
        assert_eq!(cfg.spec.draft_k, 4);
        let on = cfg.clone().with_spec_k(8);
        assert!(on.spec.enabled);
        let back = ServeConfig::from_json(&on.to_json()).unwrap();
        assert!(back.spec.enabled);
        assert_eq!(back.spec.draft_k, 8);
        // --spec-k 0 disables without clobbering the stored k.
        let off = on.with_spec_k(0);
        assert!(!off.spec.enabled);
        assert_eq!(off.spec.draft_k, 8);
        // absent key keeps the default
        let j = Json::parse(r#"{"artifacts": "a"}"#).unwrap();
        assert!(!ServeConfig::from_json(&j).unwrap().spec.enabled);
    }

    #[test]
    fn resident_scratch_roundtrip_and_default() {
        // Default: resident scratch on (the hot-path win).
        let cfg = ServeConfig::new("a");
        assert!(cfg.resident_scratch);
        let back =
            ServeConfig::from_json(&cfg.with_resident_scratch(false).to_json()).unwrap();
        assert!(!back.resident_scratch);
        // absent key keeps the default
        let j = Json::parse(r#"{"artifacts": "a"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).unwrap().resident_scratch);
    }

    #[test]
    fn fault_knobs_roundtrip_and_default() {
        // Defaults: injection disarmed, 2 retries, 3 restarts, shedding off.
        let cfg = ServeConfig::new("a");
        assert!(!cfg.faults.enabled());
        assert_eq!(cfg.max_retries, 2);
        assert_eq!(cfg.max_worker_restarts, 3);
        assert_eq!(cfg.shed_queue_depth, 0);
        assert_eq!(cfg.shed_queue_latency_ms, 0);
        let set = cfg
            .with_faults(FaultConfig {
                step_error_rate: 0.05,
                latency_spike_ms: 3,
                latency_spike_rate: 0.1,
                oom_at: 17,
                seed: 99,
                spawn_fail_worker: None,
            })
            .with_max_retries(5)
            .with_max_worker_restarts(1)
            .with_shed_queue_depth(4)
            .with_shed_queue_latency_ms(250);
        assert!(set.faults.enabled());
        let back = ServeConfig::from_json(&set.to_json()).unwrap();
        assert!((back.faults.step_error_rate - 0.05).abs() < 1e-12);
        assert_eq!(back.faults.latency_spike_ms, 3);
        assert!((back.faults.latency_spike_rate - 0.1).abs() < 1e-12);
        assert_eq!(back.faults.oom_at, 17);
        assert_eq!(back.faults.seed, 99);
        assert_eq!(back.max_retries, 5);
        assert_eq!(back.max_worker_restarts, 1);
        assert_eq!(back.shed_queue_depth, 4);
        assert_eq!(back.shed_queue_latency_ms, 250);
        // absent keys keep the defaults
        let j = Json::parse(r#"{"artifacts": "a"}"#).unwrap();
        let d = ServeConfig::from_json(&j).unwrap();
        assert!(!d.faults.enabled());
        assert_eq!(d.max_retries, 2);
        // spawn_fail_worker is a test hook, never serialized
        assert!(set.to_json().get("faults").unwrap().get("spawn_fail_worker").is_none());
    }

    #[test]
    fn trace_level_roundtrip_and_default() {
        // Default: lifecycle spans on, phase timers off.
        let cfg = ServeConfig::new("a");
        assert_eq!(cfg.trace_level, TraceLevel::Spans);
        let back =
            ServeConfig::from_json(&cfg.with_trace_level(TraceLevel::Full).to_json()).unwrap();
        assert_eq!(back.trace_level, TraceLevel::Full);
        // absent key keeps the default
        let j = Json::parse(r#"{"artifacts": "a"}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().trace_level, TraceLevel::Spans);
        // bad value is a hard error, not a silent default
        let j = Json::parse(r#"{"artifacts": "a", "trace_level": "loud"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }

    #[test]
    fn bad_policy_errors() {
        let j = Json::parse(r#"{"artifacts": "a", "policy": "zap"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }
}
