//! Configuration: the AOT artifact manifest (written by `python -m
//! compile.aot`) and the serving-side configuration (TOML / CLI).

mod manifest;
mod serve;

pub use manifest::{ArtifactEntry, Manifest, ModelCfg, TokenMap, WeightEntry,
                   WeightsIndex};
pub use serve::{FaultConfig, PolicyKind, ServeConfig, SpecConfig, SqueezeConfig};
