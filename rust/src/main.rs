//! `squeeze-serve` — the serving launcher.
//!
//! Subcommands:
//!   serve     run the TCP JSON-lines server over a worker pool
//!   generate  one-shot: run a synthetic workload batch and print results
//!   inspect   print manifest / artifact inventory
//!   project   paper-scale cost-model projection (no artifacts needed)
//!
//! Run `squeeze-serve help` for flags.

use anyhow::{anyhow, Result};

use squeezeattention::config::{PolicyKind, ServeConfig};
use squeezeattention::coordinator::{Engine, Request, RoutePolicy, Router};
use squeezeattention::model::tokenizer;
use squeezeattention::simulator::{self, KvPolicy};
use squeezeattention::util::Args;
use squeezeattention::workload::{answer_accuracy, trim_at_eos, TraceSpec};

const HELP: &str = "\
squeeze-serve — SqueezeAttention serving coordinator

USAGE: squeeze-serve <command> [flags]

COMMANDS
  serve     --listen 127.0.0.1:7177 --workers 1 [engine flags]
  generate  --n 8 --prompt-len 192 --max-new 48 [--task copy] [--seed 0]
            [--verbose] [engine flags]
  inspect   --artifacts artifacts/tiny
  project   --model Mistral-7B --prompt-len 512 --gen-len 1024
            --batches 1,32,64,128,224 --budget-frac 0.2
  help      this text

ENGINE FLAGS (serve/generate)
  --artifacts DIR      artifact directory, or sim://tiny for the
                       simulated backend            [sim://tiny]
  --config FILE        JSON ServeConfig (flags override)
  --policy P           full|sliding_window|streaming_llm|h2o  [sliding_window]
  --budget N           per-layer token budget b_init          [128]
  --budget-frac F      b_init = F * prompt_len (overrides --budget)
  --no-squeeze         disable layer-budget reallocation
  --no-resident-scratch
                       disable batch-resident scratch KV: fully
                       re-gather every sequence's cache into the
                       decode scratch each step (baseline mode)
  --p F                squeeze hyperparameter p               [0.35]
  --max-batch N        decode slots                           [8]
  --kernel K           pallas|jnp                             [pallas]
  --kv-pool-mib N      device KV pool capacity (0 = unlimited) [0]
  --host-spill-mib N   host-spill tier for suspended sequences
                       (0 = disabled: preemption restarts
                       from scratch)                           [0]
  --kv-page-bytes N    KV page size for the paged allocator
                       (clamped up to one token row)           [16384]
  --batch-wait-ms N    wait up to N ms for more arrivals
                       before stepping a small batch           [0]
  --spec-k N           speculative decoding: draft N tokens per
                       sequence per step and verify them in one
                       batched pass (0 = disabled)              [0]
  --request-deadline-ms N
                       default per-request wall-clock deadline,
                       enforced at decode-step boundaries; an
                       expired request finishes with
                       \"deadline\" keeping its partial output
                       (a request's own deadline_ms overrides;
                       0 = no deadline)                        [0]
  --trace-level L      off|spans|full — telemetry recorded on
                       the hot path: \"spans\" keeps lifecycle
                       trace spans + the crash flight recorder,
                       \"full\" adds per-phase step timing,
                       \"off\" records nothing               [spans]

FAULT TOLERANCE (serve/generate; injection is sim:// only)
  --fault-step-error-rate F
                       inject backend step errors at rate F,
                       deterministically from the fault seed    [0]
  --fault-latency-spike MS
                       injected latency spike duration; fires
                       at --fault-latency-spike-rate            [0]
  --fault-latency-spike-rate F
                       latency spike rate                       [0]
  --fault-oom-at N     inject a device-OOM error on exactly the
                       N-th decode call (0 = off)               [0]
  --fault-seed N       seed for the fault hash                  [24301]
  --max-retries N      per-request retry budget for worker
                       faults; spent budget retires the request
                       with \"worker_error\"                    [2]
  --max-worker-restarts N
                       respawn attempts per worker slot before
                       the supervisor gives up                  [3]
  --shed-queue-depth N shed (\"overloaded\" + retry_after_ms)
                       when a worker's outstanding work reaches
                       N requests (0 = off)                     [0]
  --shed-queue-latency-ms N
                       shed when a worker's observed p95 queue
                       wait reaches N ms (0 = off)              [0]

WIRE PROTOCOL (serve)
  one JSON object per line; responses in request order per connection.
  -> {\"id\": 1, \"prompt\": [256, 5, 257], \"max_new_tokens\": 32}
  optional: \"stream\": true   one {\"id\",\"token\",\"pos\"} line per token
            \"deadline_ms\": N per-request deadline
  -> {\"metrics\": true}       per-worker scheduler + latency snapshot
  -> {\"metrics_prom\": true}  Prometheus text exposition, wrapped as
                             {\"content_type\", \"body\"} on one line
  -> {\"trace\": ID}           span history for request ID (lifecycle
                             transitions with timestamps + KV bytes)
  -> {\"flight_dump\": W}      worker W's last crash flight-recorder dump
  client disconnect cancels that connection's in-flight requests.
";

fn engine_config(args: &Args) -> Result<ServeConfig> {
    let mut cfg = match args.opt_str("config") {
        Some(path) => ServeConfig::from_json_file(&path)?,
        None => ServeConfig::new(args.str("artifacts", "sim://tiny")),
    };
    if args.opt_str("config").is_some() {
        if let Some(a) = args.opt_str("artifacts") {
            cfg.artifacts = a;
        }
    }
    if let Some(p) = args.opt_str("policy") {
        cfg.policy = PolicyKind::parse(&p).ok_or_else(|| anyhow!("unknown policy {p}"))?;
    }
    cfg.budget = args.usize("budget", cfg.budget)?;
    if let Some(f) = args.opt_f64("budget-frac")? {
        cfg.budget_frac = Some(f);
    }
    if args.flag("no-squeeze") {
        cfg.squeeze.enabled = false;
    }
    if args.flag("no-resident-scratch") {
        cfg.resident_scratch = false;
    }
    cfg.squeeze.p = args.f64("p", cfg.squeeze.p)?;
    cfg.max_batch = args.usize("max-batch", cfg.max_batch)?;
    cfg.kernel = args.str("kernel", &cfg.kernel);
    cfg.kv_pool_bytes = args.usize("kv-pool-mib", cfg.kv_pool_bytes >> 20)? << 20;
    cfg.host_spill_bytes = args.usize("host-spill-mib", cfg.host_spill_bytes >> 20)? << 20;
    cfg.kv_page_bytes = args.usize("kv-page-bytes", cfg.kv_page_bytes)?;
    cfg.batch_wait_ms = args.u64("batch-wait-ms", cfg.batch_wait_ms)?;
    cfg.request_deadline_ms = args.u64("request-deadline-ms", cfg.request_deadline_ms)?;
    cfg.faults.step_error_rate = args.f64("fault-step-error-rate", cfg.faults.step_error_rate)?;
    cfg.faults.latency_spike_ms = args.u64("fault-latency-spike", cfg.faults.latency_spike_ms)?;
    cfg.faults.latency_spike_rate =
        args.f64("fault-latency-spike-rate", cfg.faults.latency_spike_rate)?;
    cfg.faults.oom_at = args.u64("fault-oom-at", cfg.faults.oom_at)?;
    cfg.faults.seed = args.u64("fault-seed", cfg.faults.seed)?;
    cfg.max_retries = args.u64("max-retries", cfg.max_retries as u64)? as u32;
    cfg.max_worker_restarts = args.u64("max-worker-restarts", cfg.max_worker_restarts)?;
    cfg.shed_queue_depth = args.usize("shed-queue-depth", cfg.shed_queue_depth)?;
    cfg.shed_queue_latency_ms = args.u64("shed-queue-latency-ms", cfg.shed_queue_latency_ms)?;
    if let Some(k) = args.opt_str("spec-k") {
        let k: usize = k.parse().map_err(|_| anyhow!("--spec-k expects an integer, got {k}"))?;
        cfg = cfg.with_spec_k(k);
    }
    if let Some(t) = args.opt_str("trace-level") {
        cfg.trace_level = squeezeattention::metrics::TraceLevel::parse(&t)
            .ok_or_else(|| anyhow!("--trace-level expects off|spans|full, got {t}"))?;
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    let args = Args::from_env(&["no-squeeze", "no-resident-scratch", "verbose"])?;
    match args.positional(0).unwrap_or("help") {
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "inspect" => cmd_inspect(&args),
        "project" => cmd_project(&args),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}'\n\n{HELP}")),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = engine_config(args)?;
    let listen = args.str("listen", "127.0.0.1:7177");
    let workers = args.usize("workers", 1)?;
    let router = std::sync::Arc::new(Router::spawn(cfg, workers, RoutePolicy::LeastLoaded)?);
    let listener = std::net::TcpListener::bind(&listen)?;
    println!("listening on {listen} with {} worker(s)", router.n_workers());
    squeezeattention::coordinator::server::serve(listener, router)
}

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = engine_config(args)?;
    let n = args.usize("n", 8)?;
    let prompt_len = args.usize("prompt-len", 192)?;
    let max_new = args.usize("max-new", 48)?;
    let seed = args.u64("seed", 0)?;
    let mut eng = Engine::new(cfg)?;
    let mut spec = TraceSpec::closed(n, prompt_len, max_new, seed);
    if let Some(t) = args.opt_str("task") {
        let t = squeezeattention::workload::Task::parse(&t)
            .ok_or_else(|| anyhow!("unknown task {t}"))?;
        spec = spec.with_tasks(&[t]);
    }
    let items = spec.generate();
    let reqs: Vec<Request> = items
        .iter()
        .enumerate()
        .map(|(i, it)| Request::new(i as u64, it.sample.prompt.clone(), it.max_new_tokens))
        .collect();
    let outs = eng.generate_batch(reqs);
    let mut total_acc = 0.0;
    let mut scored = 0usize;
    for (it, out) in items.iter().zip(&outs) {
        let acc = answer_accuracy(&it.sample, &out.generated);
        if acc.is_finite() {
            total_acc += acc;
            scored += 1;
        }
        if args.flag("verbose") {
            println!(
                "[{}] {:9} acc={:.2} finish={:?} gen={}",
                out.id,
                it.sample.task.name(),
                acc,
                out.finish,
                tokenizer::render(trim_at_eos(&out.generated)),
            );
        }
    }
    let run = &eng.last_run;
    println!(
        "requests={} steps={} gen_tokens={} wall={:.2}s throughput={:.1} tok/s \
         evictions={} peak_kv={}B mean_acc={:.3}",
        outs.len(),
        run.decode_steps,
        run.generated_tokens,
        run.wall_s,
        run.generated_tokens as f64 / run.wall_s.max(1e-9),
        run.evictions,
        run.peak_pool_bytes,
        total_acc / scored.max(1) as f64,
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.str("artifacts", "sim://tiny");
    let m = if let Some(spec) = dir.strip_prefix("sim://") {
        squeezeattention::runtime::SimModel::new(spec)?.manifest().clone()
    } else {
        squeezeattention::config::Manifest::load(&dir)?
    };
    println!(
        "model={} layers={} d_model={} heads={} vocab={} max_seq={} trained={}",
        m.model.name, m.model.n_layer, m.model.d_model, m.model.n_head, m.model.vocab,
        m.model.max_seq, m.trained
    );
    println!("kv bytes/token = {}", m.model.kv_bytes_per_token());
    for a in &m.artifacts {
        println!(
            "  {:40} kind={:7} kernel={:6} len={:?} batch={:?} cap={:?}",
            a.file, a.kind, a.kernel, a.len, a.batch, a.cap
        );
    }
    Ok(())
}

fn cmd_project(args: &Args) -> Result<()> {
    let model = args.str("model", "Mistral-7B");
    let prompt_len = args.usize("prompt-len", 512)?;
    let gen_len = args.usize("gen-len", 1024)?;
    let batches = args.usize_list("batches", &[1, 32, 64, 128, 224])?;
    let budget_frac = args.f64("budget-frac", 0.2)?;
    let spec = simulator::by_name(&model)
        .ok_or_else(|| anyhow!("unknown model {model}; see simulator::ZOO"))?;
    let cluster = simulator::A100_40GB_X8;
    let b_init = ((prompt_len + gen_len) as f64 * budget_frac).round() as usize;
    let squeezed = KvPolicy::squeeze(spec.n_layer, spec.n_layer / 2, b_init, 0.35);
    println!(
        "{} on {} | prompt {} + gen {} | b_init {} tokens/layer",
        spec.name, cluster.name, prompt_len, gen_len, b_init
    );
    println!("{:>6} | {:>18} | {:>18}", "batch", "full (tok/s)", "squeeze (tok/s)");
    for b in batches {
        let full = simulator::simulate_decode(spec, &cluster, &KvPolicy::Full, b, prompt_len, gen_len);
        let sq = simulator::simulate_decode(spec, &cluster, &squeezed, b, prompt_len, gen_len);
        let f = full.tokens_per_s.map(|t| format!("{t:.1}")).unwrap_or_else(|| "OOM".into());
        let s = sq.tokens_per_s.map(|t| format!("{t:.1}")).unwrap_or_else(|| "OOM".into());
        println!("{b:>6} | {f:>18} | {s:>18}");
    }
    Ok(())
}
