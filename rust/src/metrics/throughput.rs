//! Token/request throughput accounting over a wall-clock window.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct ThroughputMeter {
    start: Instant,
    tokens: u64,
    requests: u64,
    decode_steps: u64,
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> Self {
        Self { start: Instant::now(), tokens: 0, requests: 0, decode_steps: 0 }
    }

    pub fn add_tokens(&mut self, n: u64) {
        self.tokens += n;
    }

    pub fn add_request(&mut self) {
        self.requests += 1;
    }

    pub fn add_decode_step(&mut self) {
        self.decode_steps += 1;
    }

    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    pub fn decode_steps(&self) -> u64 {
        self.decode_steps
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Generated tokens per second since construction.
    pub fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn requests_per_s(&self) -> f64 {
        self.requests as f64 / self.elapsed_s().max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        let mut m = ThroughputMeter::new();
        m.add_tokens(10);
        m.add_tokens(5);
        m.add_request();
        m.add_decode_step();
        assert_eq!(m.tokens(), 15);
        assert_eq!(m.requests(), 1);
        assert_eq!(m.decode_steps(), 1);
        assert!(m.tokens_per_s() > 0.0);
    }
}
