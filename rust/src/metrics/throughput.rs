//! Token/request throughput accounting over a wall-clock window.
//!
//! Lifetime rates (`tokens_per_s`) are computed since construction, which
//! flattens to a meaningless long-run average over server uptimes; the
//! windowed view (`since_last_snapshot`) reports rates over the interval
//! since the previous snapshot so a live exporter sees current load.

use std::time::Instant;

use crate::util::Json;

#[derive(Debug, Clone)]
pub struct ThroughputMeter {
    start: Instant,
    tokens: u64,
    requests: u64,
    decode_steps: u64,
    // Anchor of the current rate window (see `since_last_snapshot`).
    snap_at: Instant,
    snap_tokens: u64,
    snap_requests: u64,
    snap_decode_steps: u64,
}

/// Counter deltas and rates over one snapshot interval.
#[derive(Debug, Clone, Copy)]
pub struct RateWindow {
    /// Interval length in seconds (since the previous snapshot, or since
    /// construction for the first one).
    pub window_s: f64,
    pub tokens: u64,
    pub requests: u64,
    pub decode_steps: u64,
}

impl RateWindow {
    pub fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.window_s.max(1e-9)
    }

    pub fn requests_per_s(&self) -> f64 {
        self.requests as f64 / self.window_s.max(1e-9)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("window_s", Json::num(self.window_s)),
            ("tokens", Json::num(self.tokens as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("decode_steps", Json::num(self.decode_steps as f64)),
            ("tokens_per_s", Json::num(self.tokens_per_s())),
            ("requests_per_s", Json::num(self.requests_per_s())),
        ])
    }
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> Self {
        let now = Instant::now();
        Self {
            start: now,
            tokens: 0,
            requests: 0,
            decode_steps: 0,
            snap_at: now,
            snap_tokens: 0,
            snap_requests: 0,
            snap_decode_steps: 0,
        }
    }

    pub fn add_tokens(&mut self, n: u64) {
        self.tokens += n;
    }

    pub fn add_request(&mut self) {
        self.requests += 1;
    }

    pub fn add_decode_step(&mut self) {
        self.decode_steps += 1;
    }

    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    pub fn decode_steps(&self) -> u64 {
        self.decode_steps
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Generated tokens per second since construction (lifetime average).
    pub fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn requests_per_s(&self) -> f64 {
        self.requests as f64 / self.elapsed_s().max(1e-9)
    }

    /// Counter deltas since the previous call (or construction), then
    /// re-anchors the window. Call at the exporter's cadence to get current
    /// rates instead of the lifetime average.
    pub fn since_last_snapshot(&mut self) -> RateWindow {
        let now = Instant::now();
        let w = RateWindow {
            window_s: now.duration_since(self.snap_at).as_secs_f64(),
            tokens: self.tokens - self.snap_tokens,
            requests: self.requests - self.snap_requests,
            decode_steps: self.decode_steps - self.snap_decode_steps,
        };
        self.snap_at = now;
        self.snap_tokens = self.tokens;
        self.snap_requests = self.requests;
        self.snap_decode_steps = self.decode_steps;
        w
    }

    /// Lifetime + current-window rates as one JSON object.
    pub fn to_json(&mut self) -> Json {
        let window = self.since_last_snapshot();
        Json::obj(vec![
            ("tokens", Json::num(self.tokens as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("decode_steps", Json::num(self.decode_steps as f64)),
            ("elapsed_s", Json::num(self.elapsed_s())),
            ("tokens_per_s", Json::num(self.tokens_per_s())),
            ("requests_per_s", Json::num(self.requests_per_s())),
            ("window", window.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        let mut m = ThroughputMeter::new();
        m.add_tokens(10);
        m.add_tokens(5);
        m.add_request();
        m.add_decode_step();
        assert_eq!(m.tokens(), 15);
        assert_eq!(m.requests(), 1);
        assert_eq!(m.decode_steps(), 1);
        assert!(m.tokens_per_s() > 0.0);
    }

    #[test]
    fn window_resets_but_lifetime_accumulates() {
        let mut m = ThroughputMeter::new();
        m.add_tokens(10);
        m.add_request();
        let w1 = m.since_last_snapshot();
        assert_eq!(w1.tokens, 10);
        assert_eq!(w1.requests, 1);
        m.add_tokens(7);
        let w2 = m.since_last_snapshot();
        assert_eq!(w2.tokens, 7);
        assert_eq!(w2.requests, 0);
        // lifetime counters unaffected by snapshots
        assert_eq!(m.tokens(), 17);
        assert_eq!(m.requests(), 1);
        // an idle window reports zero
        let w3 = m.since_last_snapshot();
        assert_eq!(w3.tokens, 0);
    }

    #[test]
    fn window_json_shape() {
        let mut m = ThroughputMeter::new();
        m.add_tokens(4);
        let j = m.to_json();
        assert_eq!(j.get("tokens").unwrap().as_usize(), Some(4));
        let w = j.get("window").unwrap();
        assert_eq!(w.get("tokens").unwrap().as_usize(), Some(4));
        assert!(w.get("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
    }
}
