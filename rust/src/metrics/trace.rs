//! Request trace spans, step-phase timers, per-layer squeeze introspection,
//! and the crash flight recorder.
//!
//! Three cooperating pieces, all bounded and allocation-light on the hot
//! path:
//!
//! - [`FlightRecorder`] — a preallocated ring buffer of [`SpanEvent`]s, one
//!   per request lifecycle transition (submit → admit → prefill → squeeze →
//!   first token → suspend/resume/retry → retire), each stamped with a
//!   monotonic timestamp and the request's KV bytes at that moment. It is
//!   shared (`Arc`) between the engine thread that records and the
//!   router/supervisor threads that query (`{"trace": <id>}`) or dump it
//!   when a worker dies. Recording at [`TraceLevel::Off`] is a single enum
//!   compare — no lock, no clock read.
//! - [`PhaseTimers`] — per-phase histograms ([`StepPhase`]: admission /
//!   gather / model / verify / evict / commit) answering "where does a
//!   decode millisecond go". Engine-owned, recorded only at
//!   [`TraceLevel::Full`] (two `Instant::now()` reads per phase per step).
//! - [`LayerTable`] — cumulative per-layer evicted rows / KV bytes, the
//!   live-server reconstruction of the paper's Figure-1 heatmap when joined
//!   with each active sequence's `BudgetPlan` (budgets, groups, cosine layer
//!   means). Always on: it costs two array adds on an eviction event that
//!   already rewrites the cache.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::Json;

use super::histogram::{Histogram, HistogramSummary};

/// How much telemetry the hot path records.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum TraceLevel {
    /// No spans, no phase timers. Costs one enum compare per would-be event.
    Off,
    /// Lifecycle spans + flight recorder (per-transition, not per-token).
    #[default]
    Spans,
    /// Spans plus per-phase step timing (clock reads inside `Engine::step`).
    Full,
}

impl TraceLevel {
    pub fn name(&self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Spans => "spans",
            TraceLevel::Full => "full",
        }
    }

    pub fn parse(s: &str) -> Option<TraceLevel> {
        match s {
            "off" => Some(TraceLevel::Off),
            "spans" => Some(TraceLevel::Spans),
            "full" => Some(TraceLevel::Full),
            _ => None,
        }
    }

    /// Lifecycle spans recorded?
    pub fn spans(&self) -> bool {
        *self >= TraceLevel::Spans
    }

    /// Step-phase timers recorded?
    pub fn full(&self) -> bool {
        *self >= TraceLevel::Full
    }
}

/// A request lifecycle transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Request entered the engine queue.
    Submit,
    /// Request left the queue for a decode slot.
    Admit,
    /// Prompt prefill finished.
    Prefill,
    /// Layer budgets resolved (SqueezeAttention allocation or uniform).
    Squeeze,
    /// First generated token committed.
    FirstToken,
    /// Sequence swapped out to the host tier (or restart-requeued).
    Suspend,
    /// Suspended sequence swapped back in, decode continuing.
    Resume,
    /// Sequence re-queued after a contained worker fault.
    Retry,
    /// Request retired (any terminal `FinishReason`).
    Retire,
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Submit => "submit",
            SpanKind::Admit => "admit",
            SpanKind::Prefill => "prefill",
            SpanKind::Squeeze => "squeeze",
            SpanKind::FirstToken => "first_token",
            SpanKind::Suspend => "suspend",
            SpanKind::Resume => "resume",
            SpanKind::Retry => "retry",
            SpanKind::Retire => "retire",
        }
    }
}

/// One recorded lifecycle transition.
#[derive(Debug, Clone, Copy)]
pub struct SpanEvent {
    /// Request id as the recording engine sees it (the worker-local ticket
    /// behind a router; the caller's id in direct-engine use — see the
    /// recorder's alias table).
    pub id: u64,
    pub kind: SpanKind,
    /// Monotonic milliseconds since the recorder's epoch.
    pub t_ms: f64,
    /// KV bytes attributed to the request at this transition (0 where no
    /// cache exists yet, e.g. submit).
    pub kv_bytes: u64,
}

impl SpanEvent {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("kind", Json::str(self.kind.name())),
            ("t_ms", Json::num(self.t_ms)),
            ("kv_bytes", Json::num(self.kv_bytes as f64)),
        ])
    }
}

/// Default flight-recorder depth (events, not requests).
pub const DEFAULT_RING_CAP: usize = 1024;
/// Bounded local-ticket → public-id alias history.
const ALIAS_CAP: usize = 1024;

struct RecorderInner {
    /// Preallocated ring; `head` is the next write slot, `ring.len() <= cap`.
    ring: Vec<SpanEvent>,
    cap: usize,
    head: usize,
    /// Events ever recorded (ring overwrites don't forget the count).
    total: u64,
    /// (engine-local id, public id) pairs, newest last, bounded.
    aliases: Vec<(u64, u64)>,
    /// Most recent crash dump, kept for post-mortem queries.
    last_dump: Option<Json>,
}

/// Shared span ring: engine threads record, router/supervisor threads query
/// and dump. All methods are `&self`; a poisoned lock (worker panic) is
/// recovered, never propagated — the recorder must stay readable exactly
/// when things crash.
#[derive(Debug)]
pub struct FlightRecorder {
    level: TraceLevel,
    epoch: Instant,
    inner: Mutex<RecorderInner>,
}

impl std::fmt::Debug for RecorderInner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RecorderInner")
            .field("len", &self.ring.len())
            .field("total", &self.total)
            .finish()
    }
}

impl FlightRecorder {
    pub fn new(level: TraceLevel, cap: usize) -> Self {
        let cap = cap.max(1);
        Self {
            level,
            epoch: Instant::now(),
            inner: Mutex::new(RecorderInner {
                ring: Vec::with_capacity(cap),
                cap,
                head: 0,
                total: 0,
                aliases: Vec::with_capacity(ALIAS_CAP.min(cap)),
                last_dump: None,
            }),
        }
    }

    pub fn with_level(level: TraceLevel) -> Self {
        Self::new(level, DEFAULT_RING_CAP)
    }

    pub fn level(&self) -> TraceLevel {
        self.level
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, RecorderInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Milliseconds since the recorder's epoch (monotonic).
    pub fn now_ms(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e3
    }

    /// Record one lifecycle transition. No-op (one enum compare) at
    /// [`TraceLevel::Off`].
    pub fn record(&self, id: u64, kind: SpanKind, kv_bytes: u64) {
        if !self.level.spans() {
            return;
        }
        let ev = SpanEvent { id, kind, t_ms: self.now_ms(), kv_bytes };
        let mut g = self.lock();
        if g.ring.len() < g.cap {
            g.ring.push(ev);
        } else {
            let head = g.head;
            g.ring[head] = ev;
        }
        g.head = (g.head + 1) % g.cap;
        g.total += 1;
    }

    /// Remember that engine-local `local` serves public request id `public`
    /// (the router rewrites ids to worker-local tickets in flight).
    pub fn note_alias(&self, local: u64, public: u64) {
        if !self.level.spans() {
            return;
        }
        let mut g = self.lock();
        if g.aliases.len() >= ALIAS_CAP {
            g.aliases.remove(0);
        }
        g.aliases.push((local, public));
    }

    fn chronological(g: &RecorderInner) -> impl Iterator<Item = &SpanEvent> {
        // Oldest → newest: ring[head..] then ring[..head] once wrapped.
        let start = if g.ring.len() == g.cap { g.head } else { 0 };
        g.ring[start..].iter().chain(g.ring[..start].iter())
    }

    /// All retained spans for a request id, oldest first. The id is tried
    /// directly first, then through the alias table (public → local), so
    /// both wire-level and engine-local ids resolve.
    pub fn spans_for(&self, id: u64) -> Vec<SpanEvent> {
        let g = self.lock();
        let direct: Vec<SpanEvent> =
            Self::chronological(&g).filter(|e| e.id == id).copied().collect();
        if !direct.is_empty() {
            return direct;
        }
        // Newest alias wins (tickets recycle public ids across retries).
        let Some(&(local, _)) = g.aliases.iter().rev().find(|(_, p)| *p == id) else {
            return Vec::new();
        };
        Self::chronological(&g).filter(|e| e.id == local).copied().collect()
    }

    /// The most recent `n` spans, oldest first.
    pub fn recent(&self, n: usize) -> Vec<SpanEvent> {
        let g = self.lock();
        let all: Vec<SpanEvent> = Self::chronological(&g).copied().collect();
        let skip = all.len().saturating_sub(n);
        all[skip..].to_vec()
    }

    /// Answer a `{"trace": <id>}` query.
    pub fn trace_json(&self, id: u64) -> Json {
        let spans = self.spans_for(id);
        Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("found", Json::Bool(!spans.is_empty())),
            ("spans", Json::Arr(spans.iter().map(|s| s.to_json()).collect())),
        ])
    }

    /// Build a structured crash report from the ring (entire retained
    /// history, oldest first), remember it as `last_dump`, and return it.
    /// Called on worker death, `WorkerError`, and retry-budget exhaustion.
    pub fn dump(&self, reason: &str) -> Json {
        let report = {
            let g = self.lock();
            let spans: Vec<Json> = Self::chronological(&g).map(|s| s.to_json()).collect();
            let aliases: Vec<Json> = g
                .aliases
                .iter()
                .map(|(l, p)| {
                    Json::obj(vec![
                        ("local", Json::num(*l as f64)),
                        ("public", Json::num(*p as f64)),
                    ])
                })
                .collect();
            Json::obj(vec![
                ("flight_recorder", Json::Bool(true)),
                ("reason", Json::str(reason)),
                ("t_ms", Json::num(self.epoch.elapsed().as_secs_f64() * 1e3)),
                ("events_total", Json::num(g.total as f64)),
                ("spans", Json::Arr(spans)),
                ("aliases", Json::Arr(aliases)),
            ])
        };
        self.lock().last_dump = Some(report.clone());
        report
    }

    /// The most recent crash dump, if any worker fault fired one.
    pub fn last_dump(&self) -> Option<Json> {
        self.lock().last_dump.clone()
    }

    /// Events ever recorded (not bounded by the ring).
    pub fn total(&self) -> u64 {
        self.lock().total
    }
}

/// A timed section of `Engine::step`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepPhase {
    /// Lifecycle sweep + queue/suspended admission (prefill included).
    Admission = 0,
    /// KV gather into the decode scratch (resident appends or full refills).
    Gather = 1,
    /// The batched backend decode call itself.
    Model = 2,
    /// Speculative verification micro-steps (zero outside spec mode; its
    /// inner gathers/decodes also accumulate into `Gather` / `Model`).
    Verify = 3,
    /// Per-layer cache re-compression after token appends (the 2D
    /// eviction work).
    Evict = 4,
    /// Token append + sampling + event emission, minus the evict section.
    Commit = 5,
}

pub const STEP_PHASES: [StepPhase; 6] = [
    StepPhase::Admission,
    StepPhase::Gather,
    StepPhase::Model,
    StepPhase::Verify,
    StepPhase::Evict,
    StepPhase::Commit,
];

impl StepPhase {
    pub fn name(&self) -> &'static str {
        match self {
            StepPhase::Admission => "admission",
            StepPhase::Gather => "gather",
            StepPhase::Model => "model",
            StepPhase::Verify => "verify",
            StepPhase::Evict => "evict",
            StepPhase::Commit => "commit",
        }
    }
}

/// Per-phase seconds-per-step histograms (engine-owned, recorded at
/// `TraceLevel::Full`).
#[derive(Debug, Clone, Default)]
pub struct PhaseTimers {
    hists: [Histogram; 6],
}

impl PhaseTimers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record seconds spent in `phase` during one step.
    pub fn record(&mut self, phase: StepPhase, secs: f64) {
        self.hists[phase as usize].record(secs);
    }

    pub fn summaries(&mut self) -> Vec<(&'static str, HistogramSummary)> {
        STEP_PHASES
            .iter()
            .map(|p| (p.name(), self.hists[*p as usize].summary()))
            .collect()
    }

    pub fn to_json(&mut self) -> Json {
        Json::Obj(
            self.summaries().into_iter().map(|(n, s)| (n.to_string(), s.to_json())).collect(),
        )
    }
}

/// One step's phase durations, accumulated with plain adds and flushed into
/// [`PhaseTimers`] once per step (so a phase touched many times per step —
/// e.g. commit, once per slot — still costs one histogram record).
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseAcc {
    secs: [f64; 6],
}

impl PhaseAcc {
    pub fn add(&mut self, phase: StepPhase, secs: f64) {
        self.secs[phase as usize] += secs;
    }

    /// Flush nonzero phase totals into the histograms and reset.
    pub fn flush_into(&mut self, timers: &mut PhaseTimers) {
        for p in STEP_PHASES {
            let s = self.secs[p as usize];
            if s > 0.0 {
                timers.record(p, s);
            }
        }
        self.secs = [0.0; 6];
    }
}

/// Cumulative per-layer eviction activity — with each active sequence's
/// `BudgetPlan` this is the layer-indexed squeeze table the
/// `{"metrics_prom": true}` exposition and `Engine::squeeze_table_json`
/// export.
#[derive(Debug, Clone, Default)]
pub struct LayerTable {
    evicted_rows: Vec<u64>,
    evicted_bytes: Vec<u64>,
}

impl LayerTable {
    pub fn new(n_layer: usize) -> Self {
        Self { evicted_rows: vec![0; n_layer], evicted_bytes: vec![0; n_layer] }
    }

    pub fn n_layer(&self) -> usize {
        self.evicted_rows.len()
    }

    /// Account `rows` KV rows (`bytes` bytes) evicted from `layer`.
    pub fn note_eviction(&mut self, layer: usize, rows: u64, bytes: u64) {
        if layer < self.evicted_rows.len() {
            self.evicted_rows[layer] += rows;
            self.evicted_bytes[layer] += bytes;
        }
    }

    pub fn evicted_rows(&self) -> &[u64] {
        &self.evicted_rows
    }

    pub fn evicted_bytes(&self) -> &[u64] {
        &self.evicted_bytes
    }

    /// Layer-indexed array of `{layer, evicted_rows, evicted_bytes}`.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            (0..self.evicted_rows.len())
                .map(|l| {
                    Json::obj(vec![
                        ("layer", Json::num(l as f64)),
                        ("evicted_rows", Json::num(self.evicted_rows[l] as f64)),
                        ("evicted_bytes", Json::num(self.evicted_bytes[l] as f64)),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_roundtrip() {
        for l in [TraceLevel::Off, TraceLevel::Spans, TraceLevel::Full] {
            assert_eq!(TraceLevel::parse(l.name()), Some(l));
        }
        assert_eq!(TraceLevel::parse("bogus"), None);
        assert!(!TraceLevel::Off.spans());
        assert!(TraceLevel::Spans.spans());
        assert!(!TraceLevel::Spans.full());
        assert!(TraceLevel::Full.full());
    }

    #[test]
    fn off_records_nothing() {
        let r = FlightRecorder::with_level(TraceLevel::Off);
        r.record(1, SpanKind::Submit, 0);
        r.note_alias(1, 99);
        assert_eq!(r.total(), 0);
        assert!(r.spans_for(1).is_empty());
    }

    #[test]
    fn ring_wraps_keeping_newest() {
        let r = FlightRecorder::new(TraceLevel::Spans, 4);
        for i in 0..10u64 {
            r.record(i, SpanKind::Submit, i);
        }
        assert_eq!(r.total(), 10);
        let recent = r.recent(100);
        assert_eq!(recent.len(), 4);
        let ids: Vec<u64> = recent.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9]);
        // timestamps monotone non-decreasing in chronological order
        for w in recent.windows(2) {
            assert!(w[0].t_ms <= w[1].t_ms);
        }
    }

    #[test]
    fn spans_for_filters_and_orders() {
        let r = FlightRecorder::new(TraceLevel::Spans, 64);
        r.record(7, SpanKind::Submit, 0);
        r.record(8, SpanKind::Submit, 0);
        r.record(7, SpanKind::Admit, 100);
        r.record(7, SpanKind::Retire, 100);
        let spans = r.spans_for(7);
        let kinds: Vec<&str> = spans.iter().map(|e| e.kind.name()).collect();
        assert_eq!(kinds, vec!["submit", "admit", "retire"]);
        assert_eq!(spans[1].kv_bytes, 100);
    }

    #[test]
    fn alias_resolves_public_ids() {
        let r = FlightRecorder::new(TraceLevel::Spans, 64);
        // engine records under local ticket 3; the wire knows id 42
        r.note_alias(3, 42);
        r.record(3, SpanKind::Submit, 0);
        r.record(3, SpanKind::Retire, 0);
        assert_eq!(r.spans_for(42).len(), 2);
        let j = r.trace_json(42);
        assert_eq!(j.get("found").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("spans").unwrap().as_arr().unwrap().len(), 2);
        let miss = r.trace_json(41);
        assert_eq!(miss.get("found").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn dump_is_structured_and_remembered() {
        let r = FlightRecorder::new(TraceLevel::Spans, 8);
        r.record(1, SpanKind::Submit, 0);
        r.record(1, SpanKind::Retire, 64);
        assert!(r.last_dump().is_none());
        let d = r.dump("worker_death");
        assert_eq!(d.get("reason").unwrap().as_str(), Some("worker_death"));
        assert_eq!(d.get("spans").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(r.last_dump().unwrap(), d);
        // the dump is line-serializable JSON
        assert!(Json::parse(&d.to_string()).is_ok());
    }

    #[test]
    fn phase_timers_accumulate_per_step() {
        let mut acc = PhaseAcc::default();
        let mut timers = PhaseTimers::new();
        acc.add(StepPhase::Gather, 0.25);
        acc.add(StepPhase::Commit, 0.5);
        acc.add(StepPhase::Commit, 0.5);
        acc.flush_into(&mut timers);
        let sums = timers.summaries();
        let get = |name: &str| sums.iter().find(|(n, _)| *n == name).unwrap().1;
        assert_eq!(get("gather").count, 1);
        assert!((get("commit").mean - 1.0).abs() < 1e-12);
        assert_eq!(get("model").count, 0);
        // flushed: a second flush records nothing
        acc.flush_into(&mut timers);
        assert_eq!(timers.summaries().iter().find(|(n, _)| *n == "gather").unwrap().1.count, 1);
    }

    #[test]
    fn layer_table_accumulates() {
        let mut t = LayerTable::new(3);
        t.note_eviction(0, 4, 1024);
        t.note_eviction(0, 1, 256);
        t.note_eviction(2, 2, 512);
        t.note_eviction(9, 1, 1); // out of range: ignored, not a panic
        assert_eq!(t.evicted_rows(), &[5, 0, 2]);
        let j = t.to_json();
        let rows = j.as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].get("evicted_bytes").unwrap().as_usize(), Some(1280));
        assert_eq!(rows[2].get("layer").unwrap().as_usize(), Some(2));
    }
}
