//! Continuous-batching scheduler observability: queue depth, batch
//! occupancy, admission/preemption/retirement counters. One instance lives
//! inside the engine's `Scheduler` and is updated on every step; gauges
//! (`queue_depth`, `running`) reflect the state after the most recent step,
//! counters are cumulative since the last (re)configure.

#[derive(Debug, Clone, Default)]
pub struct SchedulerMetrics {
    /// Configured decode slots (batch capacity).
    pub slots: usize,
    /// Current queued requests (gauge).
    pub queue_depth: usize,
    /// High-water mark of the queue.
    pub queue_peak: usize,
    /// Currently running sequences (gauge).
    pub running: usize,
    /// High-water mark of concurrently running sequences.
    pub peak_occupancy: usize,
    /// Decode steps executed (steps with at least one running sequence).
    pub steps: u64,
    /// Sum over steps of the number of sequences in that step's batch
    /// (mean occupancy = occupancy_sum / steps).
    pub occupancy_sum: u64,
    /// Requests admitted into a decode slot (includes re-admissions).
    pub admitted: u64,
    /// Admission attempts skipped because the KV pool lacked headroom.
    pub deferred_admissions: u64,
    /// Running sequences preempted and requeued to resolve pool OOM.
    pub preemptions: u64,
    /// Requests that finished normally (EOS or length) and freed a slot.
    pub completed: u64,
    /// Requests rejected at submission (queue backpressure).
    pub rejected: u64,
    /// Requests failed with OOM (could not fit even with the pool drained).
    pub oom_failures: u64,
}

impl SchedulerMetrics {
    /// Mean sequences per decode step.
    pub fn mean_occupancy(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.steps as f64
        }
    }

    /// Mean occupancy as a fraction of configured slots.
    pub fn batch_utilization(&self) -> f64 {
        if self.slots == 0 {
            0.0
        } else {
            self.mean_occupancy() / self.slots as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_math() {
        let mut m = SchedulerMetrics { slots: 4, ..Default::default() };
        assert_eq!(m.mean_occupancy(), 0.0);
        assert_eq!(m.batch_utilization(), 0.0);
        m.steps = 4;
        m.occupancy_sum = 10;
        assert!((m.mean_occupancy() - 2.5).abs() < 1e-12);
        assert!((m.batch_utilization() - 0.625).abs() < 1e-12);
    }
}
