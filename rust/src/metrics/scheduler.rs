//! Continuous-batching scheduler observability: queue depth, batch
//! occupancy, admission/preemption/retirement counters, and the swap
//! counters of the two-tier KV hierarchy (suspend = swap-out to host,
//! resume = swap-in to device). One instance lives inside the engine's
//! `Scheduler` and is updated on every step; gauges (`queue_depth`,
//! `running`, `suspended`) reflect the state after the most recent step,
//! counters are cumulative since the last (re)configure.

use crate::util::Json;

#[derive(Debug, Clone, Default)]
pub struct SchedulerMetrics {
    /// Configured decode slots (batch capacity).
    pub slots: usize,
    /// Current queued requests (gauge).
    pub queue_depth: usize,
    /// High-water mark of the queue.
    pub queue_peak: usize,
    /// Currently running sequences (gauge).
    pub running: usize,
    /// High-water mark of concurrently running sequences.
    pub peak_occupancy: usize,
    /// Decode steps executed (steps with at least one running sequence).
    pub steps: u64,
    /// Sum over steps of the number of sequences in that step's batch
    /// (mean occupancy = occupancy_sum / steps).
    pub occupancy_sum: u64,
    /// Requests handed to this engine (accepted into the queue *or*
    /// rejected at submission). The conservation identity
    /// `submitted == completed + cancelled + deadline_exceeded +
    /// oom_failures + requests_failed + rejected + in-flight` holds at
    /// every step boundary — `tests/metrics_conservation.rs` pins it.
    pub submitted: u64,
    /// Requests admitted into a decode slot (includes re-admissions).
    pub admitted: u64,
    /// Admission attempts skipped because the KV pool lacked headroom.
    pub deferred_admissions: u64,
    /// Running sequences preempted (swapped out or requeued) to resolve
    /// device-pool OOM.
    pub preemptions: u64,
    /// Currently suspended sequences (swapped out to the host tier; gauge).
    pub suspended: usize,
    /// Sequences whose KV state moved to the host tier instead of being
    /// discarded: preemption suspends (device→host migration) plus prefills
    /// parked at admission while the device pool was transiently full — so
    /// this may exceed `preemptions`.
    pub swap_outs: u64,
    /// Suspended sequences migrated host→device and resumed mid-decode
    /// (no re-prefill, partial output kept).
    pub swap_ins: u64,
    /// Re-prefills avoided by serving a snapshot instead: incremented on
    /// every swap-in, since each resume replaces what restart-from-scratch
    /// semantics would have recomputed (equal to `swap_ins` by
    /// construction today; kept as its own counter because it is the
    /// quantity the swap-vs-restart bench compares, and the two can
    /// diverge once partial/prefix resume lands).
    pub restarts_avoided: u64,
    /// High-water mark of host-tier (spill) bytes in use.
    pub host_bytes_peak: usize,
    /// Pages physically moved device→host by suspend migrations. The
    /// pool's `migrated_into(Host)` traffic equals
    /// `pages_swapped_out * page_bytes` exactly — swaps move page-table
    /// entries, not byte blobs.
    pub pages_swapped_out: u64,
    /// Pages physically moved host→device by resume migrations (same
    /// traffic identity against `migrated_into(Device)`).
    pub pages_swapped_in: u64,
    /// Device-tier bytes allocated by the paged KV pool (gauge,
    /// page-granular).
    pub kv_alloc_bytes: usize,
    /// Device-tier bytes actually holding KV rows (gauge). The difference
    /// against `kv_alloc_bytes` is internal fragmentation: tail-page slack
    /// the fixed page size strands.
    pub kv_used_bytes: usize,
    /// Host-tier bytes allocated by the paged KV pool (gauge).
    pub host_alloc_bytes: usize,
    /// Host-tier bytes actually holding suspended KV rows (gauge).
    pub host_used_bytes: usize,
    /// Pages currently referenced by more than one sequence (prefix
    /// sharing; gauge).
    pub shared_pages: usize,
    /// Cumulative copy-on-write page privatizations (first divergent write
    /// to a shared page).
    pub cow_copies: u64,
    /// Pool accounting faults detected and absorbed (release underflow /
    /// double-free of a page). Nonzero means a bookkeeping bug was caught.
    pub accounting_errors: u64,
    /// Requests that finished normally (EOS or length) and freed a slot.
    pub completed: u64,
    /// Requests rejected at submission (queue backpressure).
    pub rejected: u64,
    /// Requests failed with OOM (could not fit even with the pool drained).
    pub oom_failures: u64,
    /// Requests cancelled via their `CancelToken` — from the queue, a
    /// decode slot, or the suspended set (the last frees the host tier
    /// without a swap-in).
    pub cancelled: u64,
    /// Requests that exceeded their deadline at a step boundary.
    pub deadline_exceeded: u64,
    /// Speculative bursts executed (one per sequence per decode step while
    /// spec mode is on — the denominator for the per-step spec rates).
    pub spec_steps: u64,
    /// Draft-model tokens proposed across all bursts (`draft_k` per burst,
    /// less when the sequence is near its length cap).
    pub spec_drafted: u64,
    /// Drafted tokens the target model verified and committed. Excludes the
    /// per-burst bonus token the target samples itself, so
    /// `spec_accepted / spec_drafted` is the draft acceptance rate.
    pub spec_accepted: u64,
    /// Drafted KV rows rolled back after verification rejected them
    /// (`spec_drafted - spec_accepted` when every burst runs to
    /// completion; tracked separately because a mid-burst cancel rolls
    /// back rows that were never verified).
    pub spec_rollback_tokens: u64,
    /// KV payload bytes copied into batch scratch by the decode gather
    /// path. With resident scratch the steady-state contribution per step
    /// is O(rows appended); with `--no-resident-scratch` it is O(total
    /// resident KV) — the ratio is the hot-path win `bench_hotpath`
    /// measures.
    pub kv_bytes_copied: u64,
    /// Slot gathers that rewrote a scratch slot from row 0 (first use,
    /// or residency invalidated by eviction/rollback/resume/reassignment).
    pub gather_full_refills: u64,
    /// Slot gathers that appended only the rows grown since the last sync.
    pub gather_incremental_appends: u64,
    /// Bytes currently held by per-tier scratch K/V buffers (gauge; the
    /// idle sweep bounds it).
    pub scratch_retained_bytes: usize,
    /// Scratch tiers reclaimed by the idle sweep.
    pub scratch_tiers_evicted: u64,
    /// Backend step errors the engine contained (each affects a whole
    /// decode batch; the per-sequence consequences show up in
    /// `requests_retried` / the `WorkerError` retirements).
    pub worker_errors: u64,
    /// Sequences re-queued (suspend or restart) after a contained worker
    /// fault, bounded by the per-request retry budget.
    pub requests_retried: u64,
    /// Requests retired abnormally by an engine fault: `WorkerError`
    /// (retry budget exhausted) or `Failed` (uncontained step error).
    /// Distinct from `worker_errors`, which counts faulted *batches* —
    /// a contained fault whose retries succeed bumps `worker_errors`
    /// without ever bumping this.
    pub requests_failed: u64,
    /// Requests the router rejected with `Overloaded` before they reached
    /// this engine (stamped by the router into its per-worker snapshot).
    pub requests_shed: u64,
    /// Faults the runtime's armed `FaultPlan` actually injected (errors +
    /// latency spikes); mirrors `Runtime::faults_injected`.
    pub faults_injected: u64,
    /// Times the supervisor respawned this worker's engine after a death
    /// (router-level; an engine never observes its own restart).
    pub worker_restarts: u64,
}

impl SchedulerMetrics {
    /// Mean sequences per decode step.
    pub fn mean_occupancy(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.steps as f64
        }
    }

    /// Mean occupancy as a fraction of configured slots.
    pub fn batch_utilization(&self) -> f64 {
        if self.slots == 0 {
            0.0
        } else {
            self.mean_occupancy() / self.slots as f64
        }
    }

    /// Fraction of drafted tokens the target accepted.
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_drafted == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_drafted as f64
        }
    }

    /// Mean tokens committed per speculative burst: accepted drafts plus the
    /// one token the target always samples itself. > 1 means speculation is
    /// paying for the draft passes.
    pub fn spec_accepted_per_step(&self) -> f64 {
        if self.spec_steps == 0 {
            0.0
        } else {
            (self.spec_accepted + self.spec_steps) as f64 / self.spec_steps as f64
        }
    }

    /// Mean drafted rows rolled back per burst (rollback depth).
    pub fn spec_rollback_depth(&self) -> f64 {
        if self.spec_steps == 0 {
            0.0
        } else {
            self.spec_rollback_tokens as f64 / self.spec_steps as f64
        }
    }

    /// Full snapshot as JSON (the router's metrics export).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("slots", Json::num(self.slots as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("queue_peak", Json::num(self.queue_peak as f64)),
            ("running", Json::num(self.running as f64)),
            ("peak_occupancy", Json::num(self.peak_occupancy as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("mean_occupancy", Json::num(self.mean_occupancy())),
            ("submitted", Json::num(self.submitted as f64)),
            ("admitted", Json::num(self.admitted as f64)),
            ("deferred_admissions", Json::num(self.deferred_admissions as f64)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("suspended", Json::num(self.suspended as f64)),
            ("swap_outs", Json::num(self.swap_outs as f64)),
            ("swap_ins", Json::num(self.swap_ins as f64)),
            ("restarts_avoided", Json::num(self.restarts_avoided as f64)),
            ("host_bytes_peak", Json::num(self.host_bytes_peak as f64)),
            ("pages_swapped_out", Json::num(self.pages_swapped_out as f64)),
            ("pages_swapped_in", Json::num(self.pages_swapped_in as f64)),
            ("kv_alloc_bytes", Json::num(self.kv_alloc_bytes as f64)),
            ("kv_used_bytes", Json::num(self.kv_used_bytes as f64)),
            ("host_alloc_bytes", Json::num(self.host_alloc_bytes as f64)),
            ("host_used_bytes", Json::num(self.host_used_bytes as f64)),
            ("shared_pages", Json::num(self.shared_pages as f64)),
            ("cow_copies", Json::num(self.cow_copies as f64)),
            ("accounting_errors", Json::num(self.accounting_errors as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("oom_failures", Json::num(self.oom_failures as f64)),
            ("cancelled", Json::num(self.cancelled as f64)),
            ("deadline_exceeded", Json::num(self.deadline_exceeded as f64)),
            ("spec_steps", Json::num(self.spec_steps as f64)),
            ("spec_drafted", Json::num(self.spec_drafted as f64)),
            ("spec_accepted", Json::num(self.spec_accepted as f64)),
            ("spec_rollback_tokens", Json::num(self.spec_rollback_tokens as f64)),
            ("spec_acceptance_rate", Json::num(self.spec_acceptance_rate())),
            ("spec_accepted_per_step", Json::num(self.spec_accepted_per_step())),
            ("spec_rollback_depth", Json::num(self.spec_rollback_depth())),
            ("kv_bytes_copied", Json::num(self.kv_bytes_copied as f64)),
            ("gather_full_refills", Json::num(self.gather_full_refills as f64)),
            ("gather_incremental_appends", Json::num(self.gather_incremental_appends as f64)),
            ("scratch_retained_bytes", Json::num(self.scratch_retained_bytes as f64)),
            ("scratch_tiers_evicted", Json::num(self.scratch_tiers_evicted as f64)),
            ("worker_errors", Json::num(self.worker_errors as f64)),
            ("requests_retried", Json::num(self.requests_retried as f64)),
            ("requests_failed", Json::num(self.requests_failed as f64)),
            ("requests_shed", Json::num(self.requests_shed as f64)),
            ("faults_injected", Json::num(self.faults_injected as f64)),
            ("worker_restarts", Json::num(self.worker_restarts as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_math() {
        let mut m = SchedulerMetrics { slots: 4, ..Default::default() };
        assert_eq!(m.mean_occupancy(), 0.0);
        assert_eq!(m.batch_utilization(), 0.0);
        m.steps = 4;
        m.occupancy_sum = 10;
        assert!((m.mean_occupancy() - 2.5).abs() < 1e-12);
        assert!((m.batch_utilization() - 0.625).abs() < 1e-12);
    }

    #[test]
    fn json_snapshot_exports_lifecycle_counters() {
        let m = SchedulerMetrics {
            slots: 4,
            cancelled: 3,
            deadline_exceeded: 2,
            steps: 5,
            occupancy_sum: 10,
            ..Default::default()
        };
        let j = m.to_json();
        assert_eq!(j.get("slots").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("cancelled").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("deadline_exceeded").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("mean_occupancy").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn spec_rates_and_json_export() {
        let mut m = SchedulerMetrics::default();
        assert_eq!(m.spec_acceptance_rate(), 0.0);
        assert_eq!(m.spec_accepted_per_step(), 0.0);
        assert_eq!(m.spec_rollback_depth(), 0.0);
        m.spec_steps = 10;
        m.spec_drafted = 40;
        m.spec_accepted = 30;
        m.spec_rollback_tokens = 10;
        assert!((m.spec_acceptance_rate() - 0.75).abs() < 1e-12);
        assert!((m.spec_accepted_per_step() - 4.0).abs() < 1e-12);
        assert!((m.spec_rollback_depth() - 1.0).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.get("spec_steps").unwrap().as_usize(), Some(10));
        assert_eq!(j.get("spec_drafted").unwrap().as_usize(), Some(40));
        assert_eq!(j.get("spec_accepted").unwrap().as_usize(), Some(30));
        assert_eq!(j.get("spec_rollback_tokens").unwrap().as_usize(), Some(10));
        assert_eq!(j.get("spec_acceptance_rate").unwrap().as_f64(), Some(0.75));
        assert_eq!(j.get("spec_accepted_per_step").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("spec_rollback_depth").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn json_snapshot_exports_gather_counters() {
        let m = SchedulerMetrics {
            kv_bytes_copied: 123_456,
            gather_full_refills: 7,
            gather_incremental_appends: 90,
            scratch_retained_bytes: 8192,
            scratch_tiers_evicted: 2,
            ..Default::default()
        };
        let j = m.to_json();
        assert_eq!(j.get("kv_bytes_copied").unwrap().as_usize(), Some(123_456));
        assert_eq!(j.get("gather_full_refills").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("gather_incremental_appends").unwrap().as_usize(), Some(90));
        assert_eq!(j.get("scratch_retained_bytes").unwrap().as_usize(), Some(8192));
        assert_eq!(j.get("scratch_tiers_evicted").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn json_snapshot_exports_fault_counters() {
        let m = SchedulerMetrics {
            worker_errors: 2,
            requests_retried: 3,
            requests_shed: 4,
            faults_injected: 5,
            worker_restarts: 1,
            ..Default::default()
        };
        let j = m.to_json();
        assert_eq!(j.get("worker_errors").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("requests_retried").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("requests_shed").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("faults_injected").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("worker_restarts").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn json_snapshot_exports_paging_gauges() {
        let m = SchedulerMetrics {
            pages_swapped_out: 5,
            pages_swapped_in: 3,
            kv_alloc_bytes: 4096,
            kv_used_bytes: 3000,
            host_alloc_bytes: 2048,
            host_used_bytes: 1024,
            shared_pages: 2,
            cow_copies: 1,
            accounting_errors: 0,
            ..Default::default()
        };
        let j = m.to_json();
        assert_eq!(j.get("pages_swapped_out").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("pages_swapped_in").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("kv_alloc_bytes").unwrap().as_usize(), Some(4096));
        assert_eq!(j.get("kv_used_bytes").unwrap().as_usize(), Some(3000));
        assert_eq!(j.get("host_alloc_bytes").unwrap().as_usize(), Some(2048));
        assert_eq!(j.get("host_used_bytes").unwrap().as_usize(), Some(1024));
        assert_eq!(j.get("shared_pages").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("cow_copies").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("accounting_errors").unwrap().as_usize(), Some(0));
    }
}
