//! Serving metrics: latency histograms, throughput counters, memory peaks.

mod histogram;
mod throughput;

pub use histogram::Histogram;
pub use throughput::ThroughputMeter;
