//! Serving metrics: latency histograms, throughput counters, memory peaks,
//! and the continuous-batching scheduler's queue/occupancy/preemption
//! counters.

mod histogram;
mod scheduler;
mod throughput;

pub use histogram::{Histogram, HistogramSummary};
pub use scheduler::SchedulerMetrics;
pub use throughput::ThroughputMeter;
