//! Serving metrics: latency histograms, throughput counters, memory peaks,
//! the continuous-batching scheduler's queue/occupancy/preemption counters,
//! request trace spans + the crash flight recorder, and the Prometheus
//! text exposition.

mod export;
mod histogram;
mod scheduler;
mod throughput;
mod trace;

pub use export::{is_well_formed_prometheus, PromWriter};
pub use histogram::{Histogram, HistogramSummary};
pub use scheduler::SchedulerMetrics;
pub use throughput::{RateWindow, ThroughputMeter};
pub use trace::{
    FlightRecorder, LayerTable, PhaseAcc, PhaseTimers, SpanEvent, SpanKind, StepPhase, TraceLevel,
    DEFAULT_RING_CAP, STEP_PHASES,
};
