//! Prometheus text-format exposition (version 0.0.4) for the serving
//! metrics: every `SchedulerMetrics` counter, the latency/phase histogram
//! summaries, throughput windows, and the per-layer squeeze series.
//!
//! [`PromWriter`] buffers samples per metric name and emits them grouped
//! under a single `# TYPE` header in `finish()` — the format requires all
//! samples of one metric to be contiguous, which a naive per-worker loop
//! would violate. Callers feed it JSON snapshots the metrics types already
//! produce (`json_fields` exports every numeric field of an object), so a
//! counter added to `SchedulerMetrics::to_json` shows up in the exposition
//! without touching this file.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::Json;

use super::histogram::HistogramSummary;

/// Sample-buffering Prometheus text writer.
#[derive(Debug, Default)]
pub struct PromWriter {
    // metric name -> (type, sample lines in insertion order)
    metrics: BTreeMap<String, (&'static str, Vec<String>)>,
}

/// Restrict a metric name to the Prometheus charset `[a-zA-Z0-9_:]`.
fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() || c == ':' { c } else { '_' }).collect()
}

/// Escape a label value per the exposition format.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Render an f64 the way our JSON does: integral values without the
/// fraction, everything else via the shortest float form.
fn format_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

impl PromWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Buffer one sample. Non-finite values are skipped (empty histograms
    /// summarize to NaN; absent beats NaN for every scraper).
    pub fn write(&mut self, name: &str, kind: &'static str, labels: &[(&str, &str)], v: f64) {
        if !v.is_finite() {
            return;
        }
        let name = sanitize(name);
        let mut line = name.clone();
        if !labels.is_empty() {
            line.push('{');
            for (i, (k, val)) in labels.iter().enumerate() {
                if i > 0 {
                    line.push(',');
                }
                let _ = write!(line, "{}=\"{}\"", sanitize(k), escape_label(val));
            }
            line.push('}');
        }
        let _ = write!(line, " {}", format_value(v));
        self.metrics.entry(name).or_insert_with(|| (kind, Vec::new())).1.push(line);
    }

    /// Export every numeric field of a JSON object as `{prefix}_{key}`.
    /// Non-numeric fields are ignored.
    pub fn json_fields(
        &mut self,
        prefix: &str,
        kind: &'static str,
        labels: &[(&str, &str)],
        obj: &Json,
    ) {
        if let Json::Obj(m) = obj {
            for (k, v) in m {
                if let Some(n) = v.as_f64() {
                    self.write(&format!("{prefix}_{k}"), kind, labels, n);
                }
            }
        }
    }

    /// Export a histogram summary as `{name}_{count,mean,p50,p95,p99,max}`.
    pub fn summary(&mut self, name: &str, labels: &[(&str, &str)], s: &HistogramSummary) {
        self.write(&format!("{name}_count"), "gauge", labels, s.count as f64);
        self.write(&format!("{name}_mean"), "gauge", labels, s.mean);
        self.write(&format!("{name}_p50"), "gauge", labels, s.p50);
        self.write(&format!("{name}_p95"), "gauge", labels, s.p95);
        self.write(&format!("{name}_p99"), "gauge", labels, s.p99);
        self.write(&format!("{name}_max"), "gauge", labels, s.max);
    }

    /// Render the exposition: per metric, one `# TYPE` header then all its
    /// samples, metrics in name order.
    pub fn finish(self) -> String {
        let mut out = String::new();
        for (name, (kind, lines)) in self.metrics {
            let _ = writeln!(out, "# TYPE {name} {kind}");
            for l in lines {
                out.push_str(&l);
                out.push('\n');
            }
        }
        out
    }
}

/// Structural check that `text` is well-formed exposition output: every
/// non-comment line is `name[{labels}] value` with a parseable value, and
/// samples stay grouped under their `# TYPE` header. Used by the wire tests
/// to assert the `{"metrics_prom": true}` payload is scrapeable.
pub fn is_well_formed_prometheus(text: &str) -> bool {
    let mut seen_types: Vec<String> = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let (Some(name), Some(_kind), None) = (it.next(), it.next(), it.next()) else {
                return false;
            };
            if seen_types.iter().any(|n| n == name) {
                return false; // duplicate TYPE header — samples not grouped
            }
            seen_types.push(name.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue; // other comments are legal
        }
        // name[{labels}] value
        let (head, value) = match line.rsplit_once(' ') {
            Some(x) => x,
            None => return false,
        };
        if value.parse::<f64>().is_err() {
            return false;
        }
        let name = head.split('{').next().unwrap_or("");
        if name.is_empty()
            || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        {
            return false;
        }
        if head.contains('{') && !head.ends_with('}') {
            return false;
        }
        // samples must appear under the most recent TYPE for their name
        match seen_types.last() {
            Some(current) if name.starts_with(current.as_str()) || current == name => {}
            _ => return false,
        }
    }
    !seen_types.is_empty()
}

#[cfg(test)]
mod tests {
    use super::super::SchedulerMetrics;
    use super::*;

    #[test]
    fn groups_samples_under_one_type_header() {
        let mut w = PromWriter::new();
        w.write("sa_up", "gauge", &[("worker", "0")], 1.0);
        w.write("sa_up", "gauge", &[("worker", "1")], 1.0);
        w.write("sa_steps", "counter", &[("worker", "0")], 42.0);
        let text = w.finish();
        assert_eq!(text.matches("# TYPE sa_up gauge").count(), 1);
        assert!(text.contains("sa_up{worker=\"0\"} 1"));
        assert!(text.contains("sa_up{worker=\"1\"} 1"));
        assert!(text.contains("sa_steps{worker=\"0\"} 42"));
        assert!(is_well_formed_prometheus(&text));
    }

    #[test]
    fn every_scheduler_counter_exported() {
        let m = SchedulerMetrics { steps: 7, completed: 3, ..Default::default() };
        let j = m.to_json();
        let n_fields = match &j {
            Json::Obj(m) => m.len(),
            _ => 0,
        };
        let mut w = PromWriter::new();
        w.json_fields("sa_sched", "gauge", &[("worker", "0")], &j);
        let text = w.finish();
        let samples = text.lines().filter(|l| !l.starts_with('#')).count();
        assert_eq!(samples, n_fields);
        assert!(text.contains("sa_sched_steps{worker=\"0\"} 7"));
        assert!(text.contains("sa_sched_completed{worker=\"0\"} 3"));
        assert!(is_well_formed_prometheus(&text));
    }

    #[test]
    fn skips_non_finite_and_escapes_labels() {
        let mut w = PromWriter::new();
        w.write("sa_nan", "gauge", &[], f64::NAN);
        w.write("sa ok", "gauge", &[("state", "he\"llo\n")], 2.5);
        let text = w.finish();
        assert!(!text.contains("sa_nan"));
        assert!(text.contains("sa_ok{state=\"he\\\"llo\\n\"} 2.5"));
        assert!(is_well_formed_prometheus(&text));
    }

    #[test]
    fn summary_export() {
        let s = HistogramSummary { count: 3, mean: 0.5, p50: 0.4, p95: 0.9, p99: 0.9, max: 1.0 };
        let mut w = PromWriter::new();
        w.summary("sa_ttft_s", &[("worker", "0")], &s);
        let text = w.finish();
        assert!(text.contains("sa_ttft_s_count{worker=\"0\"} 3"));
        assert!(text.contains("sa_ttft_s_p95{worker=\"0\"} 0.9"));
        // empty summaries (NaN quantiles) drop the sample, keep the count
        let mut w = PromWriter::new();
        w.summary("sa_itl_s", &[], &HistogramSummary::default());
        let text = w.finish();
        assert!(text.contains("sa_itl_s_count 0"));
        assert!(!text.contains("sa_itl_s_p95"));
    }

    #[test]
    fn validator_rejects_garbage() {
        assert!(!is_well_formed_prometheus(""));
        assert!(!is_well_formed_prometheus("no type header 1"));
        assert!(!is_well_formed_prometheus("# TYPE a gauge\na notanumber"));
        assert!(!is_well_formed_prometheus("# TYPE a gauge\na 1\n# TYPE a gauge\na 2"));
    }
}
