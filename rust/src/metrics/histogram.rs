//! Simple exact-quantile latency histogram (stores samples; serving runs in
//! this repo are small enough that exactness beats sketching).
//!
//! The engine keeps one of these for per-request queue latency — the time a
//! request spent waiting for a decode slot, *including* time suspended in
//! the host tier after a preemption (accounted from the preserved
//! `t_submit`). `HistogramSummary` is the exportable view (bench reports,
//! experiment logs).

use crate::util::Json;

#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

/// Point-in-time quantile summary of a histogram (for reports and JSON
/// experiment logs).
#[derive(Debug, Clone, Copy, Default)]
pub struct HistogramSummary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl HistogramSummary {
    pub fn to_json(&self) -> Json {
        let num = |v: f64| if v.is_finite() { Json::num(v) } else { Json::Null };
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", num(self.mean)),
            ("p50", num(self.p50)),
            ("p95", num(self.p95)),
            ("p99", num(self.p99)),
            ("max", num(self.max)),
        ])
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Quantile in [0,1] via nearest-rank.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let idx = ((q * self.samples.len() as f64).ceil() as usize)
            .clamp(1, self.samples.len()) - 1;
        self.samples[idx]
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&mut self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn max(&mut self) -> f64 {
        self.quantile(1.0)
    }

    pub fn summary(&mut self) -> HistogramSummary {
        HistogramSummary {
            count: self.len(),
            mean: self.mean(),
            p50: self.p50(),
            p95: self.p95(),
            p99: self.p99(),
            max: self.max(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.p50(), 50.0);
        assert_eq!(h.p95(), 95.0);
        assert_eq!(h.max(), 100.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_is_nan() {
        let mut h = Histogram::new();
        assert!(h.p50().is_nan());
        assert!(h.mean().is_nan());
    }

    #[test]
    fn single_sample() {
        let mut h = Histogram::new();
        h.record(3.0);
        assert_eq!(h.p50(), 3.0);
        assert_eq!(h.p99(), 3.0);
    }

    #[test]
    fn summary_exports_json() {
        let mut h = Histogram::new();
        for i in 1..=4 {
            h.record(i as f64);
        }
        let s = h.summary();
        assert_eq!(s.count, 4);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.max, 4.0);
        let j = s.to_json();
        assert_eq!(j.get("count").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("max").unwrap().as_f64(), Some(4.0));
        // empty histogram: NaNs serialize as null, not invalid JSON
        let j = Histogram::new().summary().to_json();
        assert!(matches!(j.get("mean"), Some(Json::Null)));
    }
}
