//! Bounded latency histogram: exact quantiles below a sample cap, uniform
//! reservoir sampling (Vitter's Algorithm R, deterministic RNG) above it.
//!
//! The engine keeps one of these for per-request queue latency — the time a
//! request spent waiting for a decode slot, *including* time suspended in
//! the host tier after a preemption (accounted from the preserved
//! `t_submit`). `HistogramSummary` is the exportable view (bench reports,
//! experiment logs).
//!
//! Memory is bounded at `cap` samples regardless of uptime; `count`, `mean`
//! and `max` stay exact over all recorded samples (running accumulators),
//! only the quantiles turn into reservoir estimates past the cap.
//! Non-finite samples are rejected at `record()` and counted in `dropped`
//! instead of poisoning the sort (quantile sorting uses `f64::total_cmp`,
//! which is total even if a NaN ever slipped in).

use crate::util::Json;
use crate::util::Rng;

/// Default reservoir capacity: plenty for exact quantiles on bench-sized
/// runs while bounding a long-lived server's per-histogram memory to ~64 KiB.
pub const DEFAULT_SAMPLE_CAP: usize = 8192;

#[derive(Debug, Clone)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
    cap: usize,
    /// Total finite samples ever recorded (may exceed `samples.len()`).
    count: u64,
    /// Running sum over all finite samples — exact mean past the cap.
    sum: f64,
    /// Running max over all finite samples — exact even if the reservoir
    /// evicts the extreme.
    running_max: f64,
    /// Non-finite samples rejected at `record()`.
    dropped: u64,
    rng: Rng,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Point-in-time quantile summary of a histogram (for reports and JSON
/// experiment logs).
#[derive(Debug, Clone, Copy, Default)]
pub struct HistogramSummary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl HistogramSummary {
    pub fn to_json(&self) -> Json {
        let num = |v: f64| if v.is_finite() { Json::num(v) } else { Json::Null };
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", num(self.mean)),
            ("p50", num(self.p50)),
            ("p95", num(self.p95)),
            ("p99", num(self.p99)),
            ("max", num(self.max)),
        ])
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::with_cap(DEFAULT_SAMPLE_CAP)
    }

    /// A histogram holding at most `cap` samples; quantiles are exact until
    /// `cap` samples have been recorded, reservoir estimates after.
    pub fn with_cap(cap: usize) -> Self {
        Self {
            samples: Vec::new(),
            sorted: false,
            cap: cap.max(1),
            count: 0,
            sum: 0.0,
            running_max: f64::NEG_INFINITY,
            dropped: 0,
            rng: Rng::seed_from_u64(0x4849_5354),
        }
    }

    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            self.dropped += 1;
            return;
        }
        self.count += 1;
        self.sum += v;
        if v > self.running_max {
            self.running_max = v;
        }
        if self.samples.len() < self.cap {
            self.samples.push(v);
            self.sorted = false;
        } else {
            // Algorithm R: item `count` replaces a reservoir slot with
            // probability cap/count, keeping the reservoir uniform.
            let j = (self.rng.next_u64() % self.count) as usize;
            if j < self.cap {
                self.samples[j] = v;
                self.sorted = false;
            }
        }
    }

    /// Total finite samples recorded (not the reservoir size).
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Non-finite samples rejected at `record()`.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Exact mean over all recorded samples (running sum, not the reservoir).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(f64::total_cmp);
            self.sorted = true;
        }
    }

    /// Quantile in [0,1] via nearest-rank over the retained samples (exact
    /// below the cap, reservoir estimate above it).
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let idx = ((q * self.samples.len() as f64).ceil() as usize)
            .clamp(1, self.samples.len()) - 1;
        self.samples[idx]
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&mut self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    /// Exact max over all recorded samples.
    pub fn max(&mut self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.running_max
    }

    pub fn summary(&mut self) -> HistogramSummary {
        HistogramSummary {
            count: self.len(),
            mean: self.mean(),
            p50: self.p50(),
            p95: self.p95(),
            p99: self.p99(),
            max: self.max(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.p50(), 50.0);
        assert_eq!(h.p95(), 95.0);
        assert_eq!(h.max(), 100.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_is_nan() {
        let mut h = Histogram::new();
        assert!(h.p50().is_nan());
        assert!(h.mean().is_nan());
    }

    #[test]
    fn single_sample() {
        let mut h = Histogram::new();
        h.record(3.0);
        assert_eq!(h.p50(), 3.0);
        assert_eq!(h.p99(), 3.0);
    }

    #[test]
    fn summary_exports_json() {
        let mut h = Histogram::new();
        for i in 1..=4 {
            h.record(i as f64);
        }
        let s = h.summary();
        assert_eq!(s.count, 4);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.max, 4.0);
        let j = s.to_json();
        assert_eq!(j.get("count").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("max").unwrap().as_f64(), Some(4.0));
        // empty histogram: NaNs serialize as null, not invalid JSON
        let j = Histogram::new().summary().to_json();
        assert!(matches!(j.get("mean"), Some(Json::Null)));
    }

    #[test]
    fn non_finite_rejected_not_panicking() {
        let mut h = Histogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(f64::NEG_INFINITY);
        h.record(1.0);
        assert_eq!(h.dropped(), 3);
        assert_eq!(h.len(), 1);
        // quantile path must not panic even with rejects interleaved
        assert_eq!(h.p50(), 1.0);
        assert!((h.mean() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn memory_bounded_above_cap() {
        let mut h = Histogram::with_cap(64);
        for i in 0..10_000 {
            h.record(i as f64);
        }
        assert_eq!(h.samples.len(), 64);
        assert_eq!(h.len(), 10_000);
        // mean and max stay exact past the cap
        assert!((h.mean() - 4999.5).abs() < 1e-9);
        assert_eq!(h.max(), 9999.0);
    }

    #[test]
    fn reservoir_quantiles_approximate_uniform() {
        let mut h = Histogram::with_cap(512);
        for i in 0..100_000 {
            h.record(i as f64);
        }
        // Uniform 0..100k: p50 ≈ 50k. A 512-slot reservoir's nearest-rank
        // p50 has stderr ≈ n / (2*sqrt(cap)) ≈ 2.2k; allow 5 sigma.
        assert!((h.p50() - 50_000.0).abs() < 12_000.0, "p50 {}", h.p50());
        assert!(h.p95() > 85_000.0);
    }

    #[test]
    fn deterministic_reservoir() {
        let mk = || {
            let mut h = Histogram::with_cap(32);
            for i in 0..5_000 {
                h.record((i * 7 % 997) as f64);
            }
            h.summary()
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.p50, b.p50);
        assert_eq!(a.p99, b.p99);
    }

    #[test]
    fn exact_below_cap() {
        let mut h = Histogram::with_cap(128);
        for i in 1..=128 {
            h.record(i as f64);
        }
        assert_eq!(h.p50(), 64.0);
        assert_eq!(h.max(), 128.0);
    }
}
