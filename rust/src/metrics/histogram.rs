//! Simple exact-quantile latency histogram (stores samples; serving runs in
//! this repo are small enough that exactness beats sketching).

#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Quantile in [0,1] via nearest-rank.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let idx = ((q * self.samples.len() as f64).ceil() as usize)
            .clamp(1, self.samples.len()) - 1;
        self.samples[idx]
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&mut self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn max(&mut self) -> f64 {
        self.quantile(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.p50(), 50.0);
        assert_eq!(h.p95(), 95.0);
        assert_eq!(h.max(), 100.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_is_nan() {
        let mut h = Histogram::new();
        assert!(h.p50().is_nan());
        assert!(h.mean().is_nan());
    }

    #[test]
    fn single_sample() {
        let mut h = Histogram::new();
        h.record(3.0);
        assert_eq!(h.p50(), 3.0);
        assert_eq!(h.p99(), 3.0);
    }
}
