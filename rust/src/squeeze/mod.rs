//! SqueezeAttention — the paper's contribution: layer-wise KV budget
//! optimization. Cosine-similarity importance statistics (`cosine`), 1-D
//! k-means grouping (`kmeans`), and the Algorithm-1 budget allocator
//! (`allocator`).

pub mod allocator;
pub mod cosine;
pub mod kmeans;

pub use allocator::{allocate, BudgetPlan};
pub use cosine::{cosine, CosineStats};
pub use kmeans::{kmeans_1d, Clustering};
