//! Layer-importance statistics from the prefill cosine-similarity probe.
//!
//! The prefill artifact emits `cos_sims[n_layer, L]` — per layer, the cosine
//! similarity of the residual stream across the attention block for every
//! prompt position (paper Eq. 5). This module reduces that to the per-layer
//! mean over *valid* prompt tokens (Algorithm 1, lines 2–4) and supports
//! accumulation across prompts (the Fig. 2 heatmaps average 200 prompts).

use crate::runtime::Tensor;

/// Accumulated per-layer cosine statistics.
#[derive(Debug, Clone)]
pub struct CosineStats {
    n_layer: usize,
    /// Sum of per-token cosine values per layer.
    sums: Vec<f64>,
    /// Token count per layer.
    counts: Vec<u64>,
    /// Optional per-position accumulation for heatmaps: `[n_layer][pos]`.
    heat_sums: Vec<Vec<f64>>,
    heat_counts: Vec<Vec<u64>>,
}

impl CosineStats {
    pub fn new(n_layer: usize) -> Self {
        Self {
            n_layer,
            sums: vec![0.0; n_layer],
            counts: vec![0; n_layer],
            heat_sums: vec![Vec::new(); n_layer],
            heat_counts: vec![Vec::new(); n_layer],
        }
    }

    pub fn n_layer(&self) -> usize {
        self.n_layer
    }

    /// Fold in one prefill's `cos_sims` tensor (`[n_layer, L]`), counting
    /// only the first `valid_len` positions (the rest is bucket padding).
    /// Position 0 is skipped: BOS changes the stream degenerately and its
    /// cosine is uninformative noise shared by all layers.
    pub fn observe(&mut self, cos_sims: &Tensor, valid_len: usize) {
        assert_eq!(cos_sims.shape.len(), 2);
        assert_eq!(cos_sims.shape[0], self.n_layer);
        let l = cos_sims.shape[1];
        let valid = valid_len.min(l);
        for layer in 0..self.n_layer {
            if self.heat_sums[layer].len() < valid {
                self.heat_sums[layer].resize(valid, 0.0);
                self.heat_counts[layer].resize(valid, 0);
            }
            for pos in 1..valid {
                let v = cos_sims.at(&[layer, pos]) as f64;
                if !v.is_finite() {
                    continue;
                }
                self.sums[layer] += v;
                self.counts[layer] += 1;
                self.heat_sums[layer][pos] += v;
                self.heat_counts[layer][pos] += 1;
            }
        }
    }

    /// Per-layer mean cosine similarity (the Algorithm-1 importance signal).
    /// Layers with no observations get 1.0 (= "attention changed nothing"),
    /// which k-means puts in the least-important group — the safe default.
    pub fn layer_means(&self) -> Vec<f64> {
        (0..self.n_layer)
            .map(|i| {
                if self.counts[i] == 0 {
                    1.0
                } else {
                    self.sums[i] / self.counts[i] as f64
                }
            })
            .collect()
    }

    /// Heatmap row for a layer: mean cosine per prompt position (Fig. 2).
    pub fn heatmap_row(&self, layer: usize) -> Vec<f64> {
        self.heat_sums[layer]
            .iter()
            .zip(&self.heat_counts[layer])
            .map(|(&s, &c)| if c == 0 { f64::NAN } else { s / c as f64 })
            .collect()
    }

    pub fn observations(&self, layer: usize) -> u64 {
        self.counts[layer]
    }

    /// Merge another accumulator (same n_layer) into this one.
    pub fn merge(&mut self, other: &CosineStats) {
        assert_eq!(self.n_layer, other.n_layer);
        for i in 0..self.n_layer {
            self.sums[i] += other.sums[i];
            self.counts[i] += other.counts[i];
            let n = other.heat_sums[i].len();
            if self.heat_sums[i].len() < n {
                self.heat_sums[i].resize(n, 0.0);
                self.heat_counts[i].resize(n, 0);
            }
            for p in 0..n {
                self.heat_sums[i][p] += other.heat_sums[i][p];
                self.heat_counts[i][p] += other.heat_counts[i][p];
            }
        }
    }
}

/// Plain cosine similarity between two host vectors (used by the simulator
/// substrate and tests; the request path uses the Pallas kernel's output).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut dot = 0f64;
    let mut na = 0f64;
    let mut nb = 0f64;
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    dot / (na.sqrt() * nb.sqrt() + 1e-8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;

    #[test]
    fn observe_masks_padding_and_bos() {
        let mut s = CosineStats::new(2);
        // layer 0: [x, 0.5, 0.5, PAD-garbage], layer 1: [x, 0.9, 0.9, garbage]
        let t = Tensor::from_vec(
            &[2, 4],
            vec![0.0, 0.5, 0.5, 77.0, 0.0, 0.9, 0.9, -77.0],
        )
        .unwrap();
        s.observe(&t, 3); // only positions 1..3 counted
        let m = s.layer_means();
        assert!((m[0] - 0.5).abs() < 1e-6);
        assert!((m[1] - 0.9).abs() < 1e-6);
        assert_eq!(s.observations(0), 2);
    }

    #[test]
    fn empty_layers_default_unimportant() {
        let s = CosineStats::new(3);
        assert_eq!(s.layer_means(), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn merge_matches_joint_observation() {
        let t1 = Tensor::from_vec(&[1, 3], vec![0.0, 0.2, 0.4]).unwrap();
        let t2 = Tensor::from_vec(&[1, 3], vec![0.0, 0.8, 0.6]).unwrap();
        let mut a = CosineStats::new(1);
        a.observe(&t1, 3);
        let mut b = CosineStats::new(1);
        b.observe(&t2, 3);
        a.merge(&b);
        let mut joint = CosineStats::new(1);
        joint.observe(&t1, 3);
        joint.observe(&t2, 3);
        assert!((a.layer_means()[0] - joint.layer_means()[0]).abs() < 1e-12);
    }

    #[test]
    fn cosine_host() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
        assert!((cosine(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn heatmap_rows() {
        let mut s = CosineStats::new(1);
        let t = Tensor::from_vec(&[1, 4], vec![0.0, 0.1, 0.2, 0.3]).unwrap();
        s.observe(&t, 4);
        let row = s.heatmap_row(0);
        assert!(row[0].is_nan()); // BOS position skipped
        assert!((row[2] - 0.2).abs() < 1e-6);
    }
}
