//! 1-D k-means for layer grouping (paper §4.1, Algorithm 1 line 5).
//!
//! Deterministic: centroids are seeded at quantiles of the sorted input, and
//! Lloyd iterations on one dimension preserve the order of centroids, so the
//! returned group ids are stable and ordered — group `k-1` always has the
//! *largest* cosine similarity (the least important layers, "G3").

/// Result of clustering `values` into `k` ordered groups.
#[derive(Debug, Clone, PartialEq)]
pub struct Clustering {
    /// Group id per input value, in input order. Ids are ordered by centroid:
    /// group 0 = smallest values (most important layers).
    pub assignment: Vec<usize>,
    /// Final centroid per group, ascending.
    pub centroids: Vec<f64>,
    /// Iterations until convergence.
    pub iterations: usize,
}

impl Clustering {
    pub fn group_size(&self, g: usize) -> usize {
        self.assignment.iter().filter(|&&a| a == g).count()
    }

    /// Member indices of group `g`, in input order.
    pub fn members(&self, g: usize) -> Vec<usize> {
        self.assignment
            .iter()
            .enumerate()
            .filter(|(_, &a)| a == g)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Cluster 1-D `values` into `k` groups. Panics if `values` is empty or
/// `k == 0`; if there are fewer distinct values than `k`, duplicate
/// centroids collapse and high groups may be empty — callers (the budget
/// allocator) treat empty G3 as "no reallocation".
pub fn kmeans_1d(values: &[f64], k: usize, max_iter: usize) -> Clustering {
    assert!(!values.is_empty() && k > 0);
    let n = values.len();
    let mut sorted: Vec<f64> = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());

    // Quantile seeding: centroid j at the (j + 0.5)/k quantile.
    let mut centroids: Vec<f64> = (0..k)
        .map(|j| {
            let q = (j as f64 + 0.5) / k as f64;
            sorted[((q * n as f64) as usize).min(n - 1)]
        })
        .collect();

    let mut assignment = vec![0usize; n];
    let mut iterations = 0;
    for it in 0..max_iter {
        iterations = it + 1;
        // Assign to nearest centroid (ties -> lower group).
        let mut changed = false;
        for (i, &v) in values.iter().enumerate() {
            let mut best = 0usize;
            let mut bd = f64::INFINITY;
            for (j, &c) in centroids.iter().enumerate() {
                let d = (v - c).abs();
                if d < bd {
                    bd = d;
                    best = j;
                }
            }
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        // Update centroids (empty groups keep their position).
        for j in 0..k {
            let members: Vec<f64> = values
                .iter()
                .zip(&assignment)
                .filter(|(_, &a)| a == j)
                .map(|(&v, _)| v)
                .collect();
            if !members.is_empty() {
                centroids[j] = members.iter().sum::<f64>() / members.len() as f64;
            }
        }
        if !changed && it > 0 {
            break;
        }
    }

    // Normalize: relabel groups so centroids ascend (quantile seeding keeps
    // them sorted already, but guard against pathological updates).
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| centroids[a].partial_cmp(&centroids[b]).unwrap());
    let mut relabel = vec![0usize; k];
    for (new, &old) in order.iter().enumerate() {
        relabel[old] = new;
    }
    let assignment = assignment.into_iter().map(|a| relabel[a]).collect();
    let mut cs = centroids.clone();
    cs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Clustering { assignment, centroids: cs, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_obvious_groups() {
        let vals = [0.1, 0.12, 0.5, 0.52, 0.9, 0.92];
        let c = kmeans_1d(&vals, 3, 50);
        assert_eq!(c.assignment, vec![0, 0, 1, 1, 2, 2]);
        assert!(c.centroids[0] < c.centroids[1] && c.centroids[1] < c.centroids[2]);
    }

    #[test]
    fn order_preserving() {
        // Higher value never lands in a lower group.
        let vals = [0.3, 0.8, 0.1, 0.95, 0.5, 0.2, 0.85];
        let c = kmeans_1d(&vals, 3, 50);
        for i in 0..vals.len() {
            for j in 0..vals.len() {
                if vals[i] < vals[j] {
                    assert!(c.assignment[i] <= c.assignment[j]);
                }
            }
        }
    }

    #[test]
    fn constant_input_collapses() {
        let vals = [0.5; 8];
        let c = kmeans_1d(&vals, 3, 50);
        // All assigned to one group; others empty.
        let g = c.assignment[0];
        assert!(c.assignment.iter().all(|&a| a == g));
    }

    #[test]
    fn k_one() {
        let vals = [1.0, 2.0, 3.0];
        let c = kmeans_1d(&vals, 1, 10);
        assert_eq!(c.assignment, vec![0, 0, 0]);
        assert!((c.centroids[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn members_and_sizes() {
        let vals = [0.1, 0.9, 0.1, 0.9];
        let c = kmeans_1d(&vals, 2, 50);
        assert_eq!(c.group_size(0), 2);
        assert_eq!(c.members(1), vec![1, 3]);
    }
}
