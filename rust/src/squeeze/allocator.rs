//! Algorithm 1: layer-wise budget reallocation.
//!
//! Given per-layer importance (mean cosine similarity, *lower = more
//! important*), cluster into `groups` (paper: 3) with 1-D k-means. The
//! highest-cosine group G3 ("unimportant") keeps only `p × b_init`; the freed
//! budget is split equally among the remaining layers. Total budget is
//! conserved exactly (integer rounding remainder is handed out
//! deterministically, one token at a time, to the most important layers).


use super::kmeans::{kmeans_1d, Clustering};
use crate::config::SqueezeConfig;

/// The outcome of one budget-reallocation decision.
#[derive(Debug, Clone)]
pub struct BudgetPlan {
    /// Per-layer token budget.
    pub budgets: Vec<usize>,
    /// Group id per layer (0 = most important … groups-1 = least).
    pub groups: Vec<usize>,
    /// Per-layer importance signal that produced the plan.
    pub layer_means: Vec<f64>,
    /// True when reallocation actually moved budget (false = identity:
    /// squeeze disabled, degenerate clustering, or p = 1).
    pub reallocated: bool,
}

impl BudgetPlan {
    /// Uniform plan: every layer gets `b_init` (the baselines).
    pub fn uniform(n_layer: usize, b_init: usize) -> Self {
        Self {
            budgets: vec![b_init; n_layer],
            groups: vec![0; n_layer],
            layer_means: vec![0.0; n_layer],
            reallocated: false,
        }
    }

    pub fn total(&self) -> usize {
        self.budgets.iter().sum()
    }

    pub fn max_budget(&self) -> usize {
        self.budgets.iter().copied().max().unwrap_or(0)
    }

    /// Count of layers in the least-important group.
    pub fn unimportant_layers(&self) -> usize {
        let g = self.groups.iter().copied().max().unwrap_or(0);
        if !self.reallocated {
            return 0;
        }
        self.groups.iter().filter(|&&x| x == g).count()
    }
}

/// Compute the Algorithm-1 budget plan.
///
/// * `layer_means` — mean cosine per layer (higher = less important).
/// * `b_init` — the uniform per-layer budget being redistributed.
pub fn allocate(layer_means: &[f64], b_init: usize, cfg: &SqueezeConfig) -> BudgetPlan {
    let n = layer_means.len();
    assert!(n > 0);
    if !cfg.enabled || cfg.p >= 1.0 || n <= cfg.groups || b_init == 0 {
        let mut plan = BudgetPlan::uniform(n, b_init);
        plan.layer_means = layer_means.to_vec();
        return plan;
    }

    let clustering: Clustering = kmeans_1d(layer_means, cfg.groups, 100);
    let g3 = cfg.groups - 1;
    let g3_members = clustering.members(g3);
    let keep = clustering.assignment.iter().filter(|&&a| a != g3).count();
    // Degenerate: everything (or nothing) is "unimportant" — do not move.
    if g3_members.is_empty() || keep == 0 {
        let mut plan = BudgetPlan::uniform(n, b_init);
        plan.layer_means = layer_means.to_vec();
        plan.groups = clustering.assignment;
        return plan;
    }

    let total = n * b_init;
    // G3 keeps p*b_init, floored at min_budget.
    let g3_budget = ((b_init as f64 * cfg.p).round() as usize).max(cfg.min_budget).min(b_init);
    let freed = total - g3_members.len() * g3_budget;
    let boosted = freed / keep;
    let mut remainder = freed - boosted * keep;

    let mut budgets = vec![0usize; n];
    // Hand the rounding remainder to the most important layers first
    // (ascending cosine -> stable order by (group, mean, index)).
    let mut keep_order: Vec<usize> = (0..n).filter(|&i| clustering.assignment[i] != g3).collect();
    keep_order.sort_by(|&a, &b| {
        clustering.assignment[a]
            .cmp(&clustering.assignment[b])
            .then(layer_means[a].partial_cmp(&layer_means[b]).unwrap())
            .then(a.cmp(&b))
    });
    for &i in &keep_order {
        budgets[i] = boosted;
        if remainder > 0 {
            budgets[i] += 1;
            remainder -= 1;
        }
    }
    for &i in &g3_members {
        budgets[i] = g3_budget;
    }

    debug_assert_eq!(budgets.iter().sum::<usize>(), total);
    BudgetPlan {
        budgets,
        groups: clustering.assignment,
        layer_means: layer_means.to_vec(),
        reallocated: g3_budget < b_init,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(p: f64) -> SqueezeConfig {
        SqueezeConfig { enabled: true, p, groups: 3, min_budget: 1 }
    }

    #[test]
    fn conserves_total_budget() {
        // 8 layers: 2 special (low), 3 mid, 3 high cosine.
        let means = [0.1, 0.15, 0.5, 0.55, 0.52, 0.9, 0.92, 0.95];
        let plan = allocate(&means, 100, &cfg(0.3));
        assert_eq!(plan.total(), 800);
        assert!(plan.reallocated);
        // G3 layers squeezed to 30.
        assert_eq!(plan.budgets[5], 30);
        assert_eq!(plan.budgets[6], 30);
        assert_eq!(plan.budgets[7], 30);
        // Important layers got boosted above b_init.
        assert!(plan.budgets[0] > 100 && plan.budgets[2] > 100);
    }

    #[test]
    fn paper_appendix_a2_example() {
        // 32 layers, 18 important, 14 unimportant, b_init=1000, p=0.3:
        // unimportant -> 300, important -> (18000 + 700*14)/18 = 1544.
        let mut means = vec![0.2; 10];
        means.extend(vec![0.5; 8]);
        means.extend(vec![0.9; 14]);
        let plan = allocate(&means, 1000, &cfg(0.3));
        assert_eq!(plan.total(), 32_000);
        for i in 18..32 {
            assert_eq!(plan.budgets[i], 300);
        }
        for i in 0..18 {
            assert!(plan.budgets[i] == 1544 || plan.budgets[i] == 1545,
                    "layer {i} got {}", plan.budgets[i]);
        }
    }

    #[test]
    fn p_one_is_identity() {
        let means = [0.1, 0.5, 0.9, 0.2, 0.6, 0.95];
        let plan = allocate(&means, 64, &cfg(1.0));
        assert!(!plan.reallocated);
        assert!(plan.budgets.iter().all(|&b| b == 64));
    }

    #[test]
    fn disabled_is_identity() {
        let mut c = cfg(0.3);
        c.enabled = false;
        let plan = allocate(&[0.1, 0.9, 0.5, 0.2, 0.8], 64, &c);
        assert!(!plan.reallocated);
        assert_eq!(plan.total(), 5 * 64);
    }

    #[test]
    fn degenerate_constant_means() {
        let plan = allocate(&[0.5; 8], 64, &cfg(0.3));
        // k-means collapses; no group separation worth acting on — either
        // identity or a conserved reallocation, but never a budget loss.
        assert_eq!(plan.total(), 8 * 64);
    }

    #[test]
    fn min_budget_floor() {
        let mut c = cfg(0.05);
        c.min_budget = 8;
        let means = [0.1, 0.1, 0.9, 0.9, 0.9, 0.9, 0.9, 0.2];
        let plan = allocate(&means, 20, &c);
        assert_eq!(plan.total(), 160);
        for (i, &g) in plan.groups.iter().enumerate() {
            if g == 2 {
                assert!(plan.budgets[i] >= 8);
            }
        }
    }

    #[test]
    fn few_layers_identity() {
        // n <= groups cannot cluster meaningfully.
        let plan = allocate(&[0.1, 0.9], 64, &cfg(0.3));
        assert!(!plan.reallocated);
    }
}
