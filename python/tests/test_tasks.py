"""Task-suite invariants (mirrored by rust/src/workload/tasks.rs tests)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import tasks


def rng(seed=0):
    return np.random.default_rng(seed)


def test_copy_answer_is_payload():
    prompt, answer = tasks.gen_copy(rng(), 10)
    assert prompt[0] == tasks.BOS and prompt[-1] == tasks.SEP
    assert answer[:-1] == prompt[1:-1]
    assert answer[-1] == tasks.EOS


def test_lookup_answer_correct():
    for seed in range(10):
        prompt, answer = tasks.gen_lookup(rng(seed), 8)
        q = prompt[-2]
        body = prompt[1:prompt.index(tasks.QUERY)]
        pairs = {body[i]: body[i + 2] for i in range(0, len(body), 4)}
        assert answer[0] == pairs[q]
        assert answer[1] == tasks.EOS


def test_lookup_keys_distinct():
    prompt, _ = tasks.gen_lookup(rng(3), 40)
    body = prompt[1:prompt.index(tasks.QUERY)]
    keys = [body[i] for i in range(0, len(body), 4)]
    assert len(set(keys)) == len(keys)


def test_selective_marks():
    prompt, answer = tasks.gen_selective(rng(1), 20, 4)
    marked = [prompt[i + 1] for i, t in enumerate(prompt) if t == tasks.MARK]
    assert answer[:-1] == marked
    assert len(marked) == 4


def test_first_prefix():
    prompt, answer = tasks.gen_first(rng(2), 30)
    assert answer[:tasks.FIRST_K] == prompt[1:1 + tasks.FIRST_K]


def test_lm_next_matches_rust_formula():
    # Mirrors rust workload::tasks::lm_next test values.
    assert tasks.lm_next(1, 1) == ((31 + 17 + 7) % tasks.LM_MOD) + 1


@settings(max_examples=20, deadline=None)
@given(task=st.sampled_from([t for t in tasks.TASKS]),
       n=st.integers(16, 300), seed=st.integers(0, 1000))
def test_sample_token_ranges(task, n, seed):
    prompt, answer = tasks.sample(rng(seed), task, n)
    for t in prompt + answer:
        assert 0 <= t < tasks.VOCAB
    assert prompt[0] == tasks.BOS


@settings(max_examples=10, deadline=None)
@given(seq_len=st.sampled_from([48, 96, 160]), seed=st.integers(0, 500))
def test_training_example_shapes(seq_len, seed):
    toks, mask = tasks.training_example(rng(seed), seq_len)
    assert toks.shape == (seq_len,)
    assert mask.shape == (seq_len,)
    assert toks.dtype == np.int32
    # padding is masked out
    pad_positions = toks == tasks.PAD
    assert np.all(mask[pad_positions] == 0.0)


def test_make_batch():
    toks, mask = tasks.make_batch(rng(5), 4, 64)
    assert toks.shape == (4, 64)
    assert mask.shape == (4, 64)
