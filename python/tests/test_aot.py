"""AOT pipeline unit tests (weight layout + manifest schema; the heavy
HLO-lowering path is exercised by `make artifacts` + the rust runtime)."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile import train as T


CFG = M.ModelConfig(name="test", n_layer=2, d_model=32, n_head=2, vocab=64,
                    ffn_mult=2, max_seq=128)


def test_weight_order_stable():
    names = aot.weight_order(CFG)
    assert names[0] == "embed"
    assert names[1] == "ln_f"
    assert names[2] == "layers.0.ln1"
    assert len(names) == 2 + 2 * 8


def test_params_list_roundtrip():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    lst = aot.params_to_list(CFG, params)
    back = aot.list_to_params(CFG, lst)
    np.testing.assert_array_equal(params["embed"], back["embed"])
    np.testing.assert_array_equal(params["layers"][1]["w2"], back["layers"][1]["w2"])


def test_weight_shapes_match_params():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    lst = aot.params_to_list(CFG, params)
    shapes = aot.weight_shapes(CFG)
    assert len(lst) == len(shapes)
    for arr, shape in zip(lst, shapes):
        assert tuple(arr.shape) == tuple(shape)


def test_flatten_unflatten_roundtrip():
    params = M.init_params(CFG, jax.random.PRNGKey(1))
    flat = T.flatten_params(params)
    back = T.unflatten_params(CFG, flat)
    np.testing.assert_array_equal(params["layers"][0]["wq"], back["layers"][0]["wq"])


def test_manifest_written_by_make_artifacts():
    """If the repo's artifacts exist, validate their schema end-to-end."""
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/tiny/manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        m = json.load(f)
    assert m["model"]["n_layer"] >= 1
    assert m["model"]["head_dim"] * m["model"]["n_head"] == m["model"]["d_model"]
    kinds = {a["kind"] for a in m["artifacts"]}
    assert kinds == {"prefill", "decode"}
    for a in m["artifacts"]:
        assert os.path.exists(os.path.join(os.path.dirname(path), a["file"]))
    # weight index covers the whole bin file contiguously
    idx = m["weights"]["index"]
    total = sum(e["len"] for e in idx)
    bin_path = os.path.join(os.path.dirname(path), m["weights"]["file"])
    assert os.path.getsize(bin_path) == total * 4
    off = 0
    for e in idx:
        assert e["offset"] == off
        assert int(np.prod(e["shape"])) == e["len"]
        off += e["len"]
