"""Layer-2 correctness: prefill/decode graph consistency.

The critical invariant: running prefill on a prompt and then decode steps
with the (full) cache must reproduce the teacher-forced forward pass — this
is exactly the contract the rust engine relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import tasks


CFG = M.ModelConfig(name="test", n_layer=2, d_model=32, n_head=2, vocab=64,
                    ffn_mult=2, max_seq=128)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_prefill_shapes(params):
    L = 64
    toks = jnp.arange(L, dtype=jnp.int32) % 60
    logits, k, v, sims = M.prefill_fn(params, CFG, toks, 40, kernel="jnp")
    assert logits.shape == (CFG.vocab,)
    assert k.shape == (2, L, 2, 16)
    assert v.shape == (2, L, 2, 16)
    assert sims.shape == (2, L)


def test_prefill_pallas_matches_jnp(params):
    L = 64
    toks = (jnp.arange(L, dtype=jnp.int32) * 7) % 60
    out_p = M.prefill_fn(params, CFG, toks, 50, kernel="pallas")
    out_j = M.prefill_fn(params, CFG, toks, 50, kernel="jnp")
    np.testing.assert_allclose(out_p[0], out_j[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(out_p[3][:, :50], out_j[3][:, :50],
                               rtol=1e-4, atol=1e-4)


def test_decode_continues_prefill(params):
    """Greedy decode steps after prefill == teacher-forced argmax chain."""
    p_len, steps, L, Mcap = 20, 6, 64, 40
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, 60, size=p_len).astype(np.int32)

    # Teacher-forced reference: repeatedly prefill the growing sequence.
    seq = list(prompt)
    ref_tokens = []
    for _ in range(steps):
        toks = jnp.asarray(seq + [0] * (L - len(seq)), jnp.int32)
        logits, _, _, _ = M.prefill_fn(params, CFG, toks, len(seq), kernel="jnp")
        t = int(jnp.argmax(logits))
        ref_tokens.append(t)
        seq.append(t)

    # Engine-style: one prefill + decode steps with explicit cache.
    toks = jnp.asarray(list(prompt) + [0] * (L - p_len), jnp.int32)
    logits, k, v, _ = M.prefill_fn(params, CFG, toks, p_len, kernel="jnp")
    B = 1
    k_cache = np.zeros((CFG.n_layer, B, Mcap, CFG.n_head, CFG.head_dim), np.float32)
    v_cache = np.zeros_like(k_cache)
    k_cache[:, 0, :p_len] = np.asarray(k)[:, :p_len]
    v_cache[:, 0, :p_len] = np.asarray(v)[:, :p_len]
    lens = np.full((CFG.n_layer, B), p_len, np.int32)

    got = []
    tok = int(jnp.argmax(logits))
    pos = p_len
    for _ in range(steps):
        got.append(tok)
        logits_d, nk, nv, scores = M.decode_fn(
            params, CFG,
            jnp.asarray([tok], jnp.int32), jnp.asarray([pos], jnp.int32),
            jnp.asarray(k_cache), jnp.asarray(v_cache), jnp.asarray(lens),
            kernel="jnp")
        # append new rows (the rust engine's job)
        for layer in range(CFG.n_layer):
            k_cache[layer, 0, lens[layer, 0]] = np.asarray(nk)[layer, 0]
            v_cache[layer, 0, lens[layer, 0]] = np.asarray(nv)[layer, 0]
        lens += 1
        tok = int(jnp.argmax(logits_d[0]))
        pos += 1

    assert got == ref_tokens


def test_decode_scores_shape_and_mass(params):
    B, Mcap = 2, 32
    k_cache = np.random.default_rng(1).normal(
        size=(CFG.n_layer, B, Mcap, CFG.n_head, CFG.head_dim)).astype(np.float32)
    v_cache = k_cache.copy()
    lens = np.asarray([[10, 0], [10, 0]], np.int32)
    logits, nk, nv, scores = M.decode_fn(
        params, CFG, jnp.asarray([3, 5], jnp.int32), jnp.asarray([10, 0], jnp.int32),
        jnp.asarray(k_cache), jnp.asarray(v_cache), jnp.asarray(lens), kernel="jnp")
    assert scores.shape == (CFG.n_layer, B, Mcap)
    # active slot: mass sums to n_head over cache+self
    np.testing.assert_allclose(np.asarray(scores)[0, 0].sum(), CFG.n_head, rtol=1e-3)
    # inactive slot contributes nothing
    np.testing.assert_allclose(np.asarray(scores)[:, 1], 0.0, atol=1e-6)


def test_lm_loss_decreases_with_memorization():
    """Single-batch overfit sanity: a few Adam steps reduce the loss."""
    from compile import train as T
    cfg = CFG
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    toks, mask = tasks.make_batch(rng, 4, 48, tasks=["copy"])
    toks = jnp.asarray(toks % cfg.vocab)  # clamp into test vocab
    mask = jnp.asarray(mask)
    state = T.adam_init(params)
    loss0 = float(M.lm_loss(params, cfg, toks, mask))
    step = jax.jit(lambda p, s, t, m: _one_step(p, s, t, m, cfg))
    for _ in range(10):
        params, state, loss = step(params, state, toks, mask)
    assert float(loss) < loss0 * 0.9


def _one_step(params, state, toks, mask, cfg):
    from compile import train as T
    loss, grads = jax.value_and_grad(M.lm_loss)(params, cfg, toks, mask)
    params, state = T.adam_update(params, grads, state, 1e-2)
    return params, state, loss


def test_rope_position_dependence(params):
    """Same token at different positions gives different K rows."""
    L = 64
    toks = jnp.full((L,), 7, jnp.int32)
    _, k, _, _ = M.prefill_fn(params, CFG, toks, L, kernel="jnp")
    assert not np.allclose(np.asarray(k)[0, 0], np.asarray(k)[0, 1])
