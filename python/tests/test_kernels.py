"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and length masks; assert_allclose against ref.py is
the core correctness signal for everything the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cosine_rows, decode_attention, flash_prefill
from compile.kernels import ref

ATOL = 2e-5
RTOL = 2e-5


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- flash ----
@settings(max_examples=12, deadline=None)
@given(
    lq=st.sampled_from([64, 128, 256]),
    heads=st.sampled_from([1, 2, 4]),
    dim=st.sampled_from([16, 32]),
    vfrac=st.floats(0.2, 1.0),
    seed=st.integers(0, 2**16),
)
def test_flash_prefill_matches_ref(lq, heads, dim, vfrac, seed):
    q = rand(seed, (lq, heads, dim))
    k = rand(seed + 1, (lq, heads, dim))
    v = rand(seed + 2, (lq, heads, dim))
    vlen = max(2, int(lq * vfrac))
    out = flash_prefill(q, k, v, vlen)
    want = ref.causal_attention(q, k, v, vlen)
    np.testing.assert_allclose(out[:vlen], want[:vlen], rtol=RTOL, atol=ATOL)


def test_flash_prefill_full_length():
    q, k, v = (rand(i, (128, 4, 32)) for i in range(3))
    out = flash_prefill(q, k, v, 128)
    want = ref.causal_attention(q, k, v, 128)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


def test_flash_prefill_rejects_ragged():
    q = rand(0, (100, 2, 16))  # not a multiple of block_q
    with pytest.raises(ValueError):
        flash_prefill(q, q, q, 50)


def test_flash_prefill_first_token_only():
    # vlen=1: every valid query row attends only to position 0.
    q, k, v = (rand(i + 9, (64, 2, 16)) for i in range(3))
    out = flash_prefill(q, k, v, 1)
    want = ref.causal_attention(q, k, v, 1)
    np.testing.assert_allclose(out[:1], want[:1], rtol=RTOL, atol=ATOL)


# --------------------------------------------------------------- decode ----
@settings(max_examples=12, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    m=st.sampled_from([16, 64, 192]),
    heads=st.sampled_from([1, 4]),
    dim=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**16),
)
def test_decode_matches_ref(b, m, heads, dim, seed):
    q = rand(seed, (b, heads, dim))
    kc = rand(seed + 1, (b, m, heads, dim))
    vc = rand(seed + 2, (b, m, heads, dim))
    rng = np.random.default_rng(seed)
    lens = jnp.asarray(rng.integers(0, m + 1, size=b), jnp.int32)
    out, scores = decode_attention(q, kc, vc, lens)
    want_o, want_s = ref.decode_attention(q, kc, vc, lens)
    np.testing.assert_allclose(out, want_o, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(scores, want_s, rtol=RTOL, atol=ATOL)


def test_decode_inactive_slots_zero():
    q = rand(0, (3, 2, 16))
    kc = rand(1, (3, 8, 2, 16))
    vc = rand(2, (3, 8, 2, 16))
    lens = jnp.asarray([0, 4, 0], jnp.int32)
    out, scores = decode_attention(q, kc, vc, lens)
    assert np.allclose(out[0], 0.0) and np.allclose(out[2], 0.0)
    assert np.allclose(scores[0], 0.0) and np.allclose(scores[2], 0.0)
    assert not np.allclose(out[1], 0.0)


def test_decode_scores_sum_to_heads():
    # probability mass per sequence sums to n_heads (softmax over M per head).
    heads = 4
    q = rand(3, (2, heads, 16))
    kc = rand(4, (2, 32, heads, 16))
    vc = rand(5, (2, 32, heads, 16))
    lens = jnp.asarray([32, 7], jnp.int32)
    _, scores = decode_attention(q, kc, vc, lens)
    np.testing.assert_allclose(scores.sum(axis=1), [heads, heads], rtol=1e-4)
    # masked slots get zero mass
    assert np.allclose(scores[1, 7:], 0.0)


# --------------------------------------------------------------- cosine ----
@settings(max_examples=10, deadline=None)
@given(
    rows=st.sampled_from([64, 128, 256]),
    dim=st.sampled_from([8, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_cosine_matches_ref(rows, dim, seed):
    a = rand(seed, (rows, dim))
    b = rand(seed + 1, (rows, dim))
    out = cosine_rows(a, b)
    want = ref.cosine_rows(a, b)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


def test_cosine_identical_rows_one():
    a = rand(7, (64, 32))
    out = cosine_rows(a, a)
    np.testing.assert_allclose(out, np.ones(64), rtol=1e-4)


def test_cosine_opposite_rows_minus_one():
    a = rand(8, (64, 32))
    out = cosine_rows(a, -a)
    np.testing.assert_allclose(out, -np.ones(64), rtol=1e-4)
