"""Build-time training of the tiny model on the synthetic task mixture.

This is the substitution for the paper's pretrained checkpoints (DESIGN.md §4):
a model that has actually *learned* the tasks is required for the accuracy-vs-
budget experiments (Fig. 3, Tables 2/6) to have non-trivial shape — KV eviction
must be able to hurt, and layer importance must be heterogeneous.

Runs once at `make weights`; parameters land in artifacts/weights_<cfg>.npz and
are baked into the HLO artifacts by aot.py. Hand-rolled Adam (no optax
dependency). Deterministic given --seed.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import tasks


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.98, eps=1e-9, clip=1.0):
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, clip / (gnorm + 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda x: x / (1 - b1 ** t), m)
    vhat = jax.tree.map(lambda x: x / (1 - b2 ** t), v)
    params = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
                          params, mhat, vhat)
    return params, {"m": m, "v": v, "t": t}


def flatten_params(params, prefix=""):
    """Stable name -> array mapping for npz round-trip."""
    out = {}
    out["embed"] = params["embed"]
    out["ln_f"] = params["ln_f"]
    for i, layer in enumerate(params["layers"]):
        for k, v in layer.items():
            out[f"layers.{i}.{k}"] = v
    return out


def unflatten_params(cfg, flat):
    params = {"embed": jnp.asarray(flat["embed"]),
              "ln_f": jnp.asarray(flat["ln_f"]), "layers": []}
    for i in range(cfg.n_layer):
        params["layers"].append(
            {k: jnp.asarray(flat[f"layers.{i}.{k}"])
             for k in ["ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2"]})
    return params


def train(cfg, steps, batch, seq_len, lr, seed, log_every=25, init_from=None):
    rng = np.random.default_rng(seed)
    if init_from:
        params = unflatten_params(cfg, dict(np.load(init_from)))
        print(f"resumed from {init_from}")
    else:
        params = M.init_params(cfg, jax.random.PRNGKey(seed))
    state = adam_init(params)

    @jax.jit
    def step(params, state, toks, mask, lr_t):
        loss, grads = jax.value_and_grad(M.lm_loss)(params, cfg, toks, mask)
        params, state = adam_update(params, grads, state, lr_t)
        return params, state, loss

    warmup = max(1, steps // 20)
    t0 = time.time()
    for it in range(steps):
        toks, mask = tasks.make_batch(rng, batch, seq_len)
        # linear warmup + cosine decay to 10%
        frac = it / max(steps - 1, 1)
        lr_t = lr * min(1.0, (it + 1) / warmup) \
            * (0.55 + 0.45 * float(np.cos(np.pi * frac)))
        params, state, loss = step(params, state, jnp.asarray(toks),
                                   jnp.asarray(mask), lr_t)
        if it % log_every == 0 or it == steps - 1:
            print(f"step {it:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return params


def eval_answer_accuracy(params, cfg, rng, n=40, seq_len=192):
    """Teacher-forced answer-token accuracy per task (training sanity only)."""
    accs = {}
    for task in tasks.TASKS:
        if task == "lm":
            continue
        hit = tot = 0
        for _ in range(n):
            prompt, answer = tasks.sample(rng, task, seq_len // 2)
            toks = prompt + answer
            if len(toks) > seq_len:
                continue
            arr = jnp.asarray([toks + [tasks.PAD] * (seq_len - len(toks))],
                              jnp.int32)
            mask = jnp.zeros_like(arr, jnp.float32)
            # reuse lm_loss forward by direct call of internals: compute logits
            logits = _forward_logits(params, cfg, arr)[0]
            for j in range(len(prompt) - 1, len(toks) - 1):
                pred = int(jnp.argmax(logits[j]))
                hit += pred == toks[j + 1]
                tot += 1
        accs[task] = hit / max(tot, 1)
    return accs


def _forward_logits(params, cfg, toks):
    B, T = toks.shape
    positions = jnp.arange(T, dtype=jnp.int32)
    x = params["embed"][toks]
    from .kernels import ref
    for layer in params["layers"]:
        h = M.rmsnorm(x, layer["ln1"])
        q, k, v = M._qkv(layer, h, cfg)
        q = jax.vmap(lambda qq: M.rope(qq, positions, cfg.rope_theta))(q)
        k = jax.vmap(lambda kk: M.rope(kk, positions, cfg.rope_theta))(k)
        attn = jax.vmap(ref.causal_attention)(q, k, v)
        x = x + attn.reshape(B, T, cfg.d_model) @ layer["wo"]
        x = x + M._mlp(layer, M.rmsnorm(x, layer["ln2"]))
    x = M.rmsnorm(x, params["ln_f"])
    return x @ params["embed"].T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="tiny", choices=list(M.CONFIGS))
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--batch", type=int, default=24)
    ap.add_argument("--seq-len", type=int, default=160)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--init-from", default=None,
                    help="resume from an existing weights npz")
    args = ap.parse_args()

    cfg = M.CONFIGS[args.config]
    params = train(cfg, args.steps, args.batch, args.seq_len, args.lr,
                   args.seed, init_from=args.init_from)
    accs = eval_answer_accuracy(params, cfg, np.random.default_rng(args.seed + 1))
    print("teacher-forced answer accuracy:", accs)
    out = args.out or f"../artifacts/weights_{cfg.name}.npz"
    np.savez(out, **{k: np.asarray(v) for k, v in flatten_params(params).items()})
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
