"""Synthetic task suite — the stand-in for the paper's five datasets.

The paper evaluates on CNN/DailyMail, XSUM, SAMSUM, TriviaQA, NarrativeQA.
We have no HF/network access, so we substitute five token-level tasks with
*exactly measurable* answers, chosen so that each stresses a different
token-importance pattern — the property on which the sequence-wise baselines
(Sliding Window / StreamingLLM / H2O) genuinely differ (see DESIGN.md §4):

  copy       repeat the full payload after SEP           (recency + induction)
  lookup     key=value store, answer a queried key       (random access ≈ QA)
  selective  repeat only tokens that follow MARK         (heavy hitters)
  first      repeat the first FIRST_K payload tokens     (sink tokens)
  lm         deterministic 2nd-order recurrence + noise  (local structure)

The rust workload generator (rust/src/workload/tasks.rs) implements the SAME
token-level formats (same special-token ids, same layout); the two sides only
need to agree on the distribution, not on RNG streams.

Token map (shared with rust/src/model/tokenizer.rs):
  0            PAD
  1..=223      content tokens (tasks draw from documented sub-ranges)
  256 BOS, 257 SEP, 258 QUERY, 259 ANSWER, 260 EOS, 261 MARK, 262 EQUALS,
  263 COMMA
  vocab size   272 (rounded up; 264..271 reserved)
"""

import numpy as np

PAD = 0
BOS = 256
SEP = 257
QUERY = 258
ANSWER = 259
EOS = 260
MARK = 261
EQUALS = 262
COMMA = 263
VOCAB = 272

KEY_LO, KEY_HI = 1, 48        # lookup keys
VAL_LO, VAL_HI = 49, 96       # lookup values
WORD_LO, WORD_HI = 1, 96      # copy/selective/first payload
LM_MOD = 96                   # lm recurrence modulus (tokens 1..=96)

FIRST_K = 8                   # `first` task answer length

TASKS = ["copy", "lookup", "selective", "first", "lm"]


def lm_next(a, b):
    """Deterministic component of the lm task: x_t from (x_{t-1}, x_{t-2}).

    Mirrored exactly by rust (workload/tasks.rs::lm_next).
    """
    return ((a * 31 + b * 17 + 7) % LM_MOD) + 1


def gen_copy(rng, payload_len):
    words = rng.integers(WORD_LO, WORD_HI + 1, size=payload_len).tolist()
    prompt = [BOS] + words + [SEP]
    answer = words + [EOS]
    return prompt, answer


def gen_lookup(rng, n_pairs):
    keys = rng.choice(np.arange(KEY_LO, KEY_HI + 1), size=n_pairs,
                      replace=False).tolist()
    vals = rng.integers(VAL_LO, VAL_HI + 1, size=n_pairs).tolist()
    body = []
    for k, v in zip(keys, vals):
        body += [k, EQUALS, v, COMMA]
    qi = int(rng.integers(0, n_pairs))
    prompt = [BOS] + body + [QUERY, keys[qi], ANSWER]
    answer = [vals[qi], EOS]
    return prompt, answer


def gen_selective(rng, payload_len, n_marks):
    words = rng.integers(WORD_LO, WORD_HI + 1, size=payload_len).tolist()
    mark_pos = sorted(rng.choice(payload_len, size=n_marks, replace=False).tolist())
    body = []
    marked = []
    for i, w in enumerate(words):
        if i in set(mark_pos):
            body.append(MARK)
            marked.append(w)
        body.append(w)
    prompt = [BOS] + body + [SEP]
    answer = marked + [EOS]
    return prompt, answer


def gen_first(rng, payload_len):
    words = rng.integers(WORD_LO, WORD_HI + 1, size=payload_len).tolist()
    prompt = [BOS] + words + [QUERY]
    answer = words[:FIRST_K] + [EOS]
    return prompt, answer


def gen_lm(rng, length, noise=0.1):
    seq = [int(rng.integers(1, LM_MOD + 1)), int(rng.integers(1, LM_MOD + 1))]
    for _ in range(length - 2):
        if rng.random() < noise:
            seq.append(int(rng.integers(1, LM_MOD + 1)))
        else:
            seq.append(lm_next(seq[-1], seq[-2]))
    return [BOS] + seq, []  # trained as plain next-token LM; no answer region


def sample(rng, task, approx_prompt_len):
    """Sample one (prompt, answer) sized to roughly approx_prompt_len tokens."""
    n = max(4, approx_prompt_len)
    if task == "copy":
        return gen_copy(rng, max(4, min(n - 2, (n - 2))))
    if task == "lookup":
        return gen_lookup(rng, max(2, min((n - 4) // 4, KEY_HI - KEY_LO)))
    if task == "selective":
        pl = max(8, int((n - 2) / 1.25))
        return gen_selective(rng, pl, max(2, pl // 8))
    if task == "first":
        return gen_first(rng, n - 2)
    if task == "lm":
        return gen_lm(rng, n - 1)
    raise ValueError(f"unknown task {task}")


def training_example(rng, seq_len, tasks=TASKS):
    """One fixed-length training row: prompt + answer, PAD/crop to seq_len.

    Returns (tokens[seq_len], loss_mask[seq_len]) — the mask puts full weight
    on answer tokens and light weight on prompt tokens (the model must still
    learn the prompt LM to have meaningful hidden states).
    """
    task = tasks[int(rng.integers(0, len(tasks)))]
    # Size the prompt so prompt+answer fits (copy/selective roughly double).
    budget = {"copy": seq_len // 2 - 2, "selective": int(seq_len / 2.2),
              "lookup": seq_len - 8, "first": seq_len - FIRST_K - 4,
              "lm": seq_len}[task]
    approx = int(rng.integers(max(8, budget // 3), max(9, budget)))
    prompt, answer = sample(rng, task, approx)
    toks = (prompt + answer)[:seq_len]
    mask = ([0.05] * len(prompt) + [1.0] * len(answer))[:seq_len]
    if task == "lm":
        mask = [1.0] * len(toks)
    pad = seq_len - len(toks)
    toks = toks + [PAD] * pad
    mask = mask + [0.0] * pad
    # never train to predict PAD or from the final position
    return np.array(toks, np.int32), np.array(mask, np.float32)


def make_batch(rng, batch, seq_len, tasks=TASKS):
    xs, ms = zip(*(training_example(rng, seq_len, tasks) for _ in range(batch)))
    return np.stack(xs), np.stack(ms)
