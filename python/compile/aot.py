"""AOT pipeline: lower prefill/decode graphs to HLO *text* artifacts.

Interchange is HLO text, NOT `.serialize()` — the image's xla_extension 0.5.1
rejects jax>=0.5's 64-bit-instruction-id protos; the text parser reassigns ids
(see /opt/xla-example/README.md).

Weights are *runtime inputs*, not baked constants: baking ~1.8M f32 constants
into HLO text makes multi-MB artifacts and slow parses. The rust runtime
uploads the weight set once as device buffers at load time and passes them to
every execute_b call, so there is no per-step weight traffic either. Weight
layout ships as artifacts/<cfg>/weights.bin (raw f32 LE, concatenated in
manifest order) + the index inside manifest.json.

Artifact set per model config:
  prefill_<kernel>_l<L>.hlo.txt      L in PREFILL_BUCKETS, b=1
  decode_<kernel>_b<B>_m<M>.hlo.txt  (B, M) in DECODE_TIERS
  manifest.json                      model cfg, token map, weight index, list
  weights.bin

The capacity tiers are how the paper's memory saving becomes a throughput
saving on a static-shape runtime: a squeezed run binds a small-M executable
and moves less KV per step (DESIGN.md §2).
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import tasks
from . import train as T

PREFILL_BUCKETS = [64, 128, 256, 512]
# (B, M) decode tiers. M=640 fits prompt<=512 + gen<=120 with full cache;
# smaller M tiers serve compressed-budget runs.
DECODE_TIERS = [(1, 640), (2, 640), (4, 640), (8, 640),
                (8, 320), (8, 192), (8, 128), (8, 96), (8, 64),
                (4, 320), (4, 192), (4, 128), (4, 64),
                (16, 192), (16, 128)]
# Kernel-ablation artifacts (jnp oracle path) — small set, used by the
# ablation bench to compare pallas-lowered HLO vs plain-jnp HLO.
JNP_ABLATION_PREFILL = [256]
JNP_ABLATION_DECODE = [(8, 192)]

WEIGHT_KEYS = ["ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2"]


def weight_order(cfg):
    names = ["embed", "ln_f"]
    for i in range(cfg.n_layer):
        names += [f"layers.{i}.{k}" for k in WEIGHT_KEYS]
    return names


def params_to_list(cfg, params):
    flat = T.flatten_params(params)
    return [flat[n] for n in weight_order(cfg)]


def list_to_params(cfg, lst):
    names = weight_order(cfg)
    flat = dict(zip(names, lst))
    return T.unflatten_params(cfg, flat)


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_prefill(cfg, L, kernel):
    def fn(*args):
        weights = args[:-2]
        tokens, valid_len = args[-2], args[-1]
        params = list_to_params(cfg, weights)
        return M.prefill_fn(params, cfg, tokens, valid_len, kernel=kernel)

    wspecs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in weight_shapes(cfg)]
    specs = wspecs + [jax.ShapeDtypeStruct((L,), jnp.int32),
                      jax.ShapeDtypeStruct((), jnp.int32)]
    return jax.jit(fn).lower(*specs)


def lower_decode(cfg, B, Mcap, kernel):
    H, D = cfg.n_head, cfg.head_dim

    def fn(*args):
        weights = args[:-5]
        tokens, positions, k_cache, v_cache, cache_lens = args[-5:]
        params = list_to_params(cfg, weights)
        return M.decode_fn(params, cfg, tokens, positions, k_cache, v_cache,
                           cache_lens, kernel=kernel)

    wspecs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in weight_shapes(cfg)]
    specs = wspecs + [
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((cfg.n_layer, B, Mcap, H, D), jnp.float32),
        jax.ShapeDtypeStruct((cfg.n_layer, B, Mcap, H, D), jnp.float32),
        jax.ShapeDtypeStruct((cfg.n_layer, B), jnp.int32),
    ]
    return jax.jit(fn).lower(*specs)


def weight_shapes(cfg):
    d, f, v = cfg.d_model, cfg.ffn_mult * cfg.d_model, cfg.vocab
    shapes = [(v, d), (d,)]
    per_layer = {"ln1": (d,), "wq": (d, d), "wk": (d, d), "wv": (d, d),
                 "wo": (d, d), "ln2": (d,), "w1": (d, f), "w2": (f, d)}
    for _ in range(cfg.n_layer):
        shapes += [per_layer[k] for k in WEIGHT_KEYS]
    return shapes


def load_or_init_params(cfg, weights_path, seed=0):
    if weights_path and os.path.exists(weights_path):
        flat = dict(np.load(weights_path))
        print(f"loaded trained weights from {weights_path}")
        return T.unflatten_params(cfg, flat), True
    print("WARNING: no trained weights found; using deterministic random init")
    return M.init_params(cfg, jax.random.PRNGKey(seed)), False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="tiny", choices=list(M.CONFIGS))
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--weights", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="only the artifacts needed by tests/quickstart")
    args = ap.parse_args()

    cfg = M.CONFIGS[args.config]
    out = os.path.join(args.out_dir, cfg.name)
    os.makedirs(out, exist_ok=True)
    weights_path = args.weights or os.path.join(args.out_dir,
                                                f"weights_{cfg.name}.npz")
    params, trained = load_or_init_params(cfg, weights_path)

    # --- weights.bin ---------------------------------------------------
    order = weight_order(cfg)
    arrays = params_to_list(cfg, params)
    windex, off = [], 0
    with open(os.path.join(out, "weights.bin"), "wb") as f:
        for name, arr in zip(order, arrays):
            a = np.asarray(arr, np.float32)
            f.write(a.tobytes())
            windex.append({"name": name, "shape": list(a.shape),
                           "offset": off, "len": int(a.size)})
            off += a.size

    # --- HLO artifacts --------------------------------------------------
    prefill_buckets = PREFILL_BUCKETS if not args.fast else [64, 128]
    decode_tiers = DECODE_TIERS if not args.fast else [(1, 640), (4, 192)]
    entries = []

    def emit(name, lowered, meta):
        t0 = time.time()
        text = to_hlo_text(lowered)
        path = os.path.join(out, name)
        with open(path, "w") as f:
            f.write(text)
        entries.append({"file": name, **meta})
        print(f"  {name}: {len(text)} chars ({time.time() - t0:.1f}s)",
              flush=True)

    for L in prefill_buckets:
        emit(f"prefill_pallas_l{L}.hlo.txt", lower_prefill(cfg, L, "pallas"),
             {"kind": "prefill", "kernel": "pallas", "len": L})
    for (B, Mcap) in decode_tiers:
        emit(f"decode_pallas_b{B}_m{Mcap}.hlo.txt",
             lower_decode(cfg, B, Mcap, "pallas"),
             {"kind": "decode", "kernel": "pallas", "batch": B, "cap": Mcap})
    if not args.fast:
        for L in JNP_ABLATION_PREFILL:
            emit(f"prefill_jnp_l{L}.hlo.txt", lower_prefill(cfg, L, "jnp"),
                 {"kind": "prefill", "kernel": "jnp", "len": L})
        for (B, Mcap) in JNP_ABLATION_DECODE:
            emit(f"decode_jnp_b{B}_m{Mcap}.hlo.txt",
                 lower_decode(cfg, B, Mcap, "jnp"),
                 {"kind": "decode", "kernel": "jnp", "batch": B, "cap": Mcap})

    manifest = {
        "model": cfg.to_dict(),
        "trained": trained,
        "tokens": {"pad": tasks.PAD, "bos": tasks.BOS, "sep": tasks.SEP,
                   "query": tasks.QUERY, "answer": tasks.ANSWER,
                   "eos": tasks.EOS, "mark": tasks.MARK,
                   "equals": tasks.EQUALS, "comma": tasks.COMMA},
        "weights": {"file": "weights.bin", "dtype": "f32", "index": windex},
        "artifacts": entries,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(entries)} artifacts -> {out}/manifest.json")


if __name__ == "__main__":
    main()
