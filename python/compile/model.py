"""Layer 2 — the JAX transformer whose prefill/decode graphs become artifacts.

GPT-style decoder: RMSNorm pre-norm, rotary embeddings, multi-head attention
(optionally grouped-query), GELU MLP, tied embedding/unembedding. Two entry
points are AOT-lowered (aot.py) with the weights baked in as constants:

  prefill_fn  one sequence, bucketed length L; returns next-token logits, the
              full K/V cache, and the per-layer per-token cosine similarity of
              the residual stream across the attention block — the
              SqueezeAttention layer-importance probe (paper Eq. 5).
  decode_fn   B sequence slots, one token each, attending to rust-owned padded
              KV caches with per-layer valid lengths; returns logits, the new
              K/V rows to append, and per-slot attention mass (H2O signal).

`kernel="pallas"` routes attention + cosine through the Layer-1 Pallas kernels
(interpret=True; the shipped artifacts), `kernel="jnp"` through the pure-jnp
oracles (training fast-path and the kernel-ablation artifacts).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import tasks
from .kernels import cosine_rows as _pl_cosine_rows
from .kernels import decode_attention as _pl_decode_attention
from .kernels import flash_prefill as _pl_flash_prefill
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    n_layer: int = 8
    d_model: int = 128
    n_head: int = 4
    vocab: int = tasks.VOCAB
    ffn_mult: int = 4
    max_seq: int = 640
    rope_theta: float = 10000.0

    @property
    def head_dim(self):
        return self.d_model // self.n_head

    def to_dict(self):
        d = dataclasses.asdict(self)
        d["head_dim"] = self.head_dim
        return d


CONFIGS = {
    "tiny": ModelConfig(),
    "small": ModelConfig(name="small", n_layer=12, d_model=256, n_head=8),
}


def init_params(cfg, key):
    """Deterministic init; scaled like GPT-2 (residual projections damped)."""
    keys = jax.random.split(key, 2 + cfg.n_layer)
    s = cfg.d_model ** -0.5
    params = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "ln_f": jnp.ones((cfg.d_model,)),
        "layers": [],
    }
    resid = (2 * cfg.n_layer) ** -0.5
    for i in range(cfg.n_layer):
        k = jax.random.split(keys[2 + i], 6)
        d, f = cfg.d_model, cfg.ffn_mult * cfg.d_model
        params["layers"].append({
            "ln1": jnp.ones((d,)),
            "wq": jax.random.normal(k[0], (d, d)) * s,
            "wk": jax.random.normal(k[1], (d, d)) * s,
            "wv": jax.random.normal(k[2], (d, d)) * s,
            "wo": jax.random.normal(k[3], (d, d)) * s * resid,
            "ln2": jnp.ones((d,)),
            "w1": jax.random.normal(k[4], (d, f)) * s,
            "w2": jax.random.normal(k[5], (f, d)) * (f ** -0.5) * resid,
        })
    return params


def rmsnorm(x, scale, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope(x, positions, theta):
    """Rotary embedding. x: [..., H, D]; positions broadcastable to x[..., :-2]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., half]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv(layer, x, cfg):
    H, D = cfg.n_head, cfg.head_dim
    q = (x @ layer["wq"]).reshape(*x.shape[:-1], H, D)
    k = (x @ layer["wk"]).reshape(*x.shape[:-1], H, D)
    v = (x @ layer["wv"]).reshape(*x.shape[:-1], H, D)
    return q, k, v


def _mlp(layer, x):
    return jax.nn.gelu(x @ layer["w1"]) @ layer["w2"]


def prefill_fn(params, cfg, tokens, valid_len, kernel="pallas"):
    """Prefill one sequence of bucketed length L.

    Args:
      tokens: [L] int32 (PAD beyond valid_len).
      valid_len: scalar int32.
    Returns:
      logits:   [vocab]           next-token logits at position valid_len - 1
      k_cache:  [n_layer, L, H, D]  (RoPE already applied to K)
      v_cache:  [n_layer, L, H, D]
      cos_sims: [n_layer, L]      residual cosine across each attention block
    """
    L = tokens.shape[0]
    positions = jnp.arange(L, dtype=jnp.int32)
    x = params["embed"][tokens]  # [L, d]
    ks, vs, sims = [], [], []
    for layer in params["layers"]:
        h = rmsnorm(x, layer["ln1"])
        q, k, v = _qkv(layer, h, cfg)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if kernel == "pallas":
            attn = _pl_flash_prefill(q, k, v, valid_len)
        else:
            attn = ref.causal_attention(q, k, v, valid_len)
        attn = attn.reshape(L, cfg.d_model) @ layer["wo"]
        x_new = x + attn
        if kernel == "pallas":
            sims.append(_pl_cosine_rows(x, x_new))
        else:
            sims.append(ref.cosine_rows(x, x_new))
        x = x_new
        x = x + _mlp(layer, rmsnorm(x, layer["ln2"]))
        ks.append(k)
        vs.append(v)
    x = rmsnorm(x, params["ln_f"])
    last = x[valid_len - 1]  # [d]
    logits = last @ params["embed"].T
    return (logits, jnp.stack(ks), jnp.stack(vs), jnp.stack(sims))


def decode_fn(params, cfg, tokens, positions, k_cache, v_cache, cache_lens,
              kernel="pallas"):
    """One decode step for B sequence slots.

    Args:
      tokens:     [B] int32 (garbage for inactive slots).
      positions:  [B] int32 absolute positions of the new tokens.
      k_cache, v_cache: [n_layer, B, M, H, D] valid-prefix padded.
      cache_lens: [n_layer, B] int32 valid slots (0 = inactive).
    Returns:
      logits: [B, vocab]
      new_k, new_v: [n_layer, B, H, D] rows to append (K rotated).
      scores: [n_layer, B, M] per-slot attention mass (H2O signal).
    """
    B = tokens.shape[0]
    x = params["embed"][tokens]  # [B, d]
    new_ks, new_vs, score_list = [], [], []
    for i, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["ln1"])
        q, k, v = _qkv(layer, h, cfg)  # [B, H, D]
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        # The new token attends to the cache PLUS itself: fold itself in by
        # placing (k, v) at slot cache_len. Rust owns the real append; inside
        # the step we attend to cache ++ self via a scatter into the padded
        # buffer (cache_len < M always holds — rust evicts *before* the step
        # whenever a layer is at budget).
        lens = cache_lens[i]  # [B]
        bidx = jnp.arange(B)
        kc = k_cache[i].at[bidx, lens].set(k)
        vc = v_cache[i].at[bidx, lens].set(v)
        attend_lens = jnp.where(lens > 0, lens + 1, 0)  # inactive stays 0
        if kernel == "pallas":
            attn, scores = _pl_decode_attention(q, kc, vc, attend_lens)
        else:
            attn, scores = ref.decode_attention(q, kc, vc, attend_lens)
        attn = attn.reshape(B, cfg.d_model) @ layer["wo"]
        x = x + attn
        x = x + _mlp(layer, rmsnorm(x, layer["ln2"]))
        new_ks.append(k)
        new_vs.append(v)
        score_list.append(scores)
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["embed"].T
    return (logits, jnp.stack(new_ks), jnp.stack(new_vs), jnp.stack(score_list))


def lm_loss(params, cfg, tokens, mask):
    """Training loss: next-token CE, weighted by mask. tokens: [B, T]."""
    B, T = tokens.shape
    positions = jnp.arange(T, dtype=jnp.int32)
    x = params["embed"][tokens]
    for layer in params["layers"]:
        h = rmsnorm(x, layer["ln1"])
        q, k, v = jax.vmap(lambda hh: _qkv(layer, hh, cfg))(h)
        q = jax.vmap(lambda qq: rope(qq, positions, cfg.rope_theta))(q)
        k = jax.vmap(lambda kk: rope(kk, positions, cfg.rope_theta))(k)
        attn = jax.vmap(ref.causal_attention)(q, k, v)
        x = x + attn.reshape(B, T, cfg.d_model) @ layer["wo"]
        x = x + _mlp(layer, rmsnorm(x, layer["ln2"]))
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["embed"].T  # [B, T, V]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    w = mask[:, 1:] * (targets != tasks.PAD)
    return (nll * w).sum() / jnp.maximum(w.sum(), 1.0)


def make_prefill(params, cfg, L, kernel="pallas"):
    """Close over params/cfg -> jittable (tokens[L], valid_len) fn."""
    def fn(tokens, valid_len):
        return prefill_fn(params, cfg, tokens, valid_len, kernel=kernel)
    return fn


def make_decode(params, cfg, B, M, kernel="pallas"):
    def fn(tokens, positions, k_cache, v_cache, cache_lens):
        return decode_fn(params, cfg, tokens, positions, k_cache, v_cache,
                         cache_lens, kernel=kernel)
    return fn
