"""Pallas row-wise cosine-similarity tracker (Layer 1).

The SqueezeAttention layer-importance probe: for every token position, the
cosine similarity between the residual stream entering a self-attention block
and the stream leaving it (Eq. 5 of the paper). The prefill graph calls this
once per layer; the rust coordinator averages over valid prompt tokens and
feeds the per-layer means to 1-D k-means.

Blocked over rows so the tile (2 × block_rows × D) stays VMEM-resident; the
reduction over D happens entirely in-tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cosine_kernel(a_ref, b_ref, o_ref, *, eps):
    a = a_ref[...]  # [block_rows, D]
    b = b_ref[...]
    dot = (a * b).sum(axis=-1)
    na = jnp.sqrt((a * a).sum(axis=-1))
    nb = jnp.sqrt((b * b).sum(axis=-1))
    o_ref[...] = dot / (na * nb + eps)


def cosine_rows(a, b, *, block_rows=64, eps=1e-8, interpret=True):
    """Row-wise cosine similarity between [L, D] matrices -> [L]."""
    L, D = a.shape
    if L % block_rows:
        raise ValueError(f"L={L} must be a multiple of block_rows={block_rows}")
    kernel = functools.partial(_cosine_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(L // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((L,), jnp.float32),
        interpret=interpret,
    )(a, b)
