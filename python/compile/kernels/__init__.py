"""Layer-1 Pallas kernels + pure-jnp oracles.

All kernels run with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); correctness is pinned to kernels.ref by the pytest suite.
"""

from .cosine_tracker import cosine_rows
from .decode_attention import decode_attention
from .flash_prefill import flash_prefill

__all__ = ["cosine_rows", "decode_attention", "flash_prefill"]
