"""Pallas budget-masked decode attention (Layer 1).

One grid step per sequence slot: the new token's query attends to that
sequence's padded KV-cache prefix (`cache_len` valid slots out of capacity M),
and — in the same pass — emits the per-slot attention probability mass summed
over heads. That second output is the accumulation signal the rust coordinator
feeds the H2O (Heavy-Hitter) eviction policy, so H2O costs nothing extra on the
request path.

Unlike the prefill kernel this one is deliberately single-shot (no online
softmax): M is the *compressed* per-layer budget, small by construction of the
paper's technique, so one sequence's full [M, H, D] stripe fits comfortably in
VMEM (640×4×32 f32 ≈ 320 KiB for the largest shipped tier).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, s_ref, *, scale):
    cache_len = len_ref[0, 0]
    q = q_ref[0] * scale                    # [H, D]
    k = k_ref[0]                            # [M, H, D]
    v = v_ref[0]
    M = k.shape[0]

    logits = jnp.einsum("hd,mhd->hm", q, k)  # [H, M]
    slot = jax.lax.iota(jnp.int32, M)
    valid = slot < cache_len                 # [M]
    logits = jnp.where(valid[None, :], logits, NEG_INF)
    m = logits.max(axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / e.sum(axis=-1, keepdims=True)
    active = cache_len > 0
    probs = jnp.where(active, probs, 0.0)    # kill garbage from empty slots
    o_ref[0] = jnp.einsum("hm,mhd->hd", probs, v)
    s_ref[0] = probs.sum(axis=0)             # [M] — H2O mass per cache slot


def decode_attention(q, k_cache, v_cache, cache_len, *, scale=None,
                     interpret=True):
    """Batched single-token attention over padded per-sequence caches.

    Args:
      q: [B, H, D] f32.
      k_cache, v_cache: [B, M, H, D] f32, valid-prefix padded.
      cache_len: [B] int32 — valid slots per sequence (0 = inactive slot).
    Returns:
      out: [B, H, D] f32 (zeros for inactive slots).
      scores: [B, M] f32 — per-slot attention mass summed over heads.
    """
    B, M, H, D = k_cache.shape
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    lens = cache_len.astype(jnp.int32).reshape((B, 1))
    kernel = functools.partial(_decode_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, H, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, M, H, D), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, M, H, D), lambda b: (b, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, M), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, D), jnp.float32),
            jax.ShapeDtypeStruct((B, M), jnp.float32),
        ],
        interpret=interpret,
    )(lens, q, k_cache, v_cache)
