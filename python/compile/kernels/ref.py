"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package is
checked against the corresponding function here (pytest + hypothesis sweeps in
python/tests/). They are deliberately written in the most direct way possible —
no tiling, no online softmax — so a mismatch always indicts the kernel.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def causal_attention(q, k, v, valid_len=None, scale=None):
    """Naive causal multi-head attention.

    Args:
      q, k, v: [L, H, D]
      valid_len: optional scalar int — key positions >= valid_len are masked
        out (padding of a bucketed prefill).
      scale: optional softmax scale; defaults to 1/sqrt(D).
    Returns:
      out: [L, H, D]
    """
    L, H, D = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(D, q.dtype))
    logits = jnp.einsum("qhd,khd->hqk", q, k) * scale  # [H, Lq, Lk]
    pos = jnp.arange(L)
    causal = pos[None, :] <= pos[:, None]  # [Lq, Lk]
    mask = causal[None, :, :]
    if valid_len is not None:
        kv_ok = pos[None, None, :] < valid_len
        mask = jnp.logical_and(mask, kv_ok)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", probs, v)


def decode_attention(q, k_cache, v_cache, cache_len, scale=None):
    """Single-token decode attention against a padded KV cache.

    Args:
      q: [B, H, D] — the new token's query per sequence.
      k_cache, v_cache: [B, M, H, D] — padded cache (valid prefix).
      cache_len: [B] int — number of valid slots per sequence (0 => inactive
        slot; output and scores are zeros).
      scale: optional; defaults to 1/sqrt(D).
    Returns:
      out: [B, H, D]
      scores: [B, M] — attention probability mass per cache slot, summed over
        heads (the H2O accumulation signal).
    """
    B, M, H, D = k_cache.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(D, q.dtype))
    logits = jnp.einsum("bhd,bmhd->bhm", q, k_cache) * scale
    slot = jnp.arange(M)
    valid = slot[None, :] < cache_len[:, None]  # [B, M]
    logits = jnp.where(valid[:, None, :], logits, NEG_INF)
    m = logits.max(axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / e.sum(axis=-1, keepdims=True)
    # Inactive sequences (cache_len == 0): all-masked softmax is garbage; zero it.
    active = (cache_len > 0)[:, None, None]
    probs = jnp.where(active, probs, 0.0)
    out = jnp.einsum("bhm,bmhd->bhd", probs, v_cache)
    scores = probs.sum(axis=1)  # [B, M]
    return out, scores


def cosine_rows(a, b, eps=1e-8):
    """Row-wise cosine similarity between two [L, D] matrices -> [L]."""
    dot = (a * b).sum(axis=-1)
    na = jnp.sqrt((a * a).sum(axis=-1))
    nb = jnp.sqrt((b * b).sum(axis=-1))
    return dot / (na * nb + eps)
