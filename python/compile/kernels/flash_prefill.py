"""Pallas flash-style causal prefill attention (Layer 1).

TPU mapping of the hot spot (see DESIGN.md §Hardware-Adaptation): the grid is
(heads, query-blocks); BlockSpec streams one query tile plus this head's full
K/V stripe through VMEM, and an online-softmax fori_loop walks the KV stripe in
`block_k` tiles so the L×L score matrix is never materialized in HBM. On a real
TPU the (block_q × block_k) partial matmuls are MXU-shaped; here the kernel is
executed with interpret=True (the CPU PJRT plugin cannot run Mosaic
custom-calls) and validated against kernels.ref.causal_attention.

VMEM footprint per grid step (f32):
  q tile        block_q × D
  K,V stripe    2 × L × D
  accumulators  block_q × (D + 2)
For the shipped configs (L ≤ 640, D = 32, block_q = 64) that is ~180 KiB —
far under the ~16 MiB VMEM budget, leaving room for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(vlen_ref, q_ref, k_ref, v_ref, o_ref, *, block_q, block_k,
                  kv_len, scale):
    qi = pl.program_id(1)
    vlen = vlen_ref[0]

    q = q_ref[:, 0, :] * scale  # [block_q, D]
    d = q.shape[-1]

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # global q rows

    num_kv_blocks = kv_len // block_k

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[pl.dslice(j * block_k, block_k), 0, :]  # [block_k, D]
        v = v_ref[pl.dslice(j * block_k, block_k), 0, :]
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.dot(q, k.T)  # [block_q, block_k]
        mask = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] < vlen)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_kv_blocks, body, (m0, l0, acc0))
    # Padded query rows (q_pos >= vlen) still have l > 0 because the causal
    # diagonal element survives the mask only when k_pos < vlen; fully masked
    # rows end with l == 0 — guard the division.
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[:, 0, :] = acc / l[:, None]


def flash_prefill(q, k, v, valid_len, *, block_q=64, block_k=64, scale=None,
                  interpret=True):
    """Tiled causal attention over a (possibly padded) prompt.

    Args:
      q, k, v: [L, H, D] f32. L must be a multiple of block_q and block_k.
      valid_len: scalar int32 — key positions >= valid_len are padding.
    Returns:
      out: [L, H, D] f32 (rows >= valid_len are unspecified padding).
    """
    L, H, D = q.shape
    if L % block_q or L % block_k:
        raise ValueError(f"L={L} must be a multiple of block_q/block_k")
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    vlen = jnp.asarray(valid_len, jnp.int32).reshape((1,))
    kernel = functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                               kv_len=L, scale=scale)
    grid = (H, L // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda h, i: (0,)),
            pl.BlockSpec((block_q, 1, D), lambda h, i: (i, h, 0)),
            pl.BlockSpec((L, 1, D), lambda h, i: (0, h, 0)),
            pl.BlockSpec((L, 1, D), lambda h, i: (0, h, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, 1, D), lambda h, i: (i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((L, H, D), jnp.float32),
        interpret=interpret,
    )(vlen, q, k, v)
