//! Paper-scale what-if explorer: run the A100 cost model over the whole
//! model zoo (the 7 models the paper evaluates) and print memory/throughput/
//! OOM projections for Full Cache vs SqueezeAttention. No artifacts needed.
//!
//!     cargo run --release --example paper_scale_projection

use squeezeattention::simulator::{
    per_token_kv_bytes, simulate_decode, KvPolicy, A100_40GB_X8, ZOO,
};
use squeezeattention::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let cluster = A100_40GB_X8;
    let (prompt, gen) = (512usize, 1024usize);
    let seq = prompt + gen;

    println!("== per-token KV bytes across the zoo (seq {seq}) ==");
    let mut mem = Table::new(&["model", "layers", "kv B/token (full)", "squeeze@20%", "saving"]);
    for model in ZOO {
        let full = per_token_kv_bytes(model, &KvPolicy::Full, seq);
        let sq_policy =
            KvPolicy::squeeze(model.n_layer, model.n_layer / 2, (seq as f64 * 0.2) as usize, 0.35);
        let sq = per_token_kv_bytes(model, &sq_policy, seq);
        mem.row(vec![
            model.name.into(),
            model.n_layer.to_string(),
            format!("{full:.0}"),
            format!("{sq:.0}"),
            format!("-{:.0}%", (1.0 - sq / full) * 100.0),
        ]);
    }
    mem.print();

    println!("\n== max batch before OOM on {} ==", cluster.name);
    let mut oom = Table::new(&["model", "full-cache max batch", "squeeze max batch", "gain"]);
    for model in ZOO {
        let sq_policy =
            KvPolicy::squeeze(model.n_layer, model.n_layer / 2, (seq as f64 * 0.2) as usize, 0.35);
        let max_batch = |policy: &KvPolicy| {
            let mut best = 0usize;
            for b in (1..=4096).step_by(1) {
                if simulate_decode(model, &cluster, policy, b, prompt, gen).tokens_per_s.is_some() {
                    best = b;
                } else {
                    break;
                }
            }
            best
        };
        let f = max_batch(&KvPolicy::Full);
        let s = max_batch(&sq_policy);
        oom.row(vec![
            model.name.into(),
            f.to_string(),
            s.to_string(),
            if f == 0 { "weights do not fit".into() } else { format!("{:.1}x", s as f64 / f as f64) },
        ]);
    }
    oom.print();

    println!("\n== throughput at the paper's Table-3 operating points ==");
    let mut tp = Table::new(&["model", "batch", "full tok/s", "squeeze tok/s", "speedup"]);
    for (model, batch) in [(&ZOO[0], 128usize), (&ZOO[0], 224), (&ZOO[2], 32), (&ZOO[2], 64)] {
        let sq_policy =
            KvPolicy::squeeze(model.n_layer, model.n_layer / 2, (seq as f64 * 0.2) as usize, 0.35);
        let full = simulate_decode(model, &cluster, &KvPolicy::Full, batch, prompt, gen);
        let sq = simulate_decode(model, &cluster, &sq_policy, batch, prompt, gen);
        let fmt = |t: Option<f64>| t.map(|x| format!("{x:.0}")).unwrap_or_else(|| "OOM".into());
        let speedup = match (full.tokens_per_s, sq.tokens_per_s) {
            (Some(f), Some(s)) => format!("{:.2}x", s / f),
            (None, Some(_)) => "∞ (full OOM)".into(),
            _ => "-".into(),
        };
        tp.row(vec![
            model.name.into(),
            batch.to_string(),
            fmt(full.tokens_per_s),
            fmt(sq.tokens_per_s),
            speedup,
        ]);
    }
    tp.print();
    Ok(())
}
