//! LongBench-style evaluation: every task × every eviction policy, with and
//! without SqueezeAttention, at one budget — the cross-product view that
//! Fig. 3 summarizes per-task. Runs on the simulated backend by default
//! (SA_ARTIFACTS overrides).
//!
//!     cargo run --release --example serve_longbench

use squeezeattention::config::{PolicyKind, ServeConfig};
use squeezeattention::coordinator::Engine;
use squeezeattention::util::bench::Table;
use squeezeattention::workload::{evaluate, EvalSpec, ALL_TASKS};

fn main() -> anyhow::Result<()> {
    let artifacts =
        std::env::var("SA_ARTIFACTS").unwrap_or_else(|_| "sim://tiny".to_string());
    let budget_frac: f64 =
        std::env::var("SA_BUDGET").ok().and_then(|v| v.parse().ok()).unwrap_or(0.25);
    let n: usize = std::env::var("SA_REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(4);

    let mut eng = Engine::new(ServeConfig::new(artifacts.as_str()))?;
    let policies =
        [PolicyKind::SlidingWindow, PolicyKind::StreamingLlm, PolicyKind::H2o];

    let mut table = Table::new(&["task", "policy", "baseline acc", "+squeeze acc", "delta"]);
    for task in ALL_TASKS {
        let spec = EvalSpec::new(task, n, 160, 32, 123);
        for policy in policies {
            let base = evaluate(
                &mut eng,
                ServeConfig::new(artifacts.as_str())
                    .with_policy(policy)
                    .with_budget_frac(budget_frac)
                    .with_squeeze(false),
                &spec,
            )?;
            let sq = evaluate(
                &mut eng,
                ServeConfig::new(artifacts.as_str())
                    .with_policy(policy)
                    .with_budget_frac(budget_frac)
                    .with_squeeze(true),
                &spec,
            )?;
            println!(
                "{:9} x {:13}  baseline {:.3}  +squeeze {:.3}",
                task.name(),
                policy.name(),
                base.accuracy,
                sq.accuracy
            );
            table.row(vec![
                task.name().into(),
                policy.name().into(),
                format!("{:.3}", base.accuracy),
                format!("{:.3}", sq.accuracy),
                format!("{:+.3}", sq.accuracy - base.accuracy),
            ]);
        }
    }
    println!("\nLongBench-style grid @ {:.0}% budget:", budget_frac * 100.0);
    table.print();
    table.write_csv("reports/longbench_grid.csv")?;
    Ok(())
}
