//! End-to-end validation driver (EXPERIMENTS.md §E2E): boots the full
//! serving stack — router, worker engine, TCP JSON-lines server — then
//! drives batched requests over a real socket and reports latency,
//! throughput, accuracy and KV memory, for Full Cache vs best-baseline vs
//! +SqueezeAttention. Requests are pipelined on one connection, so they
//! stream into the worker's continuous-batching scheduler and join its
//! running batch mid-flight.
//!
//!     cargo run --release --example e2e_serving            # sim backend
//!     SA_ARTIFACTS=artifacts/tiny cargo run --release --example e2e_serving

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use squeezeattention::config::{PolicyKind, ServeConfig};
use squeezeattention::coordinator::{server, RoutePolicy, Router};
use squeezeattention::metrics::Histogram;
use squeezeattention::util::bench::Table;
use squeezeattention::util::Json;
use squeezeattention::workload::{answer_accuracy, TraceSpec};

struct ArmResult {
    name: &'static str,
    throughput: f64,
    accuracy: f64,
    p50: f64,
    p95: f64,
    wall: f64,
}

fn run_arm(name: &'static str, cfg: ServeConfig, n: usize) -> anyhow::Result<ArmResult> {
    // Boot the full network stack for this arm.
    let router = Arc::new(Router::spawn(cfg, 1, RoutePolicy::LeastLoaded)?);
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    std::thread::spawn(move || {
        let _ = server::serve(listener, router);
    });

    let items = TraceSpec::closed(n, 144, 32, 11).generate();
    let stream = TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);

    let t0 = std::time::Instant::now();
    // Pipeline all requests on one connection (the worker micro-batches).
    for (i, it) in items.iter().enumerate() {
        let prompt: Vec<String> = it.sample.prompt.iter().map(|t| t.to_string()).collect();
        writeln!(
            writer,
            "{{\"id\": {i}, \"prompt\": [{}], \"max_new_tokens\": {}}}",
            prompt.join(","),
            it.max_new_tokens
        )?;
    }
    let mut lat = Histogram::new();
    let mut acc_sum = 0.0;
    let mut acc_n = 0;
    let mut gen_tokens = 0usize;
    for _ in 0..n {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let j = Json::parse(&line)?;
        let id = j.req("id")?.as_usize().unwrap();
        let generated: Vec<i32> = j
            .req("generated")?
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|v| v.as_i64().map(|x| x as i32))
            .collect();
        gen_tokens += generated.len();
        lat.record(j.req("total_s")?.as_f64().unwrap());
        let a = answer_accuracy(&items[id].sample, &generated);
        if a.is_finite() {
            acc_sum += a;
            acc_n += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok(ArmResult {
        name,
        throughput: gen_tokens as f64 / wall,
        accuracy: acc_sum / acc_n.max(1) as f64,
        p50: lat.p50(),
        p95: lat.p95(),
        wall,
    })
}

fn main() -> anyhow::Result<()> {
    let artifacts =
        std::env::var("SA_ARTIFACTS").unwrap_or_else(|_| "sim://tiny".to_string());
    let n: usize = std::env::var("SA_REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(10);
    println!("e2e serving driver: {n} mixed-task requests over TCP per arm ({artifacts})\n");

    let arms: Vec<(&'static str, ServeConfig)> = vec![
        ("full-cache", ServeConfig::new(artifacts.as_str()).with_policy(PolicyKind::Full)),
        (
            "sliding@30% (baseline)",
            ServeConfig::new(artifacts.as_str())
                .with_policy(PolicyKind::SlidingWindow)
                .with_budget_frac(0.3)
                .with_squeeze(false),
        ),
        (
            "sliding@20% +squeeze",
            ServeConfig::new(artifacts.as_str())
                .with_policy(PolicyKind::SlidingWindow)
                .with_budget_frac(0.2)
                .with_squeeze(true),
        ),
    ];

    let mut table = Table::new(&["arm", "tok/s", "accuracy", "p50 lat", "p95 lat", "wall s"]);
    for (name, cfg) in arms {
        let r = run_arm(name, cfg, n)?;
        println!(
            "{:24} {:6.1} tok/s  acc {:.3}  p50 {:.2}s  p95 {:.2}s",
            r.name, r.throughput, r.accuracy, r.p50, r.p95
        );
        table.row(vec![
            r.name.into(),
            format!("{:.1}", r.throughput),
            format!("{:.3}", r.accuracy),
            format!("{:.2}s", r.p50),
            format!("{:.2}s", r.p95),
            format!("{:.1}", r.wall),
        ]);
    }
    println!("\nE2E summary (full stack: TCP -> router -> engine -> PJRT):");
    table.print();
    table.write_csv("reports/e2e_serving.csv")?;
    Ok(())
}
