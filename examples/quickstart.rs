//! Quickstart: boot the engine, serve one request with SqueezeAttention
//! enabled, and inspect the layer-budget plan it produced. Runs on the
//! simulated backend by default; point SA_ARTIFACTS at an artifact
//! directory (PJRT build) for the compiled tiny model.
//!
//!     cargo run --release --example quickstart

use squeezeattention::config::{PolicyKind, ServeConfig};
use squeezeattention::coordinator::{Engine, Request};
use squeezeattention::model::tokenizer;
use squeezeattention::workload::{answer_accuracy, trim_at_eos, Task, TaskGen};

fn main() -> anyhow::Result<()> {
    // 1. Engine over the artifacts (sim://tiny, or PJRT + HLO-text load).
    let artifacts =
        std::env::var("SA_ARTIFACTS").unwrap_or_else(|_| "sim://tiny".to_string());
    let cfg = ServeConfig::new(artifacts)
        .with_policy(PolicyKind::SlidingWindow) // sequence-wise C_seq
        .with_budget_frac(0.25); // b_init = 25% of the prompt
    let mut engine = Engine::new(cfg)?;

    // 2. A lookup task: "k1=v1; k2=v2; ... <q> k3 <a>" — answer the query.
    let mut gen = TaskGen::new(42);
    let sample = gen.sample(Task::Lookup, 120);
    println!("prompt  : {}", tokenizer::render(&sample.prompt));
    println!("expected: {}", tokenizer::render(&sample.answer));

    // 3. Generate.
    let outs = engine.generate_batch(vec![Request::new(0, sample.prompt.clone(), 8)]);
    let out = &outs[0];
    println!("got     : {}", tokenizer::render(trim_at_eos(&out.generated)));
    println!("accuracy: {:.2}", answer_accuracy(&sample, &out.generated));
    println!("finish  : {:?} in {:.2}s (prefill {:.3}s, squeeze ops {:.6}s)",
             out.finish, out.timing.total_s, out.timing.prefill_s, out.timing.squeeze_s);

    // 4. The 2-D part: per-layer budgets Algorithm 1 allocated for THIS prompt.
    println!("\nlayer-budget plan (b_init = {} tokens):", out.plan.total() / out.plan.budgets.len());
    for (l, (&b, &g)) in out.plan.budgets.iter().zip(&out.plan.groups).enumerate() {
        println!(
            "  layer {l}: budget {b:4}  group G{}  mean-cosine {:.4}",
            g + 1,
            out.plan.layer_means[l]
        );
    }
    println!("reallocated: {}  | total conserved: {} tokens", out.plan.reallocated, out.plan.total());
    Ok(())
}
